"""AOT compile path: lower the L2 step functions to HLO *text* artifacts.

HLO text — NOT `lowered.compile()` nor serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's bundled XLA (xla_extension 0.5.1) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Emits, per (J, R, B) variant:
    artifacts/train_step_j{J}_r{R}_b{B}.hlo.txt
    artifacts/factor_step_j{J}_r{R}_b{B}.hlo.txt
    artifacts/predict_j{J}_r{R}_b{B}.hlo.txt
plus artifacts/manifest.tsv, a tab-separated index the Rust runtime parses
(no serde/json available offline on the Rust side):

    <entry-point>\t<file>\t<J>\t<R>\t<B>\t<n_outputs>

Run once via `make artifacts`; a no-op if inputs are unchanged (stamp file).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (J, R_core, batch) variants compiled by default. The small variant is used
# by Rust integration tests; the default one by the end-to-end driver.
DEFAULT_VARIANTS = (
    (8, 8, 256),      # small batch: integration tests / tiny workloads
    (8, 8, 2048),     # perf pass: large batch amortizes PJRT call overhead
    (16, 16, 2048),
)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(J: int, R: int, B: int):
    row = jax.ShapeDtypeStruct((B, J), F32)
    bfac = jax.ShapeDtypeStruct((R, J), F32)
    vals = jax.ShapeDtypeStruct((B,), F32)
    scalar = jax.ShapeDtypeStruct((), F32)
    return row, bfac, vals, scalar


def lower_variant(J: int, R: int, B: int):
    """Lower all three step functions for one shape variant."""
    row, bfac, vals, scalar = _specs(J, R, B)
    entries = []
    entries.append((
        "train_step",
        jax.jit(model.train_step).lower(row, row, row, bfac, bfac, bfac,
                                        vals, scalar, scalar),
        7,
    ))
    entries.append((
        "factor_step",
        jax.jit(model.factor_step).lower(row, row, row, bfac, bfac, bfac,
                                         vals, scalar, scalar),
        4,
    ))
    entries.append((
        "predict",
        jax.jit(model.predict).lower(row, row, row, bfac, bfac, bfac),
        1,
    ))
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default=None,
                    help="comma list of J:R:B triples, e.g. 8:8:256,16:16:2048")
    args = ap.parse_args()

    if args.variants:
        variants = tuple(
            tuple(int(x) for x in v.split(":")) for v in args.variants.split(",")
        )
    else:
        variants = DEFAULT_VARIANTS

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for (J, R, B) in variants:
        for name, lowered, n_out in lower_variant(J, R, B):
            fname = f"{name}_j{J}_r{R}_b{B}.hlo.txt"
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest_lines.append(f"{name}\t{fname}\t{J}\t{R}\t{B}\t{n_out}")
            print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest.tsv ({len(manifest_lines)} entries)")


if __name__ == "__main__":
    main()
