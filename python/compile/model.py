"""L2: the cuFastTucker update step as a JAX compute graph.

Three step functions, each lowered once by aot.py to HLO text and executed
from the Rust coordinator via PJRT (python never runs at training time):

  * train_step  — Eq. 13 factor SGD update for all three modes **and**
                  Eq. 17 core-factor gradient sums, in one fused graph.
  * factor_step — Eq. 13 only (the paper's "Factor" configuration, Fig. 4).
  * predict     — batched x̂ for RMSE/MAE evaluation.

All heavy lifting goes through the L1 Pallas kernel (kernels.fasttucker);
the remaining arithmetic (SGD updates, the (e·w)^T A core-gradient matmuls)
stays in jnp so XLA fuses it with the kernel output.

Shapes are static: one artifact per (J, R, B) variant. The Rust side owns
gather/scatter of factor rows (HLO cannot do dynamic-size scatter cheaply,
and the coordinator already owns the index structure).

Update semantics: within one batch every sample reads the *pre-batch*
factors (mini-batch SGD at a single linearization point). The native Rust
engine has an identical `batched` mode used for cross-checking artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import fasttucker as ker


def train_step(a1, a2, a3, b1, b2, b3, vals, lr, lam):
    """One mini-batch step: updated factor rows + core-factor gradient sums.

    Args:
      a1, a2, a3: (B, J) gathered factor rows.
      b1, b2, b3: (R, J) Kruskal core factors (transposed layout).
      vals: (B,) observed values.
      lr, lam: scalar learning rate / regularization.

    Returns:
      (new_a1, new_a2, new_a3, gb1, gb2, gb3, e) where new_a* are the
      SGD-updated rows (B, J), gb* are the *summed* core gradients (R, J)
      (caller divides by the sample count, per Algorithm 1's M = |Ψ|),
      and e is the per-sample residual (B,) (reused for loss logging).
    """
    gs1, gs2, gs3, w1, w2, w3, e = ker.contract(a1, a2, a3, b1, b2, b3, vals)

    ecol = e[:, None]
    # Eq. 13: grad a = e * GS + lam * a   (parts (1)+(3) fold into e*GS).
    new_a1 = a1 - lr * (ecol * gs1 + lam * a1)
    new_a2 = a2 - lr * (ecol * gs2 + lam * a2)
    new_a3 = a3 - lr * (ecol * gs3 + lam * a3)

    # Eq. 17: grad b_r^(n) = sum_b e_b * w_n[b,r] * a_n[b,:]  -> (R, J) matmul.
    gb1 = (ecol * w1).T @ a1
    gb2 = (ecol * w2).T @ a2
    gb3 = (ecol * w3).T @ a3

    return new_a1, new_a2, new_a3, gb1, gb2, gb3, e


def factor_step(a1, a2, a3, b1, b2, b3, vals, lr, lam):
    """Eq. 13 factor update only (paper's 'Factor' ablation, Fig. 4)."""
    gs1, gs2, gs3, _, _, _, e = ker.contract(a1, a2, a3, b1, b2, b3, vals)
    ecol = e[:, None]
    new_a1 = a1 - lr * (ecol * gs1 + lam * a1)
    new_a2 = a2 - lr * (ecol * gs2 + lam * a2)
    new_a3 = a3 - lr * (ecol * gs3 + lam * a3)
    return new_a1, new_a2, new_a3, e


def predict(a1, a2, a3, b1, b2, b3):
    """Batched prediction x̂[b] = Σ_r Π_n (a_n[b]·b_r^(n)) for evaluation."""
    c1 = a1 @ b1.T
    c2 = a2 @ b2.T
    c3 = a3 @ b3.T
    return jnp.sum(c1 * c2 * c3, axis=1)
