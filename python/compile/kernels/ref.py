"""Pure-jnp oracle for the Pallas contraction kernel.

Implements the same Thm-1/2 contraction as kernels/fasttucker.py with no
Pallas machinery, and additionally a *naive* reference that materializes the
dense Kruskal core explicitly (the exponential-cost path the paper's
theorems remove) — used by tests to prove the reduction is exact, not
approximate.
"""

from __future__ import annotations

import jax.numpy as jnp


def contract_ref(a1, a2, a3, b1, b2, b3, vals):
    """Thm-1/2 contraction, plain jnp. Same returns as fasttucker.contract."""
    c1 = a1 @ b1.T  # (B, R)
    c2 = a2 @ b2.T
    c3 = a3 @ b3.T
    w1 = c2 * c3
    w2 = c1 * c3
    w3 = c1 * c2
    gs1 = w1 @ b1  # (B, J)
    gs2 = w2 @ b2
    gs3 = w3 @ b3
    xhat = jnp.sum(a1 * gs1, axis=1)
    e = xhat - vals
    return gs1, gs2, gs3, w1, w2, w3, e


def predict_naive(a1, a2, a3, b1, b2, b3):
    """Exponential-cost prediction through the *materialized* dense core.

    Builds the Kruskal core G[j1,j2,j3] = sum_r b1[r,j1] b2[r,j2] b3[r,j3]
    and contracts it against the factor rows directly — O(J^3) per sample,
    the cost the paper's Theorems 1 and 2 eliminate. Tests assert this
    equals the linear-cost path to float tolerance.
    """
    G = jnp.einsum("ri,rj,rk->ijk", b1, b2, b3)
    return jnp.einsum("bi,bj,bk,ijk->b", a1, a2, a3, G)


def gs_naive(a1, a2, a3, b1, b2, b3, mode: int):
    """GS^(n) through the dense core: GS^(n) = G^(n) (kron of other rows)."""
    G = jnp.einsum("ri,rj,rk->ijk", b1, b2, b3)
    if mode == 0:
        return jnp.einsum("ijk,bj,bk->bi", G, a2, a3)
    if mode == 1:
        return jnp.einsum("ijk,bi,bk->bj", G, a1, a3)
    if mode == 2:
        return jnp.einsum("ijk,bi,bj->bk", G, a1, a2)
    raise ValueError(f"mode must be 0..2, got {mode}")
