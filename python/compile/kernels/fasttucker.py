"""L1: Pallas kernel for the cuFastTucker Thm-1/2 contraction.

This is the paper's Fig. 1 hot spot — the "two key steps" that build, for a
batch of sampled nonzeros, the per-mode coefficient vectors

    c_n[b, r]  = b_r^(n) . a_{i_n}^(n)            (warp-shuffle dot in CUDA)
    w_n[b, r]  = prod_{m != n} c_m[b, r]          (Thm 1/2 reduction)
    GS_n[b, :] = sum_r w_n[b, r] * b_r^(n)        (factor-update coefficient)
    xhat[b]    = a_n[b, :] . GS_n[b, :]           (prediction, mode-invariant)
    e[b]       = xhat[b] - x[b]                   (residual)

`w_n` doubles as the core-update coefficient: Q^(n),r = w_n[b,r] * a_n[b,:]
(Eq. 17), so downstream the core gradient is the matmul (e*w_n)^T @ a_n.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the Kruskal factors
`b_n` (R x J, a few KB) are the VMEM-resident operand — the analogue of the
paper keeping the core factors in shared memory — while the gathered factor
rows stream through the batch grid tile by tile. All contractions are
(TB,J)x(J,R) / (TB,R)x(R,J) matmuls, i.e. MXU-shaped.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax-CPU (tests)
and the Rust PJRT client (runtime) execute bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile. Must divide the batch size handed to contract().
DEFAULT_TILE = 128


def _contract_kernel(a1_ref, a2_ref, a3_ref, b1_ref, b2_ref, b3_ref, x_ref,
                     gs1_ref, gs2_ref, gs3_ref, w1_ref, w2_ref, w3_ref, e_ref):
    """One batch tile of the Thm-1/2 contraction (order 3).

    a*_ref: (TB, J) gathered factor rows.  b*_ref: (R, J) Kruskal factors
    (transposed layout, the paper's coalesced storage).  x_ref: (TB, 1).
    """
    a1 = a1_ref[...]
    a2 = a2_ref[...]
    a3 = a3_ref[...]
    b1 = b1_ref[...]
    b2 = b2_ref[...]
    b3 = b3_ref[...]

    # c_n[b, r] = <b_r^(n), a_n[b]> — the warp-shuffle dot products.
    c1 = jnp.dot(a1, b1.T)  # (TB, R)
    c2 = jnp.dot(a2, b2.T)
    c3 = jnp.dot(a3, b3.T)

    # w_n = prod over the other modes (Thm 1: Kronecker dot -> scalar products).
    w1 = c2 * c3
    w2 = c1 * c3
    w3 = c1 * c2

    # GS_n[b] = sum_r w_n[b, r] b_r^(n)  — (TB,R)x(R,J) matmul.
    gs1 = jnp.dot(w1, b1)
    gs2 = jnp.dot(w2, b2)
    gs3 = jnp.dot(w3, b3)

    # Prediction is mode-invariant; use mode 1.
    xhat = jnp.sum(a1 * gs1, axis=1, keepdims=True)  # (TB, 1)

    gs1_ref[...] = gs1
    gs2_ref[...] = gs2
    gs3_ref[...] = gs3
    w1_ref[...] = w1
    w2_ref[...] = w2
    w3_ref[...] = w3
    e_ref[...] = xhat - x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def contract(a1, a2, a3, b1, b2, b3, vals, *, tile: int = DEFAULT_TILE):
    """Run the Pallas contraction over a batch.

    Args:
      a1, a2, a3: (B, J) gathered factor rows per mode.
      b1, b2, b3: (R, J) Kruskal core factors (transposed layout).
      vals: (B,) observed nonzero values.
      tile: batch tile size; must divide B.

    Returns:
      (gs1, gs2, gs3, w1, w2, w3, e): per-sample coefficient vectors,
      core coefficients, and residuals e = xhat - vals, shapes
      (B,J)x3, (B,R)x3, (B,).
    """
    B, J = a1.shape
    R = b1.shape[0]
    tile = min(tile, B)  # small batches run as a single tile
    if B % tile != 0:
        raise ValueError(f"batch {B} not divisible by tile {tile}")
    x2d = vals.reshape(B, 1)

    grid = (B // tile,)
    row_spec = pl.BlockSpec((tile, J), lambda i: (i, 0))
    wcoef_spec = pl.BlockSpec((tile, R), lambda i: (i, 0))
    full_b = pl.BlockSpec((R, J), lambda i: (0, 0))
    val_spec = pl.BlockSpec((tile, 1), lambda i: (i, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((B, J), a1.dtype),
        jax.ShapeDtypeStruct((B, J), a1.dtype),
        jax.ShapeDtypeStruct((B, J), a1.dtype),
        jax.ShapeDtypeStruct((B, R), a1.dtype),
        jax.ShapeDtypeStruct((B, R), a1.dtype),
        jax.ShapeDtypeStruct((B, R), a1.dtype),
        jax.ShapeDtypeStruct((B, 1), a1.dtype),
    )
    out_specs = (row_spec, row_spec, row_spec,
                 wcoef_spec, wcoef_spec, wcoef_spec, val_spec)

    gs1, gs2, gs3, w1, w2, w3, e = pl.pallas_call(
        _contract_kernel,
        grid=grid,
        in_specs=(row_spec, row_spec, row_spec,
                  full_b, full_b, full_b, val_spec),
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=True,
    )(a1, a2, a3, b1, b2, b3, x2d)
    return gs1, gs2, gs3, w1, w2, w3, e.reshape(B)


def vmem_footprint_bytes(tile: int, J: int, R: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes held live per grid step (inputs+outputs+temps).

    Used by the perf notes in DESIGN.md: the paper's analogous number is the
    shared-memory footprint of the core factors per thread block.
    """
    rows = 3 * tile * J            # a1..a3
    bfac = 3 * R * J               # b1..b3 (resident)
    outs = 3 * tile * J + 3 * tile * R + tile
    temps = 3 * tile * R           # c1..c3
    return dtype_bytes * (rows + bfac + outs + temps + tile)
