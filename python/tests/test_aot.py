"""AOT path: lowering produces parseable HLO text with the right interface,
and the HLO evaluates to the same numbers as the jitted function (via the
jax CPU client compiling the same computation)."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_lower_variant_entries():
    entries = aot.lower_variant(8, 8, 256)
    names = [n for n, _, _ in entries]
    assert names == ["train_step", "factor_step", "predict"]
    n_outs = {n: k for n, _, k in entries}
    assert n_outs == {"train_step": 7, "factor_step": 4, "predict": 1}


def test_hlo_text_shape_signature():
    entries = aot.lower_variant(8, 8, 256)
    for name, lowered, _ in entries:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # Static shapes visible in the entry layout.
        assert "f32[256,8]" in text
        assert "f32[8,8]" in text


def test_manifest_written(tmp_path):
    import subprocess, sys
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--variants", "4:4:64"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    manifest = (out / "manifest.tsv").read_text().strip().split("\n")
    assert len(manifest) == 3
    for line in manifest:
        name, fname, J, R, B, n_out = line.split("\t")
        assert (out / fname).exists()
        assert (J, R, B) == ("4", "4", "64")


def test_hlo_text_roundtrips_numerics():
    """The emitted HLO text, recompiled via the jax CPU client, computes the
    same numbers as direct jit execution — the python-side mirror of the
    check the Rust runtime's integration test performs on its side of the
    bridge."""
    B, J, R = 64, 4, 4
    rng = np.random.default_rng(1)
    a = [np.asarray(rng.normal(size=(B, J)), np.float32) for _ in range(3)]
    b = [np.asarray(rng.normal(size=(R, J)), np.float32) for _ in range(3)]

    specs = [jax.ShapeDtypeStruct((B, J), jnp.float32)] * 3 + \
            [jax.ShapeDtypeStruct((R, J), jnp.float32)] * 3
    lowered = jax.jit(model.predict).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")

    backend = jax.devices("cpu")[0].client
    # jaxlib renamed Client.compile to compile_and_load in newer releases;
    # accept either so the test tracks the installed runtime.
    compile_fn = getattr(backend, "compile_and_load", None)
    if compile_fn is not None:
        exe = compile_fn(str(mlir_mod), [jax.devices("cpu")[0]])
    else:
        exe = backend.compile(str(mlir_mod))
    bufs = [backend.buffer_from_pyval(x) for x in a + b]
    (out,) = exe.execute(bufs)
    got = np.asarray(out[0] if isinstance(out, (list, tuple)) else out)

    want = np.asarray(model.predict(*[jnp.asarray(x) for x in a],
                                    *[jnp.asarray(x) for x in b]))
    np.testing.assert_allclose(got.reshape(B), want, rtol=1e-5, atol=1e-6)
