"""L2 model-step correctness: SGD semantics, gradient identity vs jax.grad,
and convergence of the step functions on a tiny planted problem."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def make_state(rng, B, J, R, scale=0.3):
    a = [jnp.asarray(rng.normal(scale=scale, size=(B, J)), jnp.float32)
         for _ in range(3)]
    b = [jnp.asarray(rng.normal(scale=scale, size=(R, J)), jnp.float32)
         for _ in range(3)]
    vals = jnp.asarray(rng.normal(size=(B,)), jnp.float32)
    return a, b, vals


class TestFactorStepGradient:
    """Eq. 13's hand-built gradient must equal autodiff of the loss."""

    def test_matches_jax_grad(self):
        rng = np.random.default_rng(0)
        B, J, R = 64, 8, 4
        a, b, vals = make_state(rng, B, J, R)
        lr, lam = jnp.float32(0.05), jnp.float32(0.01)

        def loss(a1, a2, a3):
            xh = model.predict(a1, a2, a3, *b)
            # Per-sample loss (x - xhat)^2 / ... paper uses unscaled squared
            # error per sample; Eq.13's gradient is e*GS with e = xhat - x,
            # matching d/da of 0.5*(xhat - x)^2 + 0.5*lam*|a|^2.
            return 0.5 * jnp.sum((xh - vals) ** 2) + 0.5 * lam * (
                jnp.sum(a1**2) + jnp.sum(a2**2) + jnp.sum(a3**2))

        g1, g2, g3 = jax.grad(loss, argnums=(0, 1, 2))(*a)
        new_a1, new_a2, new_a3, e = model.factor_step(*a, *b, vals, lr, lam)
        np.testing.assert_allclose(new_a1, a[0] - lr * g1, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(new_a2, a[1] - lr * g2, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(new_a3, a[2] - lr * g3, rtol=1e-3, atol=1e-4)

    def test_core_grad_matches_jax_grad(self):
        rng = np.random.default_rng(1)
        B, J, R = 64, 8, 4
        a, b, vals = make_state(rng, B, J, R)

        def data_loss(b1, b2, b3):
            xh = model.predict(*a, b1, b2, b3)
            return 0.5 * jnp.sum((xh - vals) ** 2)

        g1, g2, g3 = jax.grad(data_loss, argnums=(0, 1, 2))(*b)
        _, _, _, gb1, gb2, gb3, _ = model.train_step(
            *a, *b, vals, jnp.float32(0.0), jnp.float32(0.0))
        np.testing.assert_allclose(gb1, g1, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gb2, g2, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gb3, g3, rtol=1e-3, atol=1e-4)


class TestTrainStepSemantics:
    def test_zero_lr_is_identity(self):
        rng = np.random.default_rng(2)
        a, b, vals = make_state(rng, 64, 8, 4)
        na1, na2, na3, *_ = model.train_step(
            *a, *b, vals, jnp.float32(0.0), jnp.float32(0.0))
        np.testing.assert_array_equal(na1, a[0])
        np.testing.assert_array_equal(na2, a[1])
        np.testing.assert_array_equal(na3, a[2])

    def test_factor_step_equals_train_step_factor_part(self):
        rng = np.random.default_rng(3)
        a, b, vals = make_state(rng, 64, 8, 4)
        lr, lam = jnp.float32(0.01), jnp.float32(0.001)
        f = model.factor_step(*a, *b, vals, lr, lam)
        t = model.train_step(*a, *b, vals, lr, lam)
        for i in range(3):
            np.testing.assert_allclose(f[i], t[i], rtol=1e-6, atol=1e-6)

    def test_residual_consistent_with_predict(self):
        rng = np.random.default_rng(4)
        a, b, vals = make_state(rng, 64, 8, 4)
        *_, e = model.train_step(*a, *b, vals, jnp.float32(0.0), jnp.float32(0.0))
        xh = model.predict(*a, *b)
        np.testing.assert_allclose(e, xh - vals, rtol=1e-4, atol=1e-5)


class TestConvergence:
    def test_sgd_descends_on_planted_problem(self):
        """Repeated train_step on a planted rank-R problem must shrink RMSE."""
        rng = np.random.default_rng(5)
        B, J, R = 256, 8, 4
        a, b, _ = make_state(rng, B, J, R, scale=0.4)
        # Plant a ground truth and synthesize values from it.
        at, bt, _ = make_state(rng, B, J, R, scale=0.5)
        vals = model.predict(*at, *bt)

        lr, lam = jnp.float32(0.02), jnp.float32(1e-4)
        a = list(a)
        b = list(b)
        rmse0 = float(jnp.sqrt(jnp.mean((model.predict(*a, *b) - vals) ** 2)))
        for step in range(60):
            na1, na2, na3, gb1, gb2, gb3, e = model.train_step(
                *a, *b, vals, lr, lam)
            a = [na1, na2, na3]
            b = [b[0] - lr * (gb1 / B + lam * b[0]),
                 b[1] - lr * (gb2 / B + lam * b[1]),
                 b[2] - lr * (gb3 / B + lam * b[2])]
        rmse1 = float(jnp.sqrt(jnp.mean((model.predict(*a, *b) - vals) ** 2)))
        assert rmse1 < 0.7 * rmse0, (rmse0, rmse1)


class TestPredict:
    def test_against_dense_core(self):
        rng = np.random.default_rng(6)
        a, b, _ = make_state(rng, 64, 8, 8)
        np.testing.assert_allclose(
            model.predict(*a, *b), ref.predict_naive(*a, *b),
            rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("R", [1, 2, 4])
    def test_rank_additivity(self, R):
        """Kruskal prediction is additive over rank-1 terms."""
        rng = np.random.default_rng(7 + R)
        a, b, _ = make_state(rng, 32, 8, R)
        total = model.predict(*a, *b)
        acc = jnp.zeros(32, jnp.float32)
        for r in range(R):
            br = [x[r:r + 1, :] for x in b]
            acc = acc + model.predict(*a, *br)
        np.testing.assert_allclose(total, acc, rtol=1e-3, atol=1e-4)
