"""L1 kernel correctness: Pallas contraction vs pure-jnp oracle.

The hypothesis sweep varies batch/J/R/tile shapes and value scales; every
case asserts allclose against ref.contract_ref, and the Thm-1/2 linear path
is checked against the exponential dense-core path (the identity the paper's
theorems claim).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from compile.kernels import fasttucker as ker
from compile.kernels import ref


def make_case(rng, B, J, R, scale=1.0):
    a = [jnp.asarray(rng.normal(scale=scale, size=(B, J)), jnp.float32)
         for _ in range(3)]
    b = [jnp.asarray(rng.normal(scale=scale, size=(R, J)), jnp.float32)
         for _ in range(3)]
    vals = jnp.asarray(rng.normal(size=(B,)), jnp.float32)
    return a, b, vals


def assert_contract_matches(a, b, vals, tile):
    out_k = ker.contract(*a, *b, vals, tile=tile)
    out_r = ref.contract_ref(*a, *b, vals)
    names = ["gs1", "gs2", "gs3", "w1", "w2", "w3", "e"]
    for name, k, r in zip(names, out_k, out_r):
        np.testing.assert_allclose(k, r, rtol=1e-4, atol=1e-4, err_msg=name)


class TestContractBasic:
    def test_small(self):
        rng = np.random.default_rng(0)
        a, b, vals = make_case(rng, 128, 8, 8)
        assert_contract_matches(a, b, vals, tile=128)

    def test_multi_tile(self):
        rng = np.random.default_rng(1)
        a, b, vals = make_case(rng, 512, 16, 8)
        assert_contract_matches(a, b, vals, tile=128)

    def test_tile_equals_batch(self):
        rng = np.random.default_rng(2)
        a, b, vals = make_case(rng, 64, 4, 4)
        assert_contract_matches(a, b, vals, tile=64)

    def test_rectangular_j_ne_r(self):
        rng = np.random.default_rng(3)
        a, b, vals = make_case(rng, 128, 32, 4)
        assert_contract_matches(a, b, vals, tile=64)

    def test_rank_one_core(self):
        rng = np.random.default_rng(4)
        a, b, vals = make_case(rng, 128, 8, 1)
        assert_contract_matches(a, b, vals, tile=128)

    def test_bad_tile_raises(self):
        rng = np.random.default_rng(5)
        a, b, vals = make_case(rng, 100, 8, 8)
        with pytest.raises(ValueError):
            ker.contract(*a, *b, vals, tile=64)

    def test_zero_inputs(self):
        B, J, R = 128, 8, 8
        a = [jnp.zeros((B, J), jnp.float32)] * 3
        b = [jnp.zeros((R, J), jnp.float32)] * 3
        vals = jnp.ones((B,), jnp.float32)
        *_, e = ker.contract(*a, *b, vals)
        np.testing.assert_allclose(e, -vals)

    def test_residual_zero_when_exact(self):
        # If vals == xhat the residual must be identically ~0.
        rng = np.random.default_rng(6)
        a, b, _ = make_case(rng, 128, 8, 8)
        xhat = ref.predict_naive(*a, *b)
        *_, e = ker.contract(*a, *b, xhat)
        np.testing.assert_allclose(e, np.zeros(128), atol=1e-3)


class TestTheoremIdentity:
    """Thm 1/2: linear-cost contraction == exponential dense-core contraction."""

    def test_prediction_identity(self):
        rng = np.random.default_rng(7)
        a, b, vals = make_case(rng, 64, 8, 8)
        gs1, *_, e = ker.contract(*a, *b, vals)
        xhat_naive = ref.predict_naive(*a, *b)
        np.testing.assert_allclose(e + vals, xhat_naive, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("mode", [0, 1, 2])
    def test_gs_identity(self, mode):
        rng = np.random.default_rng(8 + mode)
        a, b, vals = make_case(rng, 64, 8, 8)
        out = ker.contract(*a, *b, vals)
        gs = out[mode]
        gs_naive = ref.gs_naive(*a, *b, mode)
        np.testing.assert_allclose(gs, gs_naive, rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([32, 64, 128]),
    J=st.sampled_from([4, 8, 16, 32]),
    R=st.sampled_from([1, 4, 8, 16]),
    scale=st.sampled_from([0.1, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_contract_hypothesis(b_tiles, tile, J, R, scale, seed):
    rng = np.random.default_rng(seed)
    a, b, vals = make_case(rng, b_tiles * tile, J, R, scale=scale)
    assert_contract_matches(a, b, vals, tile=tile)


def test_vmem_footprint_sane():
    # Default variant must fit comfortably in a 16 MB VMEM budget.
    fp = ker.vmem_footprint_bytes(tile=128, J=16, R=16)
    assert fp < 16 * 1024 * 1024
    assert fp > 0
