"""Offline stand-in for the small slice of `hypothesis` the tests use.

The CI image is fully offline; when the real `hypothesis` package is
available it is used unchanged, otherwise this module provides a
deterministic mini-implementation of `given` / `settings` /
`strategies.{integers,sampled_from}` that sweeps a fixed number of seeded
pseudo-random examples. Shrinking and the database are out of scope — a
failing case prints its drawn arguments so it can be replayed by hand.
"""

import random

try:  # pragma: no cover - prefer the real thing when present
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on the offline image
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 20
    _BASE_SEED = 0xFA57_7C4E

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

    st = strategies

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def wrap(fn):
            fn._compat_max_examples = max_examples
            return fn

        return wrap

    def given(**strategy_kwargs):
        def wrap(fn):
            def runner(*args, **kwargs):
                # `@settings` may sit above `@given`, so the attribute
                # lands on the runner itself; read it there at call time.
                n = getattr(runner, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
                for case in range(n):
                    rng = random.Random(_BASE_SEED + case * 0x9E3779B9)
                    drawn = {
                        name: strat.example(rng)
                        for name, strat in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **{**kwargs, **drawn})
                    except BaseException:
                        print(f"falsifying example (case {case}): {drawn}")
                        raise

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return wrap
