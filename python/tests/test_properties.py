"""Hypothesis-driven property tests for the L1/L2 math (beyond the direct
kernel-vs-ref sweep in test_kernel.py)."""

import numpy as np
import jax
import jax.numpy as jnp
from tests._hypothesis_compat import given, settings, strategies as st

from compile import model
from compile.kernels import fasttucker as ker
from compile.kernels import ref


def make(rng, B, J, R, scale=0.5):
    a = [jnp.asarray(rng.normal(scale=scale, size=(B, J)), jnp.float32)
         for _ in range(3)]
    b = [jnp.asarray(rng.normal(scale=scale, size=(R, J)), jnp.float32)
         for _ in range(3)]
    vals = jnp.asarray(rng.normal(size=(B,)), jnp.float32)
    return a, b, vals


@settings(max_examples=20, deadline=None)
@given(
    J=st.sampled_from([2, 4, 8, 16]),
    R=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prediction_is_multilinear_in_each_factor(J, R, seed):
    """x̂ is linear in each a_n separately: predict(α·a1) == α·predict(a1)."""
    rng = np.random.default_rng(seed)
    a, b, _ = make(rng, 32, J, R)
    base = model.predict(*a, *b)
    alpha = 2.5
    scaled = model.predict(alpha * a[0], a[1], a[2], *b)
    np.testing.assert_allclose(scaled, alpha * base, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    J=st.sampled_from([4, 8]),
    R=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_residual_invariant_to_mode_used(J, R, seed):
    """The kernel predicts through mode 0's GS; the identity x̂ = a_n·GS_n
    must hold for every mode."""
    rng = np.random.default_rng(seed)
    a, b, vals = make(rng, 32, J, R)
    gs1, gs2, gs3, *_rest, e = ker.contract(*a, *b, vals)
    x1 = jnp.sum(a[0] * gs1, axis=1)
    x2 = jnp.sum(a[1] * gs2, axis=1)
    x3 = jnp.sum(a[2] * gs3, axis=1)
    np.testing.assert_allclose(x1, x2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(x1, x3, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(e, x1 - vals, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    J=st.sampled_from([4, 8]),
    R=st.sampled_from([2, 4]),
    lr=st.sampled_from([1e-4, 1e-3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_one_step_reduces_batch_loss(J, R, lr, seed):
    """A small factor_step strictly decreases the batch squared error."""
    rng = np.random.default_rng(seed)
    a, b, vals = make(rng, 64, J, R)
    e0 = model.predict(*a, *b) - vals
    loss0 = float(jnp.sum(e0**2))
    na = model.factor_step(*a, *b, vals, jnp.float32(lr), jnp.float32(0.0))[:3]
    e1 = model.predict(*na, *b) - vals
    loss1 = float(jnp.sum(e1**2))
    assert loss1 <= loss0 * (1.0 + 1e-5), (loss0, loss1)


@settings(max_examples=15, deadline=None)
@given(
    J=st.sampled_from([4, 8]),
    R=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_core_grad_zero_at_zero_residual(J, R, seed):
    """When vals == x̂ the core gradients vanish."""
    rng = np.random.default_rng(seed)
    a, b, _ = make(rng, 32, J, R)
    vals = model.predict(*a, *b)
    _, _, _, gb1, gb2, gb3, e = model.train_step(
        *a, *b, vals, jnp.float32(0.0), jnp.float32(0.0))
    np.testing.assert_allclose(e, np.zeros(32), atol=2e-3)
    for gb in (gb1, gb2, gb3):
        assert float(jnp.max(jnp.abs(gb))) < 5e-2


def test_factor_step_grad_composes_with_jax():
    """The L2 graph (including the Pallas kernel output path) is traceable
    under jit with donated-style reuse — guards against kernel opacity in
    the lowering used by aot.py."""
    rng = np.random.default_rng(0)
    a, b, vals = make(rng, 32, 4, 2)
    jitted = jax.jit(model.factor_step)
    outs = jitted(*a, *b, vals, jnp.float32(1e-3), jnp.float32(0.0))
    assert outs[0].shape == (32, 4)
    assert all(bool(jnp.all(jnp.isfinite(o))) for o in outs)
