//! End-to-end validation driver: exercises the FULL three-layer stack on a
//! realistic workload, proving all layers compose —
//!
//!   Rust coordinator (data gen, sampling, epoch loop, eval)
//!     → PJRT runtime (AOT HLO artifacts from `make artifacts`)
//!       → the L2 JAX `train_step` graph
//!         → the L1 Pallas Thm-1/2 contraction kernel
//!
//! on a netflix-shaped synthetic tensor (~500k nonzeros, J=R=16), logging
//! the RMSE/MAE curve and asserting the model beats the value-variance
//! baseline. Falls back to the native engine (same math, pure Rust) when
//! artifacts are missing, and reports which path ran.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used the default scale.

use fasttucker::util::error::Result;

use fasttucker::algo::SgdHyper;
use fasttucker::config::{AlgoKind, EngineKind, TrainConfig};
use fasttucker::coordinator::{PjrtEngine, Trainer};
use fasttucker::data::{split::train_test_split, Dataset};
use fasttucker::util::Rng;

fn main() -> Result<()> {
    let scale = std::env::var("E2E_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let mut rng = Rng::new(2026);
    let dataset = Dataset::by_name("netflix-like", scale)?;
    let tensor = dataset.build(&mut rng)?;
    let (raw_train, raw_test) = train_test_split(&tensor, 0.1, &mut rng);
    // Standard recommender preprocessing: train on mean-centered ratings
    // (the multilinear model has no bias term), add the mean back at
    // serving time.
    let mean = raw_train.mean_value();
    let train = raw_train.with_shifted_values(-mean);
    let test = raw_test.with_shifted_values(-mean);
    println!(
        "netflix-like (scale {scale}): dims={:?} nnz={} train={} test={} mean={mean:.3}",
        tensor.dims(),
        tensor.nnz(),
        train.nnz(),
        test.nnz()
    );

    let mut hyper = SgdHyper::default();
    hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.02, 0.02);
    hyper.lr_core = fasttucker::sched::LrSchedule::new(0.01, 0.05);
    hyper.lambda_factor = 5e-3;
    hyper.lambda_core = 5e-3;

    let artifacts = std::path::Path::new("artifacts");
    let (engine_desc, mut trainer, mut model) =
        match PjrtEngine::new(artifacts, 16, 16, hyper) {
            Ok(engine) => {
                let desc = format!(
                    "pjrt ({}, batch {})",
                    engine.platform(),
                    engine.batch()
                );
                let model = fasttucker::model::TuckerModel::init_kruskal(
                    &mut rng,
                    tensor.dims(),
                    16,
                    16,
                );
                let trainer = Trainer {
                    engine: fasttucker::coordinator::Engine::Pjrt(engine),
                    opts: Default::default(),
                };
                (desc, trainer, model)
            }
            Err(e) => {
                println!("PJRT path unavailable ({e}); falling back to native engine");
                let mut cfg = TrainConfig::default();
                cfg.algo = AlgoKind::FastTucker;
                cfg.engine = EngineKind::Native;
                cfg.j = 16;
                cfg.r_core = 16;
                cfg.hyper = hyper;
                let dims = tensor.dims().to_vec();
                let (t, m) = Trainer::from_config(&cfg, &dims, &mut rng)?;
                ("native".to_string(), t, m)
            }
        };

    trainer.opts.epochs = 20;
    trainer.opts.verbose = false;
    println!("engine: {engine_desc}");
    let report = trainer.train(&mut model, &train, &test, &mut rng)?;

    println!("epoch  rmse      mae       cum_train_secs");
    for rec in &report.history {
        println!(
            "{:>5}  {:.5}  {:.5}  {:>8.2}",
            rec.epoch, rec.rmse, rec.mae, rec.train_secs
        );
    }

    // Baseline: predicting the mean of the training values.
    let mean = train.mean_value();
    let var = train
        .values()
        .iter()
        .map(|&v| ((v - mean) as f64).powi(2))
        .sum::<f64>()
        / train.nnz() as f64;
    let baseline_rmse = var.sqrt();
    let final_rmse = report.final_rmse();
    println!(
        "\nfinal rmse {final_rmse:.4} vs mean-predictor baseline {baseline_rmse:.4} \
         ({} samples/sec)",
        (report.total_stats.samples as f64 / report.total_train_secs()).round()
    );
    assert!(
        final_rmse < 0.9 * baseline_rmse,
        "end-to-end training failed to beat the mean predictor"
    );
    println!("END-TO-END OK ({engine_desc})");
    Ok(())
}
