//! Recommender-system scenario (the paper's motivating workload): a
//! (user × item × context) ratings tensor, decomposed with FastTucker,
//! then queried for top-k item recommendations per user.
//!
//! ```bash
//! cargo run --release --example recommender
//! ```

use fasttucker::util::error::Result;

use fasttucker::algo::{Decomposer, FastTucker};
use fasttucker::data::split::train_test_split;
use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::kruskal::reconstruct::rmse_mae;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

fn main() -> Result<()> {
    let mut rng = Rng::new(7);
    // Users × movies × time-of-week context, ratings 1..5.
    let spec = PlantedSpec {
        dims: vec![500, 300, 7],
        nnz: 80_000,
        j: 8,
        r_core: 8,
        noise: 0.3,
        clamp: Some((1.0, 5.0)),
    };
    let planted = planted_tucker(&mut rng, &spec);
    let (train, test) = train_test_split(&planted.tensor, 0.1, &mut rng);
    println!(
        "ratings tensor: {} users × {} movies × {} contexts, {} ratings",
        spec.dims[0],
        spec.dims[1],
        spec.dims[2],
        planted.tensor.nnz()
    );

    let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
    let mut algo = FastTucker::with_defaults();
    algo.config.hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.02, 0.05);
    algo.config.hyper.lr_core = fasttucker::sched::LrSchedule::new(0.01, 0.1);
    for epoch in 0..20 {
        algo.train_epoch(&mut model, &train, epoch, &mut rng).unwrap();
    }
    let (train_rmse, _) = rmse_mae(&model, &train);
    let (test_rmse, test_mae) = rmse_mae(&model, &test);
    println!("train rmse={train_rmse:.4}; held-out rmse={test_rmse:.4} mae={test_mae:.4}");

    // Top-5 recommendations for a few users in context 0 (e.g. weekday
    // evening), scored by predicted rating.
    for user in [0u32, 100, 250] {
        let mut scored: Vec<(u32, f32)> = (0..spec.dims[1] as u32)
            .map(|movie| (movie, model.predict(&[user, movie, 0])))
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        let top: Vec<String> = scored
            .iter()
            .take(5)
            .map(|(m, s)| format!("movie{m}({s:.2})"))
            .collect();
        println!("user {user}: {}", top.join(" "));
    }

    // Sanity: held-out error should approach the injected noise floor.
    assert!(
        test_rmse < 3.0 * spec.noise as f64 + 0.2,
        "rmse {test_rmse} far above noise floor {}",
        spec.noise
    );
    println!("ok: held-out RMSE is near the noise floor");
    Ok(())
}
