//! Core-compression demo (the paper's central memory claim): the Kruskal
//! core stores `Σ_n R·J_n` parameters versus the dense core's `Π_n J_n`,
//! with matching accuracy when the core has low-rank structure
//! (`R_core = J`, paper Fig. 3's conclusion).
//!
//! ```bash
//! cargo run --release --example core_compression
//! ```

use fasttucker::util::error::Result;

use fasttucker::algo::{CuTucker, Decomposer, FastTucker};
use fasttucker::data::split::train_test_split;
use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::kruskal::reconstruct::rmse_mae;
use fasttucker::kruskal::KruskalCore;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

fn main() -> Result<()> {
    println!("core storage, dense vs Kruskal (J per mode, R_core = J):");
    println!("order  J   dense(params)  kruskal(params)  compression");
    for (order, j) in [(3usize, 8usize), (3, 16), (3, 32), (4, 16), (5, 8), (10, 4)] {
        let kr = KruskalCore::zeros(order, j, j);
        let dense: u128 = (j as u128).pow(order as u32);
        println!(
            "{order:>5}  {j:<3} {dense:>13}  {:>15}  {:>10.4}",
            kr.param_count(),
            kr.param_count() as f64 / dense as f64
        );
    }

    // Accuracy parity at R_core = J on a planted problem.
    let spec = PlantedSpec {
        dims: vec![80, 80, 80],
        nnz: 60_000,
        j: 8,
        r_core: 8,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(3);
    let p = planted_tucker(&mut rng, &spec);
    let (train, test) = train_test_split(&p.tensor, 0.1, &mut rng);

    let mut kmodel = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
    let mut kalgo = FastTucker::with_defaults();
    kalgo.config.hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.008, 0.05);
    kalgo.config.hyper.lr_core = fasttucker::sched::LrSchedule::new(0.004, 0.1);
    kalgo.config.hyper.lambda_factor = 1e-3;
    kalgo.config.hyper.lambda_core = 1e-3;

    let mut dmodel = TuckerModel::init_dense(&mut rng, &spec.dims, 8);
    let mut dalgo = CuTucker::with_defaults();
    dalgo.hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.008, 0.05);
    dalgo.hyper.lr_core = fasttucker::sched::LrSchedule::new(0.004, 0.1);
    dalgo.hyper.lambda_factor = 1e-3;
    dalgo.hyper.lambda_core = 1e-3;

    for epoch in 0..15 {
        kalgo.train_epoch(&mut kmodel, &train, epoch, &mut rng).unwrap();
        dalgo.train_epoch(&mut dmodel, &train, epoch, &mut rng).unwrap();
    }
    let (krmse, kmae) = rmse_mae(&kmodel, &test);
    let (drmse, dmae) = rmse_mae(&dmodel, &test);
    println!("\nafter 15 epochs on a planted rank-8 tensor (noise 0.1):");
    println!("  cuFastTucker (Kruskal core): rmse={krmse:.4} mae={kmae:.4}");
    println!("  cuTucker     (dense core):   rmse={drmse:.4} mae={dmae:.4}");
    println!(
        "  core params: kruskal {} vs dense {}",
        3 * 8 * 8,
        8usize.pow(3)
    );
    assert!(
        krmse < drmse * 1.25,
        "Kruskal-core accuracy should track the dense core at R_core = J"
    );
    println!("ok: compression without accuracy loss");
    Ok(())
}
