//! Quickstart: decompose a small synthetic HOHDST tensor with FastTucker.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fasttucker::util::error::Result;

use fasttucker::config::{AlgoKind, TrainConfig};
use fasttucker::coordinator::Trainer;
use fasttucker::data::{split::train_test_split, Dataset};
use fasttucker::util::Rng;

fn main() -> Result<()> {
    // 1. Data: a planted low-rank tensor from the registry.
    let mut rng = Rng::new(42);
    let tensor = Dataset::by_name("tiny", 1.0)?.build(&mut rng)?;
    let (train, test) = train_test_split(&tensor, 0.1, &mut rng);
    println!(
        "tensor: dims={:?} nnz={} (train {} / test {})",
        tensor.dims(),
        tensor.nnz(),
        train.nnz(),
        test.nnz()
    );

    // 2. Config: FastTucker, rank J=4, Kruskal core rank R=4.
    let mut cfg = TrainConfig::default();
    cfg.algo = AlgoKind::FastTucker;
    cfg.j = 4;
    cfg.r_core = 4;
    cfg.epochs = 40;
    // NOMAD-style decaying rates (the paper's Table 7 style).
    cfg.hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.015, 0.02);
    cfg.hyper.lr_core = fasttucker::sched::LrSchedule::new(0.008, 0.05);
    cfg.hyper.lambda_factor = 1e-3;
    cfg.hyper.lambda_core = 1e-3;

    // 3. Train.
    let dims = tensor.dims().to_vec();
    let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng)?;
    trainer.opts.verbose = false;
    let report = trainer.train(&mut model, &train, &test, &mut rng)?;

    println!("epoch  rmse      mae");
    for rec in &report.history {
        println!("{:>5}  {:.5}  {:.5}", rec.epoch, rec.rmse, rec.mae);
    }
    println!(
        "\ncompression: model holds {} params for a {} -element tensor",
        model.param_count(),
        tensor.dims().iter().product::<usize>()
    );

    // 4. Predict an individual entry.
    let coords = tensor.index(0);
    println!(
        "x{:?} = {:.3} (observed {:.3})",
        coords,
        model.predict(coords),
        tensor.value(0)
    );
    Ok(())
}
