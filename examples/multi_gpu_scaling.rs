//! Multi-device scaling demo (paper Section 5.3, Figs. 7b/7c): train the
//! same tensor with 1, 2, and 4 simulated devices and report per-epoch
//! time, speedup, and the communication volume the partition scheme costs.
//!
//! ```bash
//! cargo run --release --example multi_gpu_scaling
//! ```

use fasttucker::util::error::Result;

use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::kruskal::reconstruct::rmse;
use fasttucker::model::TuckerModel;
use fasttucker::parallel::{LatinSchedule, ParallelFastTucker, ParallelOptions};
use fasttucker::util::Rng;

fn main() -> Result<()> {
    let spec = PlantedSpec {
        dims: vec![400, 400, 400],
        nnz: 1_000_000,
        j: 8,
        r_core: 8,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(11);
    println!("generating {} nonzeros...", spec.nnz);
    let p = planted_tucker(&mut rng, &spec);

    // Show the conflict-free schedule for 2 devices.
    let s = LatinSchedule::new(2, 3);
    println!("\nschedule for M=2, N=3 ({} rounds):", s.rounds());
    for round in 0..s.rounds() {
        let a = s.round_assignments(round);
        println!("  round {round}: dev0->{:?} dev1->{:?}", a[0], a[1]);
    }

    // On single-core hosts the engine reports discrete-event device time
    // (max worker time per round) — see DESIGN.md §Hardware-Adaptation.
    println!("\ndevices  epoch_secs  speedup  rmse_after3  comm_MB");
    let mut baseline = None;
    for workers in [1usize, 2, 4] {
        let mut rng = Rng::new(13);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = workers;
        opts.hyper.lr_factor = fasttucker::sched::LrSchedule::new(0.01, 0.05);
        opts.hyper.lr_core = fasttucker::sched::LrSchedule::new(0.005, 0.1);
        opts.hyper.lambda_factor = 1e-3;
        opts.hyper.lambda_core = 1e-3;
        let mut engine = ParallelFastTucker::new(opts);
        let mut secs = 0.0;
        for epoch in 0..3 {
            let st = engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
            secs += st.total_secs();
        }
        let secs = secs / 3.0;
        let speedup = baseline.map(|b: f64| b / secs).unwrap_or(1.0);
        if baseline.is_none() {
            baseline = Some(secs);
        }
        println!(
            "{workers:>7}  {secs:>10.3}  {speedup:>7.2}  {:>11.4}  {:>7.2}",
            rmse(&model, &p.tensor),
            engine.ledger.total_bytes() as f64 / 1e6
        );
    }
    Ok(())
}
