//! Cross-module property tests (the crate-level invariants; module-local
//! properties live next to their modules).

use fasttucker::algo::fasttucker::{build_strided, contract_staged, CoreLayout, Workspace};
use fasttucker::algo::Decomposer;
use fasttucker::data::synth;
use fasttucker::kernel::{batched, scalar, BatchPlan, BatchWorkspace, DispatchPool, Lanes};
use fasttucker::kruskal::KruskalCore;
use fasttucker::model::factors::FactorMatrices;
use fasttucker::model::{CoreRepr, TuckerModel};
use fasttucker::parallel::shared::{SharedFactors, SharedRowAccess};
use fasttucker::parallel::{BlockPartition, LatinSchedule};
use fasttucker::util::propcheck::forall;

#[test]
fn prop_thm12_linear_equals_exponential_prediction() {
    // Theorem 1/2 at the whole-model level, arbitrary order and ranks:
    // the linear-cost Kruskal prediction equals the dense-core prediction.
    forall("Thm 1/2 model-level identity", 32, |rng| {
        let order = 2 + rng.gen_range(4); // 2..=5
        let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(8)).collect();
        let j = 1 + rng.gen_range(5);
        let r = 1 + rng.gen_range(5);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let kcore = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dense = kcore.to_dense();
        for _ in 0..5 {
            let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
            let lin = model.predict(&coords);
            let exp = dense.predict(&model.factors, &coords);
            let tol = 1e-3 * (1.0 + exp.abs());
            assert!((lin - exp).abs() < tol, "{lin} vs {exp} (order {order})");
        }
    });
}

#[test]
fn prop_contract_staged_layouts_agree() {
    // Packed and Strided layouts compute identical contractions for any
    // shape (order 2..5).
    forall("layouts agree", 32, |rng| {
        let order = 2 + rng.gen_range(4);
        let j = 1 + rng.gen_range(12);
        let r = 1 + rng.gen_range(12);
        let core = KruskalCore::random(rng, order, j, r, 0.7);
        let strided = build_strided(&core);
        let mut ws_p = Workspace::new(order, r, j);
        let mut ws_s = Workspace::new(order, r, j);
        for n in 0..order {
            let row: Vec<f32> = (0..j).map(|_| rng.normal()).collect();
            ws_p.stage_row(n, &row);
            ws_s.stage_row(n, &row);
        }
        let x = rng.normal();
        let ep = contract_staged(&mut ws_p, &core, &[], CoreLayout::Packed, x);
        let es = contract_staged(&mut ws_s, &core, &strided, CoreLayout::Strided, x);
        assert!(
            (ep - es).abs() < 1e-4 * (1.0 + ep.abs()),
            "packed {ep} vs strided {es}"
        );
        for n in 0..order {
            for (a, b) in ws_p.gs_row(n).iter().zip(ws_s.gs_row(n).iter()) {
                assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()));
            }
        }
    });
}

#[test]
fn prop_partition_and_schedule_compose() {
    // Over a full schedule cycle, the blocks processed by all workers
    // cover every nonzero exactly once, and within every round no two
    // workers' blocks share a factor row in any mode.
    forall("partition x schedule composition", 16, |rng| {
        let order = 2 + rng.gen_range(3);
        let m = 1 + rng.gen_range(4);
        let dims: Vec<usize> = (0..order).map(|_| m + rng.gen_range(20)).collect();
        let t = synth::random_uniform(rng, &dims, 400, 1.0, 5.0);
        let part = BlockPartition::build(&t, m);
        let sched = LatinSchedule::new(m, order);

        // The independent level-1 auditor must agree with the hand-rolled
        // checks below on every geometry (ISSUE 6 tentpole).
        let rounds: Vec<Vec<Vec<usize>>> =
            (0..sched.rounds()).map(|r| sched.round_assignments(r)).collect();
        let report = fasttucker::analysis::audit_latin(&dims, m, &rounds);
        assert!(report.ok(), "auditor rejected a real schedule: {report}");
        assert!(report.checks > 0);

        let mut seen = vec![false; t.nnz()];
        for round in 0..sched.rounds() {
            let assigns = sched.round_assignments(round);
            // Per-mode row ownership must be disjoint across workers.
            for n in 0..order {
                let mut ranges: Vec<(usize, usize)> = assigns
                    .iter()
                    .map(|a| BlockPartition::chunk_range(a[n], dims[n], m))
                    .collect();
                ranges.sort_unstable();
                for w in ranges.windows(2) {
                    assert!(w[0].1 <= w[1].0, "overlapping chunks in mode {n}");
                }
            }
            for a in &assigns {
                for &k in part.block(a) {
                    assert!(!seen[k as usize], "nonzero visited twice");
                    seen[k as usize] = true;
                }
            }
        }
        assert!(seen.iter().all(|&x| x), "nonzero never visited");
    });
}

#[test]
fn prop_planted_rmse_zero_at_truth() {
    // The generator and the model's predictor are mutually consistent for
    // any shape: evaluating the truth model on noiseless data gives ~0.
    forall("planted truth has zero error", 16, |rng| {
        let order = 2 + rng.gen_range(3);
        let dims: Vec<usize> = (0..order).map(|_| 5 + rng.gen_range(15)).collect();
        let spec = synth::PlantedSpec {
            dims: dims.clone(),
            nnz: 100,
            j: 1 + rng.gen_range(4),
            r_core: 1 + rng.gen_range(4),
            noise: 0.0,
            clamp: None,
        };
        let p = synth::planted_tucker(rng, &spec);
        let model = TuckerModel {
            factors: p.truth_factors.clone(),
            core: CoreRepr::Kruskal(p.truth_core.clone()),
        };
        let r = fasttucker::kruskal::reconstruct::rmse(&model, &p.tensor);
        assert!(r < 1e-3, "rmse {r}");
    });
}

#[test]
fn prop_factor_gradient_descends_loss() {
    // One FastTucker step on a single sample strictly decreases that
    // sample's squared error (for small enough lr and no regularizer) —
    // the definition of a correct gradient.
    forall("per-sample step descends", 32, |rng| {
        let order = 2 + rng.gen_range(3);
        let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(8)).collect();
        let j = 1 + rng.gen_range(6);
        let r = 1 + rng.gen_range(4);
        let mut model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
        let x = rng.normal() * 2.0;

        let e_before = model.predict(&coords) - x;
        if e_before.abs() < 1e-4 {
            return; // already at optimum; nothing to check
        }
        // One manual SGD step via the shared contraction.
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let mut ws = Workspace::new(order, r, j);
        for n in 0..order {
            ws.stage_row(n, model.factors.row(n, coords[n] as usize));
        }
        let e = contract_staged(&mut ws, &core, &[], CoreLayout::Packed, x);
        let lr = 1e-3;
        for n in 0..order {
            let gs: Vec<f32> = ws.gs_row(n).to_vec();
            let row = model.factors.row_mut(n, coords[n] as usize);
            for (rv, gv) in row.iter_mut().zip(gs.iter()) {
                *rv -= lr * e * gv;
            }
        }
        let e_after = model.predict(&coords) - x;
        assert!(
            e_after.abs() <= e_before.abs() + 1e-5,
            "error grew: {} -> {}",
            e_before.abs(),
            e_after.abs()
        );
    });
}

#[test]
fn prop_checkpoint_roundtrip_any_shape() {
    forall("checkpoint roundtrip", 12, |rng| {
        let order = 2 + rng.gen_range(3);
        let dims: Vec<usize> = (0..order).map(|_| 2 + rng.gen_range(10)).collect();
        let j = 1 + rng.gen_range(6);
        let r_core = 1 + rng.gen_range(4);
        let model = if rng.gen_range(2) == 0 {
            TuckerModel::init_kruskal(rng, &dims, j, r_core)
        } else {
            TuckerModel::init_dense(rng, &dims, j)
        };
        let dir = std::env::temp_dir().join("fasttucker_prop_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("m{}.ftck", rng.next_u64()));
        fasttucker::model::checkpoint::save(&model, &path).unwrap();
        let loaded = fasttucker::model::checkpoint::load(&path).unwrap();
        let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
        assert!((model.predict(&coords) - loaded.predict(&coords)).abs() < 1e-6);
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_batched_kernel_bitwise_matches_scalar() {
    // The batched kernel's contract: for any tensor shape, rank, layout,
    // batch cap, and hyperparameters, executing a BatchPlan is BITWISE
    // identical to the scalar kernel over the same (grouped) sample order —
    // factors, core-gradient accumulators, and the per-sample residual
    // stream (the loss trajectory) all match to the bit.
    forall("batched == scalar, bitwise", 16, |rng| {
        let order = 2 + rng.gen_range(3); // 2..=4
        let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(40)).collect();
        let j = 1 + rng.gen_range(9);
        let r = 1 + rng.gen_range(9);
        let nnz = 200 + rng.gen_range(1500);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let layout = if rng.gen_range(2) == 0 {
            CoreLayout::Packed
        } else {
            CoreLayout::Strided
        };
        let strided = build_strided(&core);
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let max_batch = 1 + rng.gen_range(96);
        let plan = BatchPlan::build(&tensor, &ids, max_batch);
        let (lr, lam) = (0.01f32, 0.003f32);
        let update_core = rng.gen_range(2) == 0;

        let mut f_s = model.factors.clone();
        let mut ws = Workspace::new(order, r, j);
        let mut log_s = Vec::new();
        let st_s = scalar::run_ids(
            &mut ws, &tensor, plan.ids(), &core, &strided, layout, &mut f_s, lr, lam,
            update_core, Some(&mut log_s),
        );

        let mut f_b = model.factors.clone();
        let mut bws = BatchWorkspace::new(order, r, j, max_batch);
        let mut log_b = Vec::new();
        let st_b = batched::run_plan(
            &mut bws, &tensor, &plan, &core, &strided, layout, &mut f_b, lr, lam,
            update_core, Some(&mut log_b),
        );

        assert_eq!(st_s.samples, st_b.samples);
        assert_eq!(st_s.sse.to_bits(), st_b.sse.to_bits(), "sse diverged");
        assert_eq!(log_s.len(), log_b.len());
        for (i, (a, b)) in log_s.iter().zip(log_b.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "residual {i} diverged");
        }
        for n in 0..order {
            for (a, b) in f_s.mat(n).data().iter().zip(f_b.mat(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
            }
        }
        let (gs, cs) = ws.core_grad_mut();
        let (gb, cb) = bws.core_grad_mut();
        assert_eq!(*cs, *cb);
        for (a, b) in gs.iter().zip(gb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core grads diverged");
        }
    });
}

#[test]
fn prop_tiled_batched_bitwise_matches_scalar() {
    // The tentpole invariant: multi-fiber tiles (any tile width, any
    // layout, any hyperparameters) keep the batched kernel BITWISE
    // identical to the scalar kernel over plan order — factors, core
    // grads, and the residual stream.
    forall("tiled batched == scalar, bitwise", 16, |rng| {
        let order = 2 + rng.gen_range(3); // 2..=4
        // Skew mode 0 large so fibers are short and tiles really form.
        let mut dims: Vec<usize> = vec![40 + rng.gen_range(400)];
        for _ in 1..order {
            dims.push(8 + rng.gen_range(60));
        }
        let j = 1 + rng.gen_range(9);
        let r = 1 + rng.gen_range(9);
        let nnz = 200 + rng.gen_range(1500);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let layout = if rng.gen_range(2) == 0 {
            CoreLayout::Packed
        } else {
            CoreLayout::Strided
        };
        let strided = build_strided(&core);
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let params = fasttucker::kernel::PlanParams::tiled(
            2 + rng.gen_range(95),
            1 + rng.gen_range(16),
        );
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let (lr, lam) = (0.01f32, 0.003f32);
        let update_core = rng.gen_range(2) == 0;

        let mut f_s = model.factors.clone();
        let mut ws = Workspace::new(order, r, j);
        let mut log_s = Vec::new();
        let st_s = scalar::run_ids(
            &mut ws, &tensor, plan.ids(), &core, &strided, layout, &mut f_s, lr, lam,
            update_core, Some(&mut log_s),
        );

        let mut f_b = model.factors.clone();
        let mut bws = BatchWorkspace::new(order, r, j, params.max_batch);
        let mut log_b = Vec::new();
        let st_b = batched::run_plan(
            &mut bws, &tensor, &plan, &core, &strided, layout, &mut f_b, lr, lam,
            update_core, Some(&mut log_b),
        );

        assert_eq!(st_s.samples, st_b.samples);
        assert_eq!(st_s.sse.to_bits(), st_b.sse.to_bits(), "sse diverged");
        assert_eq!(log_s.len(), log_b.len());
        for (i, (a, b)) in log_s.iter().zip(log_b.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "residual {i} diverged");
        }
        for n in 0..order {
            for (a, b) in f_s.mat(n).data().iter().zip(f_b.mat(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
            }
        }
        let (gs, cs) = ws.core_grad_mut();
        let (gb, cb) = bws.core_grad_mut();
        assert_eq!(*cs, *cb);
        for (a, b) in gs.iter().zip(gb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core grads diverged");
        }
    });
}

#[test]
fn prop_panel_microkernel_bitwise_matches_scalar() {
    // ISSUE 3 tentpole invariant, extended by ISSUE 10: every
    // panel-microkernel lane width (Auto/4/8) × every SIMD level
    // (Scalar/V128/V256/Auto — explicit levels clamp to what the host
    // supports, so the sweep is portable) × every R_core tail length ×
    // Packed/Strided layout × split-group refinement keeps exact batched
    // execution BITWISE identical to the scalar kernel over plan order —
    // factors, core grads, sse, and the residual stream. One scalar
    // reference per case, every SIMD level compared against it.
    forall("panel microkernels == scalar, bitwise", 14, |rng| {
        let order = 2 + rng.gen_range(3); // 2..=4
        // Skew mode 0 large so fibers are short and tiles really form.
        let mut dims: Vec<usize> = vec![40 + rng.gen_range(400)];
        for _ in 1..order {
            dims.push(8 + rng.gen_range(60));
        }
        let j = 1 + rng.gen_range(9);
        // 1..=17 sweeps the lane-block tails: r % 4 and r % 8 both cycle,
        // including r < width entirely-tail cases.
        let r = 1 + rng.gen_range(17);
        let nnz = 200 + rng.gen_range(1200);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let layout = if rng.gen_range(2) == 0 {
            CoreLayout::Packed
        } else {
            CoreLayout::Strided
        };
        let strided = build_strided(&core);
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let lanes = match rng.gen_range(3) {
            0 => fasttucker::kernel::Lanes::Auto,
            1 => fasttucker::kernel::Lanes::W4,
            _ => fasttucker::kernel::Lanes::W8,
        };
        let base = fasttucker::kernel::PlanParams::tiled(
            2 + rng.gen_range(95),
            1 + rng.gen_range(16),
        )
        .with_lanes(lanes)
        .with_split(1 + rng.gen_range(6));
        let (lr, lam) = (0.01f32, 0.003f32);
        let update_core = rng.gen_range(2) == 0;

        let ref_plan = BatchPlan::build_params(&tensor, &ids, base);
        let mut f_s = model.factors.clone();
        let mut ws = Workspace::new(order, r, j);
        let mut log_s = Vec::new();
        let st_s = scalar::run_ids(
            &mut ws, &tensor, ref_plan.ids(), &core, &strided, layout, &mut f_s, lr, lam,
            update_core, Some(&mut log_s),
        );
        let (gs, cs) = ws.core_grad_mut();

        for simd in [
            fasttucker::kernel::SimdLevel::Scalar,
            fasttucker::kernel::SimdLevel::V128,
            fasttucker::kernel::SimdLevel::V256,
            fasttucker::kernel::SimdLevel::Auto,
        ] {
            let params = base.with_simd(simd);
            let plan = BatchPlan::build_params(&tensor, &ids, params);
            let mut f_b = model.factors.clone();
            let mut bws = BatchWorkspace::new(order, r, j, params.max_batch);
            let mut log_b = Vec::new();
            let st_b = batched::run_plan(
                &mut bws, &tensor, &plan, &core, &strided, layout, &mut f_b, lr, lam,
                update_core, Some(&mut log_b),
            );

            assert_eq!(st_s.samples, st_b.samples);
            assert_eq!(
                st_s.sse.to_bits(),
                st_b.sse.to_bits(),
                "sse diverged ({simd:?}, {lanes:?}, split {})",
                params.split
            );
            assert_eq!(log_s.len(), log_b.len());
            for (i, (a, b)) in log_s.iter().zip(log_b.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "residual {i} diverged ({simd:?}, {lanes:?})"
                );
            }
            for n in 0..order {
                for (a, b) in f_s.mat(n).data().iter().zip(f_b.mat(n).data().iter()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "mode {n} factors diverged ({simd:?}, {lanes:?}, split {})",
                        params.split
                    );
                }
            }
            let (gb, cb) = bws.core_grad_mut();
            assert_eq!(*cs, *cb);
            for (a, b) in gs.iter().zip(gb.iter()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "core grads diverged ({simd:?}, {lanes:?})"
                );
            }
        }
    });
}

#[test]
fn prop_split_group_execution_bitwise_matches_unsplit() {
    // ISSUE 3 satellite: exact split-group execution (sub-group cuts at
    // fiber sub-run boundaries) is bitwise equal to the unsplit plan —
    // and a relaxed split plan stays a permutation of the sample
    // multiset with every sample executed exactly once.
    forall("split-group == unsplit, bitwise (exact)", 10, |rng| {
        let order = 2 + rng.gen_range(3);
        let mut dims: Vec<usize> = vec![60 + rng.gen_range(400)];
        for _ in 1..order {
            dims.push(10 + rng.gen_range(60));
        }
        let j = 1 + rng.gen_range(7);
        let r = 1 + rng.gen_range(9);
        let nnz = 300 + rng.gen_range(1200);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let cap = 2 + rng.gen_range(95);
        let tile = 1 + rng.gen_range(16);
        let split = 2 + rng.gen_range(cap);
        let base = fasttucker::kernel::PlanParams::tiled(cap, tile);
        let (lr, lam) = (0.01f32, 0.003f32);

        let run = |params: fasttucker::kernel::PlanParams| {
            let plan = BatchPlan::build_params(&tensor, &ids, params);
            let mut f = model.factors.clone();
            let mut bws = BatchWorkspace::new(order, r, j, cap);
            let mut log = Vec::new();
            let st = batched::run_plan(
                &mut bws, &tensor, &plan, &core, &[], CoreLayout::Packed, &mut f, lr, lam,
                false, Some(&mut log),
            );
            (plan, f, st, log)
        };
        let (plan_u, f_u, st_u, log_u) = run(base);
        let (plan_s, f_s, st_s, log_s) = run(base.with_split(split));

        // Same sample order (the grouping sort ignores the split rule),
        // at least as many groups, identical execution bits.
        assert_eq!(plan_u.ids(), plan_s.ids());
        assert!(plan_s.n_groups() >= plan_u.n_groups());
        assert_eq!(st_u.samples, st_s.samples);
        assert_eq!(st_u.sse.to_bits(), st_s.sse.to_bits(), "sse diverged under split");
        for (a, b) in log_u.iter().zip(log_s.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "residual stream diverged under split");
        }
        for n in 0..order {
            for (a, b) in f_u.mat(n).data().iter().zip(f_s.mat(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under split");
            }
        }

        // Relaxed split: permutation of the multiset, every sample
        // executed once, sub-groups within the split budget.
        let rparams = fasttucker::kernel::PlanParams::relaxed(cap, tile).with_split(split);
        let (rplan, _f, rst, rlog) = run(rparams);
        let mut a = ids.clone();
        let mut b = rplan.ids().to_vec();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "relaxed split plan is not a permutation");
        assert_eq!(rst.samples, ids.len());
        assert_eq!(rlog.len(), ids.len());
        let budget = rparams.split_budget();
        for g in 0..rplan.n_groups() {
            assert!(rplan.group(g).len() <= budget);
        }
    });
}

#[test]
fn prop_subgroup_coloring_is_disjoint_ordered_partition() {
    // ISSUE 4 satellite: the coloring pass is a partition of the plan's
    // sub-groups whose waves have pairwise-disjoint row footprints — in
    // the mode-≥1 rows the deferred panel ops write AND the mode-0 rows
    // the sequential chains own (cap/distinctness cuts can split a fiber
    // across sub-groups, so mode 0 conflicts are real) — and any two
    // conflicting sub-groups sit in plan-order-preserving waves.
    forall("coloring: disjoint ordered partition", 12, |rng| {
        let order = 2 + rng.gen_range(3);
        let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(40)).collect();
        let nnz = 50 + rng.gen_range(400);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let params = fasttucker::kernel::PlanParams::tiled(
            2 + rng.gen_range(40),
            1 + rng.gen_range(8),
        )
        .with_split(1 + rng.gen_range(6));
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let coloring = plan.color_subgroups(&tensor);
        assert_eq!(coloring.n_groups(), plan.n_groups());

        // The independent level-2 auditor must agree with the hand-rolled
        // checks below on every geometry (ISSUE 6 tentpole).
        let waves = fasttucker::analysis::waves_of(&coloring);
        let report = fasttucker::analysis::audit_coloring(&tensor, &plan, &waves);
        assert!(report.ok(), "auditor rejected a real coloring: {report}");
        assert!(report.checks > 0);

        let rows = |g: usize| -> std::collections::HashSet<(usize, u32)> {
            let mut set = std::collections::HashSet::new();
            for &k in plan.group(g) {
                for (n, &c) in tensor.index(k as usize).iter().enumerate() {
                    set.insert((n, c));
                }
            }
            set
        };
        let mut wave_of = vec![usize::MAX; plan.n_groups()];
        for w in 0..coloring.n_waves() {
            for &g in coloring.wave(w) {
                assert_eq!(wave_of[g as usize], usize::MAX, "group {g} in two waves");
                wave_of[g as usize] = w;
            }
            // Pairwise disjoint within the wave (all modes).
            let wave = coloring.wave(w);
            for i in 0..wave.len() {
                let fi = rows(wave[i] as usize);
                for l in i + 1..wave.len() {
                    assert!(
                        fi.is_disjoint(&rows(wave[l] as usize)),
                        "wave {w}: sub-groups {} and {} share a factor row",
                        wave[i],
                        wave[l]
                    );
                }
            }
        }
        assert!(wave_of.iter().all(|&w| w != usize::MAX), "partition incomplete");
        // Conflicting pairs preserve plan order across waves.
        for i in 0..plan.n_groups() {
            let fi = rows(i);
            for l in i + 1..plan.n_groups() {
                if !fi.is_disjoint(&rows(l)) {
                    assert!(
                        wave_of[i] < wave_of[l],
                        "conflicting sub-groups {i} < {l} execute out of order \
                         (waves {} >= {})",
                        wave_of[i],
                        wave_of[l]
                    );
                }
            }
        }
    });
}

#[test]
fn prop_threaded_exact_bitwise_matches_sequential() {
    // ISSUE 4 acceptance: exact-mode in-group threading — any thread
    // count × lane width × split factor × core layout — is bitwise
    // identical to sequential sub-group execution: factors, SSE, the
    // residual stream, and the core-gradient accumulators (the
    // plan-order tape replay).
    forall("threaded exact == sequential, bitwise", 10, |rng| {
        let order = 2 + rng.gen_range(3);
        let mut dims: Vec<usize> = vec![60 + rng.gen_range(400)];
        for _ in 1..order {
            dims.push(10 + rng.gen_range(60));
        }
        let j = 1 + rng.gen_range(7);
        let r = 1 + rng.gen_range(9);
        let nnz = 300 + rng.gen_range(1200);
        let tensor = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let layout = if rng.gen_range(2) == 0 {
            CoreLayout::Packed
        } else {
            CoreLayout::Strided
        };
        let strided = build_strided(&core);
        let n_ids = 1 + rng.gen_range(nnz);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
        let cap = 2 + rng.gen_range(95);
        let lanes = match rng.gen_range(3) {
            0 => Lanes::Auto,
            1 => Lanes::W4,
            _ => Lanes::W8,
        };
        let params = fasttucker::kernel::PlanParams::tiled(cap, 1 + rng.gen_range(16))
            .with_lanes(lanes)
            .with_split(1 + rng.gen_range(cap));
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let coloring = plan.color_subgroups(&tensor);
        let threads = 2 + rng.gen_range(3); // 2..=4
        let (lr, lam) = (0.01f32, 0.003f32);
        let update_core = rng.gen_range(2) == 0;

        let mut f_seq = model.factors.clone();
        let mut seq_ws = BatchWorkspace::new(order, r, j, cap);
        let mut log_seq = Vec::new();
        let st_seq = batched::run_plan(
            &mut seq_ws, &tensor, &plan, &core, &strided, layout, &mut f_seq, lr, lam,
            update_core, Some(&mut log_seq),
        );

        let mut f_pool = model.factors.clone();
        let mut pool = DispatchPool::new(threads, order, r, j, cap);
        let mut log_pool = Vec::new();
        let st_pool = {
            let shared = SharedFactors::new(&mut f_pool);
            // SAFETY: exact coloring waves have pairwise-disjoint row
            // footprints; nothing else touches the factors.
            pool.execute(
                &tensor, &plan, &coloring, &core, &strided, layout,
                || unsafe { SharedRowAccess::new(&shared) },
                lr, lam, update_core, Some(&mut log_pool),
            )
        };

        assert_eq!(st_seq.samples, st_pool.samples);
        assert_eq!(
            st_seq.sse.to_bits(),
            st_pool.sse.to_bits(),
            "T={threads} {lanes:?} {layout:?}: sse diverged"
        );
        assert_eq!(log_seq.len(), log_pool.len());
        for (i, (a, b)) in log_seq.iter().zip(log_pool.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "residual {i} diverged");
        }
        for n in 0..order {
            for (a, b) in f_seq.mat(n).data().iter().zip(f_pool.mat(n).data().iter()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "T={threads} {lanes:?} {layout:?}: mode {n} factors diverged"
                );
            }
        }
        let (gs, cs) = seq_ws.core_grad_mut();
        let (gp, cp) = pool.core_grad_mut();
        assert_eq!(*cs, *cp);
        for (a, b) in gs.iter().zip(gp.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core grads diverged");
        }
    });
}

#[test]
fn prop_sharded_exact_bitwise_matches_single_device() {
    // ISSUE 5 acceptance: exact-mode training on a D-device grid — for
    // D ∈ {1, 2, 3, 4}, across in-group thread counts, split factors, and
    // core layouts, on BOTH a tall and a hollow workload — is bitwise
    // identical to the D = 1 path: factors, the applied core gradients
    // (compared through the core factors), and the per-epoch residual
    // trajectory. The D = 1 baseline also pins that a single device
    // ships no boundary rows.
    use fasttucker::algo::SgdHyper;
    use fasttucker::data::synth::{planted_tucker, PlantedSpec};
    use fasttucker::kernel::ThreadCount;
    use fasttucker::kruskal::reconstruct::rmse;
    use fasttucker::parallel::{DeviceCount, ParallelFastTucker, ParallelOptions};

    let workloads = [
        // Tall: long mode-0 fibers, dense chunk interactions.
        ("tall", PlantedSpec {
            dims: vec![40, 40, 40],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
        // Hollow HOHDST shape: short fibers, wide trailing modes — the
        // planner tiles, splits engage, pools find parallel width.
        ("hollow", PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
    ];
    // (threads, split, layout): sequential dispatch, pooled + split
    // dispatch, and the Strided core walk.
    let combos = [
        (1usize, 1usize, CoreLayout::Packed),
        (2, 8, CoreLayout::Packed),
        (2, 4, CoreLayout::Strided),
    ];
    for (wname, spec) in &workloads {
        let mut prng = fasttucker::util::Rng::new(0xD1CE);
        let p = planted_tucker(&mut prng, spec);
        for &(threads, split, layout) in &combos {
            let run = |devices: usize| {
                let mut rng = fasttucker::util::Rng::new(7001);
                let mut model =
                    TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
                let mut opts = ParallelOptions::default();
                opts.workers = 4;
                opts.devices = DeviceCount::Fixed(devices);
                opts.threads = ThreadCount::Fixed(threads);
                opts.split = split;
                opts.layout = layout;
                opts.hyper = SgdHyper::default();
                let mut engine = ParallelFastTucker::new(opts);
                let mut rng2 = fasttucker::util::Rng::new(7002);
                let mut trajectory = Vec::new();
                for epoch in 0..2 {
                    engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
                    trajectory.push(rmse(&model, &p.tensor));
                }
                (model, trajectory, engine.plan_accum)
            };
            let (base, base_traj, base_acc) = run(1);
            assert_eq!(base_acc.comm_rows, 0, "{wname}: one device has no boundary");
            for devices in [2usize, 3, 4] {
                let (sharded, traj, acc) = run(devices);
                assert_eq!(acc.devices, devices);
                assert!(
                    acc.comm_rows > 0,
                    "{wname} D={devices}: boundary exchange never counted"
                );
                for (e, (a, b)) in base_traj.iter().zip(traj.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{wname} D={devices} T={threads} split={split} {layout:?}: \
                         epoch {e} residual trajectory diverged ({a} vs {b})"
                    );
                }
                for n in 0..3 {
                    for (a, b) in base
                        .factors
                        .mat(n)
                        .data()
                        .iter()
                        .zip(sharded.factors.mat(n).data().iter())
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{wname} D={devices} T={threads} split={split} {layout:?}: \
                             mode {n} factors diverged"
                        );
                    }
                }
                let (ck, cs) = match (&base.core, &sharded.core) {
                    (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
                    _ => unreachable!(),
                };
                for n in 0..3 {
                    for (a, b) in
                        ck.factor(n).data().iter().zip(cs.factor(n).data().iter())
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{wname} D={devices}: core mode {n} diverged \
                             (Eq. 17 merge order)"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_relaxed_plan_execution_is_permutation_and_descends() {
    // Relaxed (hogwild) plans: the executed sample multiset is exactly
    // the input multiset (KernelStats::samples + the residual count), and
    // repeated passes still descend the loss — collisions lose bitwise
    // equality, not correctness.
    forall("relaxed execution: permutation + descent", 8, |rng| {
        let dims = vec![100 + rng.gen_range(400), 10 + rng.gen_range(30), 10 + rng.gen_range(30)];
        let j = 2 + rng.gen_range(5);
        let r = 2 + rng.gen_range(5);
        let nnz = 1000;
        let spec = synth::PlantedSpec {
            dims: dims.clone(),
            nnz,
            j,
            r_core: r,
            noise: 0.01,
            clamp: None,
        };
        let p = synth::planted_tucker(rng, &spec);
        let mut model = TuckerModel::init_kruskal(rng, &dims, j, r);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..nnz as u32).collect();
        let params = fasttucker::kernel::PlanParams::relaxed(64, 16);
        let plan = BatchPlan::build_params(&p.tensor, &ids, params);
        // Permutation of the multiset.
        let mut a = ids.clone();
        let mut b = plan.ids().to_vec();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);

        let mut bws = BatchWorkspace::new(3, r, j, 64);
        let mut first_sse = None;
        let mut last_sse = 0.0;
        for _ in 0..6 {
            let mut log = Vec::new();
            let st = batched::run_plan(
                &mut bws, &p.tensor, &plan, &core, &[], CoreLayout::Packed,
                &mut model.factors, 0.01, 0.0, false, Some(&mut log),
            );
            assert_eq!(st.samples, nnz);
            assert_eq!(log.len(), nnz);
            if first_sse.is_none() {
                first_sse = Some(st.sse);
            }
            last_sse = st.sse;
        }
        assert!(
            last_sse < first_sse.unwrap(),
            "relaxed execution failed to descend: {} -> {last_sse}",
            first_sse.unwrap()
        );
    });
}

#[test]
fn prop_layouts_equivalent_through_batched_kernel() {
    // Tables 8–12 ablation invariant: Packed and Strided layouts produce
    // identical epoch statistics (samples exactly, accuracy numerically)
    // through the batched kernel on random synthetic tensors.
    forall("Packed ≈ Strided through batched kernel", 8, |rng| {
        let dims = vec![10 + rng.gen_range(20), 10 + rng.gen_range(40), 10 + rng.gen_range(40)];
        let j = 2 + rng.gen_range(7);
        let r = 2 + rng.gen_range(7);
        let nnz = 2000;
        let spec = synth::PlantedSpec {
            dims: dims.clone(),
            nnz,
            j,
            r_core: r,
            noise: 0.05,
            clamp: None,
        };
        let p = synth::planted_tucker(rng, &spec);
        let seed = rng.next_u64();
        let mut run = |layout| {
            let mut mrng = fasttucker::util::Rng::new(seed);
            let mut model = TuckerModel::init_kruskal(&mut mrng, &dims, j, r);
            let mut algo = fasttucker::algo::FastTucker::with_batch(32);
            algo.config.layout = layout;
            algo.config.hyper.lr_factor = fasttucker::sched::LrSchedule::constant(0.02);
            algo.config.hyper.lr_core = fasttucker::sched::LrSchedule::constant(0.01);
            let mut erng = fasttucker::util::Rng::new(seed ^ 0xABCD);
            let mut samples = 0usize;
            for epoch in 0..2 {
                let st = algo
                    .train_epoch(&mut model, &p.tensor, epoch, &mut erng)
                    .unwrap();
                samples += st.samples;
            }
            (samples, fasttucker::kruskal::reconstruct::rmse(&model, &p.tensor))
        };
        let (samples_p, rmse_p) = run(CoreLayout::Packed);
        let (samples_s, rmse_s) = run(CoreLayout::Strided);
        assert_eq!(samples_p, samples_s, "identical epoch stats: sample counts");
        // The layouts reassociate a handful of f32 reductions (dot tails
        // when R % 4 != 0), so allow a small relative drift.
        assert!(
            (rmse_p - rmse_s).abs() < 1e-2 * (1.0 + rmse_p.abs()),
            "layouts diverged: {rmse_p} vs {rmse_s}"
        );
    });
}

#[test]
fn prop_factor_matrices_shapes_consistent() {
    forall("factor matrices shapes", 16, |rng| {
        let order = 1 + rng.gen_range(6);
        let dims: Vec<usize> = (0..order).map(|_| 1 + rng.gen_range(30)).collect();
        let rank = 1 + rng.gen_range(16);
        let f = FactorMatrices::random(rng, &dims, rank, 1.0);
        assert_eq!(f.order(), order);
        assert_eq!(f.dims(), dims);
        for n in 0..order {
            assert_eq!(f.row(n, dims[n] - 1).len(), rank);
        }
    });
}

#[test]
fn prop_channel_transport_exact_bitwise_matches_direct() {
    // ISSUE 7 tentpole acceptance: routing every boundary-row panel and
    // core-gradient panel through the framed, checksummed channel
    // transport is bitwise-neutral — for D ∈ {1, 2, 3, 4}, on both the
    // tall and the hollow workload, factors, core factors, and the
    // per-epoch residual trajectory match the direct handover exactly.
    // D > 1 must actually move frames (no vacuous pass); D = 1 must
    // move none.
    use fasttucker::algo::SgdHyper;
    use fasttucker::data::synth::{planted_tucker, PlantedSpec};
    use fasttucker::kruskal::reconstruct::rmse;
    use fasttucker::parallel::{
        DeviceCount, ParallelFastTucker, ParallelOptions, TransportKind,
    };

    let workloads = [
        ("tall", PlantedSpec {
            dims: vec![40, 40, 40],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
        ("hollow", PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
    ];
    for (wname, spec) in &workloads {
        let mut prng = fasttucker::util::Rng::new(0xD1CE);
        let p = planted_tucker(&mut prng, spec);
        let run = |transport: TransportKind, devices: usize| {
            let mut rng = fasttucker::util::Rng::new(7001);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = DeviceCount::Fixed(devices);
            opts.transport = transport;
            opts.hyper = SgdHyper::default();
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = fasttucker::util::Rng::new(7002);
            let mut trajectory = Vec::new();
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
                trajectory.push(rmse(&model, &p.tensor));
            }
            (model, trajectory, engine.plan_accum)
        };
        for devices in [1usize, 2, 3, 4] {
            let (direct, dtraj, _) = run(TransportKind::Direct, devices);
            let (channel, ctraj, acc) = run(TransportKind::Channel, devices);
            if devices > 1 {
                assert!(
                    acc.frames_sent > 0,
                    "{wname} D={devices}: the channel shipped no frames"
                );
                assert!(acc.frames_delivered > 0);
            } else {
                assert_eq!(acc.frames_sent, 0, "{wname}: D=1 must ship nothing");
            }
            assert_eq!(
                acc.transport_faults(),
                0,
                "{wname} D={devices}: healthy channel reported faults"
            );
            for (e, (a, b)) in dtraj.iter().zip(ctraj.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{wname} D={devices}: epoch {e} trajectory diverged over the channel"
                );
            }
            for n in 0..3 {
                for (a, b) in direct
                    .factors
                    .mat(n)
                    .data()
                    .iter()
                    .zip(channel.factors.mat(n).data().iter())
                {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{wname} D={devices}: mode {n} factors diverged over the channel"
                    );
                }
            }
            let (ck, cs) = match (&direct.core, &channel.core) {
                (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
                _ => unreachable!(),
            };
            for n in 0..3 {
                for (a, b) in ck.factor(n).data().iter().zip(cs.factor(n).data().iter()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{wname} D={devices}: core mode {n} diverged over the channel"
                    );
                }
            }
        }
    }
}

#[test]
// Name note: contains "async_prefetch_is_bitwise_neutral" so the chaos CI
// leg's existing --skip substring covers it (it asserts a fault-free run).
fn prop_async_prefetch_is_bitwise_neutral_across_devices_and_splits() {
    // ISSUE 8 tentpole acceptance: double-buffering the exchange (round
    // r+1's panels issued while round r computes, the per-epoch core
    // merge pipelined behind the last round) is bitwise-neutral in
    // exact mode — for D ∈ {1, 2, 3, 4} × split ∈ {1, 2}, on both the
    // tall and the hollow workload, factors, core factors, and the
    // per-epoch residual trajectory match both the synchronous channel
    // exchange and the direct handover exactly. D > 1 must actually
    // prefetch (and hide real exchange seconds); D = 1 has nothing in
    // flight.
    use fasttucker::algo::SgdHyper;
    use fasttucker::data::synth::{planted_tucker, PlantedSpec};
    use fasttucker::kruskal::reconstruct::rmse;
    use fasttucker::parallel::{
        DeviceCount, ParallelFastTucker, ParallelOptions, PrefetchMode, TransportKind,
    };

    let workloads = [
        ("tall", PlantedSpec {
            dims: vec![40, 40, 40],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
        ("hollow", PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }),
    ];
    for (wname, spec) in &workloads {
        let mut prng = fasttucker::util::Rng::new(0xA51C);
        let p = planted_tucker(&mut prng, spec);
        let run = |transport: TransportKind, prefetch: PrefetchMode, devices: usize, split: usize| {
            let mut rng = fasttucker::util::Rng::new(8001);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = DeviceCount::Fixed(devices);
            opts.transport = transport;
            opts.prefetch = prefetch;
            opts.split = split;
            opts.hyper = SgdHyper::default();
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = fasttucker::util::Rng::new(8002);
            let mut trajectory = Vec::new();
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
                trajectory.push(rmse(&model, &p.tensor));
            }
            (model, trajectory, engine.plan_accum)
        };
        for devices in [1usize, 2, 3, 4] {
            for split in [1usize, 2] {
                let (direct, dtraj, _) =
                    run(TransportKind::Direct, PrefetchMode::Off, devices, split);
                let (sync, straj, _) =
                    run(TransportKind::Channel, PrefetchMode::Off, devices, split);
                let (asy, atraj, acc) =
                    run(TransportKind::Channel, PrefetchMode::Async, devices, split);
                if devices > 1 {
                    assert!(
                        acc.prefetch_issued > 0,
                        "{wname} D={devices} split={split}: nothing prefetched"
                    );
                    assert!(
                        acc.comm_hidden_secs > 0.0,
                        "{wname} D={devices} split={split}: no exchange cost hidden"
                    );
                } else {
                    assert_eq!(
                        acc.prefetch_issued, 0,
                        "{wname} split={split}: D=1 must have nothing in flight"
                    );
                }
                assert_eq!(
                    acc.transport_faults(),
                    0,
                    "{wname} D={devices} split={split}: healthy async channel reported faults"
                );
                assert_eq!(acc.degraded, 0, "{wname} D={devices} split={split}: degraded");
                for (e, ((a, b), c)) in
                    dtraj.iter().zip(straj.iter()).zip(atraj.iter()).enumerate()
                {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{wname} D={devices} split={split}: epoch {e} sync trajectory diverged"
                    );
                    assert_eq!(
                        a.to_bits(),
                        c.to_bits(),
                        "{wname} D={devices} split={split}: epoch {e} async trajectory diverged"
                    );
                }
                for n in 0..3 {
                    let d = direct.factors.mat(n).data();
                    let s = sync.factors.mat(n).data();
                    let a = asy.factors.mat(n).data();
                    for ((x, y), z) in d.iter().zip(s.iter()).zip(a.iter()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{wname} D={devices} split={split}: mode {n} sync diverged"
                        );
                        assert_eq!(
                            x.to_bits(),
                            z.to_bits(),
                            "{wname} D={devices} split={split}: mode {n} async diverged"
                        );
                    }
                }
                let (dk, sk, ak) = match (&direct.core, &sync.core, &asy.core) {
                    (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b), CoreRepr::Kruskal(c)) => {
                        (a, b, c)
                    }
                    _ => unreachable!(),
                };
                for n in 0..3 {
                    for ((x, y), z) in dk
                        .factor(n)
                        .data()
                        .iter()
                        .zip(sk.factor(n).data().iter())
                        .zip(ak.factor(n).data().iter())
                    {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{wname} D={devices} split={split}: core mode {n} sync diverged"
                        );
                        assert_eq!(
                            x.to_bits(),
                            z.to_bits(),
                            "{wname} D={devices} split={split}: core mode {n} async diverged"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_relaxed_bounded_staleness_stays_in_envelope_and_audits_clean() {
    // ISSUE 8 relaxed-mode acceptance: with staleness S ∈ {1, 2} a
    // boundary panel may be applied up to S rounds late. The run must
    // (a) train to the same quality neighborhood as the synchronous
    // relaxed run (the hogwild-style accuracy envelope), (b) produce an
    // event log the staleness-aware auditor accepts at its own bound —
    // and that the strict S = 0 auditor accepts at staleness 0 is
    // already covered by the exact-mode property above.
    use fasttucker::algo::SgdHyper;
    use fasttucker::analysis::audit_exchange_with_staleness;
    use fasttucker::data::synth::{planted_tucker, PlantedSpec};
    use fasttucker::kernel::Exactness;
    use fasttucker::kruskal::reconstruct::rmse;
    use fasttucker::parallel::{
        DeviceCount, ParallelFastTucker, ParallelOptions, PrefetchMode, TransportKind,
    };

    let spec = PlantedSpec {
        dims: vec![40, 40, 40],
        nnz: 6000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut prng = fasttucker::util::Rng::new(0x51A1);
    let p = planted_tucker(&mut prng, &spec);
    let run = |staleness: usize| {
        let mut rng = fasttucker::util::Rng::new(8101);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = DeviceCount::Fixed(2);
        opts.exactness = Exactness::Relaxed;
        opts.transport = TransportKind::Channel;
        opts.prefetch = if staleness > 0 { PrefetchMode::Async } else { PrefetchMode::Off };
        opts.staleness = staleness;
        opts.hyper = SgdHyper::default();
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = fasttucker::util::Rng::new(8102);
        for epoch in 0..8 {
            engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            let report =
                audit_exchange_with_staleness(engine.exchange_events(), staleness);
            assert!(report.ok(), "S={staleness} epoch {epoch}: {report}");
        }
        assert_eq!(
            engine.plan_accum.degraded, 0,
            "S={staleness}: engaged bounded staleness wrongly degraded"
        );
        rmse(&model, &p.tensor)
    };
    let baseline = run(0);
    for staleness in [1usize, 2] {
        let stale_rmse = run(staleness);
        // Stale applies perturb individual SGD steps, not convergence:
        // the final quality must stay in the synchronous run's
        // neighborhood (generous bound — the envelope, not bitwise).
        assert!(
            stale_rmse < baseline * 1.5 + 0.05,
            "S={staleness}: rmse {stale_rmse} left the envelope (sync relaxed: {baseline})"
        );
    }
}

#[test]
fn prop_fault_matrix_recovers_bitwise_or_fails_named() {
    // ISSUE 7 acceptance: for every fault class × injection rate × seed,
    // a faulty channel run either (a) completes AND is bitwise-equal to
    // the fault-free channel run — recovery, not approximation — or
    // (b) fails with a typed AlgoError::Transport. There is no third
    // outcome: no panic, no silent divergence, no other error class.
    use fasttucker::algo::{AlgoError, SgdHyper};
    use fasttucker::data::synth::{planted_tucker, PlantedSpec};
    use fasttucker::parallel::{
        DeviceCount, FaultKind, FaultKinds, FaultPlan, ParallelFastTucker, ParallelOptions,
        TransportKind,
    };

    let spec = PlantedSpec {
        dims: vec![30, 24, 24],
        nnz: 2500,
        j: 4,
        r_core: 3,
        noise: 0.05,
        clamp: None,
    };
    let mut prng = fasttucker::util::Rng::new(0xFA17);
    let p = planted_tucker(&mut prng, &spec);
    let run = |fault: Option<FaultPlan>| {
        let mut rng = fasttucker::util::Rng::new(9001);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 3;
        opts.devices = DeviceCount::Fixed(2);
        opts.transport = TransportKind::Channel;
        opts.fault = fault;
        opts.hyper = SgdHyper::default();
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = fasttucker::util::Rng::new(9002);
        for epoch in 0..2 {
            engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2)?;
        }
        Ok::<_, AlgoError>((model, engine.plan_accum))
    };
    let (reference, _) = run(None).expect("fault-free channel run failed");

    let kinds = [
        FaultKind::Drop,
        FaultKind::Duplicate,
        FaultKind::Reorder,
        FaultKind::Corrupt,
        FaultKind::Delay,
    ];
    let mut completions = 0usize;
    let mut named_failures = 0usize;
    let mut faults_observed = 0u64;
    for kind in kinds {
        for rate in [0.05f32, 0.4] {
            for seed in [1u64, 2, 3] {
                let plan = FaultPlan {
                    seed,
                    rate,
                    kinds: FaultKinds::single(kind),
                    kill: None,
                };
                match run(Some(plan)) {
                    Ok((model, acc)) => {
                        completions += 1;
                        faults_observed += acc.transport_faults();
                        for n in 0..3 {
                            for (a, b) in reference
                                .factors
                                .mat(n)
                                .data()
                                .iter()
                                .zip(model.factors.mat(n).data().iter())
                            {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "{kind:?} rate={rate} seed={seed}: recovery was not \
                                     bitwise (mode {n})"
                                );
                            }
                        }
                        let (ck, cs) = match (&reference.core, &model.core) {
                            (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
                            _ => unreachable!(),
                        };
                        for n in 0..3 {
                            for (a, b) in
                                ck.factor(n).data().iter().zip(cs.factor(n).data().iter())
                            {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "{kind:?} rate={rate} seed={seed}: core recovery was \
                                     not bitwise (mode {n})"
                                );
                            }
                        }
                    }
                    // The only legal failure is a typed transport error
                    // (retry budget exhausted under heavy loss).
                    Err(AlgoError::Transport(e)) => {
                        named_failures += 1;
                        let _ = e;
                    }
                    Err(other) => panic!(
                        "{kind:?} rate={rate} seed={seed}: non-transport error {other:?}"
                    ),
                }
            }
        }
    }
    // The matrix must exercise both recovery and the injectors: most
    // cells complete bitwise, and the counters prove faults were real.
    assert!(completions > 0, "no fault cell ever completed");
    assert!(faults_observed > 0, "injectors never fired across the whole matrix");
    // Named failures are allowed but not required (rate 0.4 drops may or
    // may not exhaust the retry budget depending on the dice).
    let _ = named_failures;
}
