//! Shadow race-detector sessions over the real engines (ISSUE 6).
//!
//! These tests need the `shadow-ledger` feature (CI's
//! `--features strict-audit,shadow-ledger` leg); the whole file is
//! compiled out otherwise. They live in their own integration binary —
//! not in `analysis::shadow`'s unit tests — because a session records
//! process-globally: inside the lib test binary, *other* tests drive
//! instrumented engines on parallel libtest threads and would pollute an
//! open session. Sessions are still serialized by an internal lock, so
//! the tests in this binary may run on parallel threads safely.
#![cfg(feature = "shadow-ledger")]

use fasttucker::analysis::shadow::{self, AccessKind};
use fasttucker::analysis::ShadowSession;
use fasttucker::data::synth::{self, planted_tucker, PlantedSpec};
use fasttucker::kernel::{BatchSizing, Exactness, ThreadCount};
use fasttucker::model::TuckerModel;
use fasttucker::parallel::{DeviceCount, ParallelFastTucker, ParallelOptions};
use fasttucker::util::Rng;

fn planted(seed: u64) -> (fasttucker::SparseTensor, PlantedSpec) {
    let spec = PlantedSpec {
        dims: vec![40, 40, 40],
        nnz: 4000,
        j: 4,
        r_core: 4,
        noise: 0.01,
        clamp: None,
    };
    let mut rng = Rng::new(seed);
    (planted_tucker(&mut rng, &spec).tensor, spec)
}

/// One exact-mode training epoch under a recording session.
fn record_exact_epoch(
    tensor: &fasttucker::SparseTensor,
    spec: &PlantedSpec,
    threads: usize,
    devices: usize,
) -> shadow::ShadowLog {
    let mut rng = Rng::new(91);
    let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
    let mut opts = ParallelOptions::default();
    opts.workers = 4;
    opts.exactness = Exactness::Exact;
    opts.threads = ThreadCount::Fixed(threads);
    opts.devices = DeviceCount::Fixed(devices);
    let mut engine = ParallelFastTucker::new(opts);
    let session = ShadowSession::begin();
    let mut rng2 = Rng::new(92);
    engine.train_epoch(&mut model, tensor, 0, &mut rng2).unwrap();
    session.finish()
}

#[test]
fn sessions_record_and_drain_across_threads() {
    // Plumbing round trip: context propagation, per-thread ledgers,
    // drain on finish, inertness outside a session.
    let session = ShadowSession::begin();
    shadow::set_epoch(2);
    shadow::set_round(1);
    shadow::set_worker(3);
    shadow::record(0, 10, AccessKind::Write);
    let parent = shadow::current_ctx();
    std::thread::scope(|scope| {
        scope.spawn(|| {
            shadow::adopt(parent, 1);
            shadow::set_wave(4);
            shadow::record(1, 20, AccessKind::Atomic);
        });
    });
    let log = session.finish();
    assert_eq!(log.len(), 2);
    let a = log.records.iter().find(|a| a.mode == 0).unwrap();
    assert_eq!((a.prov.epoch, a.prov.round, a.prov.worker), (2, 1, 3));
    let b = log.records.iter().find(|a| a.mode == 1).unwrap();
    assert_eq!((a.prov.worker, b.prov.worker), (3, 3), "child must inherit the worker");
    assert_eq!((b.prov.wave, b.prov.thread), (4, 1));
    assert_eq!(log.written_rows(), [(0, 10), (1, 20)].into_iter().collect());
    assert!(log.check().is_empty());

    // After finish, recording is inert again.
    shadow::record(0, 99, AccessKind::Write);
    let empty = ShadowSession::begin().finish();
    assert!(empty.is_empty(), "record outside a session must not leak in");
}

#[test]
fn exact_epochs_are_race_free_at_every_thread_count() {
    // The tentpole acceptance: a real exact-mode epoch at T = 1, 2, 4
    // shows ZERO happens-before violations, and the provenance row-set
    // (which rows were written) is identical across thread counts.
    let (tensor, spec) = planted(90);
    let base = record_exact_epoch(&tensor, &spec, 1, 1);
    assert!(!base.is_empty(), "instrumentation recorded nothing");
    assert!(base.check().is_empty(), "T=1: {:?}", base.check());
    let base_rows = base.written_rows();
    assert!(!base_rows.is_empty());
    for threads in [2usize, 4] {
        let log = record_exact_epoch(&tensor, &spec, threads, 1);
        assert!(
            log.check().is_empty(),
            "T={threads}: races in an exact epoch: {:?}",
            log.check()
        );
        assert_eq!(
            log.written_rows(),
            base_rows,
            "T={threads}: written row-set diverged from T=1"
        );
    }
}

#[test]
fn exact_epochs_are_race_free_at_every_device_count() {
    // Device sharding (level 0) must not introduce overlap either: the
    // same epoch at D = 1, 2, 3 with a 2-thread pool stays clean and
    // writes the same rows.
    let (tensor, spec) = planted(93);
    let base = record_exact_epoch(&tensor, &spec, 2, 1);
    assert!(base.check().is_empty());
    let base_rows = base.written_rows();
    for devices in [2usize, 3] {
        let log = record_exact_epoch(&tensor, &spec, 2, devices);
        assert!(
            log.check().is_empty(),
            "D={devices}: races in an exact epoch: {:?}",
            log.check()
        );
        assert_eq!(
            log.written_rows(),
            base_rows,
            "D={devices}: written row-set diverged from D=1"
        );
    }
}

#[test]
fn relaxed_contention_shows_up_in_the_histogram_not_as_races() {
    // Relaxed hogwild on a deliberately narrow tensor (modes 1 and 2
    // have 6 and 5 rows): the two pool threads MUST collide on shared
    // rows — visible as a non-empty atomic-contention histogram, and
    // NOT as violations (atomic overlap is hogwild by design).
    let mut rng = Rng::new(95);
    let dims = vec![30usize, 6, 5];
    let tensor = synth::random_uniform(&mut rng, &dims, 2000, 1.0, 5.0);
    let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 4);
    let mut opts = ParallelOptions::default();
    opts.workers = 1;
    opts.exactness = Exactness::Relaxed;
    opts.threads = ThreadCount::Fixed(2);
    opts.batch = BatchSizing::Fixed(16);
    opts.devices = DeviceCount::Fixed(1);
    let mut engine = ParallelFastTucker::new(opts);

    let session = ShadowSession::begin();
    let mut rng2 = Rng::new(96);
    engine.train_epoch(&mut model, &tensor, 0, &mut rng2).unwrap();
    let log = session.finish();

    assert!(!log.is_empty());
    assert!(
        log.check().is_empty(),
        "relaxed-mode atomic overlap must not be reported as a race: {:?}",
        log.check()
    );
    let hist = log.overlap_histogram();
    assert!(
        !hist.is_empty(),
        "2-thread hogwild over 6-row modes never contended — hooks broken?"
    );
    assert!(hist.values().all(|&count| count > 0));
}
