//! Long-lived session integration tests (ISSUE 9): engine-state reuse
//! across tensors, streaming append correctness (bitwise vs a fresh
//! engine on the merged tensor), and warm-start-beats-cold retraining
//! with the cache-invalidation counters observed end to end.

use fasttucker::config::{EngineKind, TrainConfig};
use fasttucker::coordinator::Session;
use fasttucker::data::split::train_test_split;
use fasttucker::data::stream::ArrivalSim;
use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::model::{CoreRepr, TuckerModel};
use fasttucker::parallel::{ParallelFastTucker, ParallelOptions};
use fasttucker::sched::LrSchedule;
use fasttucker::serve::Query;
use fasttucker::util::Rng;
use fasttucker::SparseTensor;

fn assert_models_bitwise(a: &TuckerModel, b: &TuckerModel, what: &str) {
    for (n, (ma, mb)) in a.factors.mats().iter().zip(b.factors.mats()).enumerate() {
        for (k, (x, y)) in ma.data().iter().zip(mb.data()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: factor {n} entry {k}: {x} != {y}"
            );
        }
    }
    match (&a.core, &b.core) {
        (CoreRepr::Kruskal(ka), CoreRepr::Kruskal(kb)) => {
            for n in 0..ka.order() {
                for (k, (x, y)) in ka
                    .factor(n)
                    .data()
                    .iter()
                    .zip(kb.factor(n).data())
                    .enumerate()
                {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: core factor {n} entry {k}: {x} != {y}"
                    );
                }
            }
        }
        _ => panic!("{what}: expected kruskal cores"),
    }
}

fn engine_opts() -> ParallelOptions {
    let mut opts = ParallelOptions::default();
    opts.workers = 2;
    opts.hyper.lr_factor = LrSchedule::constant(0.02);
    opts.hyper.lr_core = LrSchedule::constant(0.01);
    opts
}

fn planted(seed: u64, dims: Vec<usize>, nnz: usize) -> SparseTensor {
    let spec = PlantedSpec { dims, nnz, j: 4, r_core: 4, noise: 0.05, clamp: None };
    let mut rng = Rng::new(seed);
    planted_tucker(&mut rng, &spec).tensor
}

/// One engine reused across tensors of different shapes: the
/// revision-keyed caches must rebuild for each switch (stale reuse is
/// impossible), and switching back still works.
#[test]
fn engine_reuse_across_different_tensors_rebuilds_state() {
    let a = planted(1, vec![24, 20, 16], 3000);
    let b = planted(2, vec![30, 18, 12], 3000); // different dims, same nnz
    let c = planted(3, vec![24, 20, 16], 4500); // A's dims, different nnz

    let mut engine = ParallelFastTucker::new(engine_opts());
    let mut rng = Rng::new(7);
    let mut model_a = TuckerModel::init_kruskal(&mut rng, a.dims(), 4, 4);
    let mut model_b = TuckerModel::init_kruskal(&mut rng, b.dims(), 4, 4);
    let mut model_c = TuckerModel::init_kruskal(&mut rng, c.dims(), 4, 4);

    engine.train_epoch(&mut model_a, &a, 0, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 1);
    engine.train_epoch(&mut model_b, &b, 0, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 2, "dims change must rebuild");
    engine.train_epoch(&mut model_c, &c, 0, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 3, "nnz change must rebuild");
    // Back to A: the cache holds only the latest state, so this is a
    // rebuild too — but correctness never depended on a hit.
    engine.train_epoch(&mut model_a, &a, 1, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 4);
}

/// Same dims, same nnz, different content: the old (dims, nnz)-shaped
/// fingerprint would silently reuse the stale partition; the content
/// revision makes that impossible.
#[test]
fn same_shape_different_content_cannot_reuse_stale_state() {
    let a = planted(4, vec![20, 20, 20], 2500);
    let b = planted(5, vec![20, 20, 20], 2500); // identical shape, new content

    let mut engine = ParallelFastTucker::new(engine_opts());
    let mut rng = Rng::new(8);
    let mut model = TuckerModel::init_kruskal(&mut rng, a.dims(), 4, 4);
    engine.train_epoch(&mut model, &a, 0, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 1);
    engine.train_epoch(&mut model, &b, 1, &mut rng).unwrap();
    assert_eq!(
        engine.rebuilds().partition,
        2,
        "fresh tensor with identical (dims, nnz) must still rebuild"
    );
    // Re-running on the same tensor object reuses cleanly.
    engine.train_epoch(&mut model, &b, 2, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 2);
}

/// The streaming acceptance pin: after an append, the next exact-mode
/// epoch through the long-lived engine is bitwise-identical to a fresh
/// engine run on the merged tensor (same model snapshot, same rng, same
/// epoch index) — the revision-keyed caches leave no stale state behind.
#[test]
fn post_append_epoch_is_bitwise_identical_to_fresh_engine_on_merged_tensor() {
    let spec = PlantedSpec {
        dims: vec![25, 22, 18],
        nnz: 4000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut gen_rng = Rng::new(11);
    let p = planted_tucker(&mut gen_rng, &spec);
    let mut sim = ArrivalSim::from_planted(&p, &spec);
    let mut train = p.tensor.clone();

    let mut engine = ParallelFastTucker::new(engine_opts());
    let mut rng = Rng::new(12);
    let mut model = TuckerModel::init_kruskal(&mut rng, train.dims(), 4, 4);
    engine.train_epoch(&mut model, &train, 0, &mut rng).unwrap();

    // Append at the epoch boundary.
    let batch = sim.next_batch(&mut gen_rng, 600);
    train.append_tensor(&batch).unwrap();

    // Snapshot, then run the post-append epoch through the live engine.
    let mut model_fresh = model.clone();
    let mut rng_fresh = rng.clone();
    engine.train_epoch(&mut model, &train, 1, &mut rng).unwrap();
    assert_eq!(engine.rebuilds().partition, 2, "append must rebuild the partition");

    // A brand-new engine over the merged tensor must land on the same bits.
    let mut fresh = ParallelFastTucker::new(engine_opts());
    fresh
        .train_epoch(&mut model_fresh, &train, 1, &mut rng_fresh)
        .unwrap();
    assert_models_bitwise(&model, &model_fresh, "post-append epoch");
}

/// Warm-start beats cold: after an append, resuming from the live
/// factors reaches the cold-retrain RMSE in fewer epochs than the cold
/// run took — and the serving cache invalidates exactly once per
/// train_epochs call, never on append.
#[test]
fn warm_start_reaches_cold_rmse_in_fewer_epochs() {
    let spec = PlantedSpec {
        dims: vec![30, 26, 22],
        nnz: 8000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut cfg = TrainConfig::default();
    cfg.engine = EngineKind::Parallel;
    cfg.workers = 2;
    cfg.j = 4;
    cfg.r_core = 4;
    cfg.hyper.lr_factor = LrSchedule::constant(0.02);
    cfg.hyper.lr_core = LrSchedule::constant(0.01);

    let mut rng = Rng::new(21);
    let p = planted_tucker(&mut rng, &spec);
    let (base_train, test) = train_test_split(&p.tensor, 0.1, &mut rng);
    let mut sim = ArrivalSim::from_planted(&p, &spec);

    // Warm session: train on the base data, serve, then stream appends.
    let mut warm = Session::new(&cfg, base_train, test.clone(), 16, &mut rng).unwrap();
    warm.set_verbose(false);
    let base_epochs = 10usize;
    warm.train_epochs(base_epochs).unwrap();
    let q = Query { coords: vec![3, 0, 5], candidate_mode: 1, candidates: (0..26).collect() };
    warm.top_k(&q, 5);
    warm.top_k(&q, 5);
    let c0 = warm.cache_counters();
    assert_eq!((c0.hits, c0.misses, c0.invalidations), (1, 1, 0));

    let mut arrival_rng = Rng::new(22);
    for _ in 0..2 {
        let batch = sim.next_batch(&mut arrival_rng, 400);
        warm.append(&batch).unwrap();
    }
    // Appends alone must not touch the serving cache.
    warm.top_k(&q, 5);
    assert_eq!(warm.cache_counters().invalidations, 0);

    // Cold baseline: a fresh session over the merged tensor, trained
    // from scratch for the same budget as the warm session's base run.
    let merged = warm.train_tensor().clone();
    let mut cold_rng = Rng::new(23);
    let mut cold = Session::new(&cfg, merged, test, 16, &mut cold_rng).unwrap();
    cold.set_verbose(false);
    cold.train_epochs(base_epochs).unwrap();
    let (cold_rmse, _) = cold.evaluate();

    // Warm start: resume from the live factors, one epoch at a time.
    let mut warm_epochs = 0usize;
    while warm_epochs < base_epochs {
        warm.train_epochs(1).unwrap();
        warm_epochs += 1;
        if warm.evaluate().0 <= cold_rmse {
            break;
        }
    }
    assert!(
        warm_epochs < base_epochs,
        "warm start took {warm_epochs} epochs to reach cold rmse {cold_rmse:.5} \
         (cold took {base_epochs})"
    );
    // Each train_epochs call moved the model: the serving cache must
    // have invalidated on the first post-training lookup each time.
    warm.top_k(&q, 5);
    let c1 = warm.cache_counters();
    assert_eq!(c1.invalidations, 1, "one invalidation per model move observed");
    assert_eq!(warm.epochs_run(), base_epochs + warm_epochs);
}
