//! Cross-module integration tests: dataset registry → trainer → engines →
//! eval → checkpoint, including algorithm-equivalence and recovery tests
//! that span the whole stack.

use fasttucker::algo::{CuTucker, Decomposer, FastTucker, PTucker, SgdTucker, Vest};
use fasttucker::config::{AlgoKind, EngineKind, TrainConfig};
use fasttucker::coordinator::Trainer;
use fasttucker::data::split::train_test_split;
use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::data::Dataset;
use fasttucker::kruskal::reconstruct::{rmse, rmse_mae};
use fasttucker::model::{CoreRepr, TuckerModel};
use fasttucker::parallel::{Execution, ParallelFastTucker, ParallelOptions};
use fasttucker::sched::LrSchedule;
use fasttucker::util::Rng;

fn planted_3d(seed: u64, nnz: usize) -> (fasttucker::SparseTensor, PlantedSpec) {
    let spec = PlantedSpec {
        dims: vec![40, 35, 30],
        nnz,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut rng = Rng::new(seed);
    (planted_tucker(&mut rng, &spec).tensor, spec)
}

#[test]
fn full_pipeline_fasttucker_recovers_planted_signal() {
    let (tensor, spec) = planted_3d(1, 10_000);
    let mut rng = Rng::new(2);
    let (train, test) = train_test_split(&tensor, 0.1, &mut rng);

    let mut cfg = TrainConfig::default();
    cfg.algo = AlgoKind::FastTucker;
    cfg.j = spec.j;
    cfg.r_core = spec.r_core;
    cfg.epochs = 80;
    cfg.hyper.lr_factor = LrSchedule::new(0.008, 0.005);
    cfg.hyper.lr_core = LrSchedule::new(0.004, 0.01);
    cfg.hyper.lambda_factor = 1e-4;
    cfg.hyper.lambda_core = 1e-4;

    let dims = tensor.dims().to_vec();
    let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
    trainer.opts.verbose = false;
    let report = trainer.train(&mut model, &train, &test, &mut rng).unwrap();

    // Test RMSE approaches the noise floor — signal, not memorization.
    let final_rmse = report.final_rmse();
    // Vanilla SGD's tail convergence is slow; "recovered the signal"
    // here means the held-out error is a small multiple of the noise
    // floor and a small fraction of the initial error.
    assert!(
        final_rmse < 7.0 * spec.noise as f64,
        "held-out rmse {final_rmse} vs noise {}",
        spec.noise
    );
    assert!(final_rmse < 0.3 * report.history[0].rmse);
}

#[test]
fn serial_and_parallel_fasttucker_reach_similar_accuracy() {
    let (tensor, spec) = planted_3d(3, 12_000);
    let run_serial = || {
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = LrSchedule::constant(0.02);
        algo.config.hyper.lr_core = LrSchedule::constant(0.01);
        for e in 0..15 {
            algo.train_epoch(&mut model, &tensor, e, &mut rng).unwrap();
        }
        rmse(&model, &tensor)
    };
    let run_parallel = |workers| {
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = workers;
        opts.hyper.lr_factor = LrSchedule::constant(0.02);
        opts.hyper.lr_core = LrSchedule::constant(0.01);
        let mut engine = ParallelFastTucker::new(opts);
        for e in 0..15 {
            engine.train_epoch(&mut model, &tensor, e, &mut rng).unwrap();
        }
        rmse(&model, &tensor)
    };
    let serial = run_serial();
    for workers in [2usize, 3] {
        let par = run_parallel(workers);
        assert!(
            (par - serial).abs() < 0.35 * serial.max(0.05),
            "workers {workers}: parallel rmse {par} vs serial {serial}"
        );
    }
}

#[test]
fn all_five_algorithms_agree_on_easy_problem() {
    // Every method should fit an easy low-noise planted problem; their
    // final RMSEs land in the same ballpark (the paper's Fig. 6 claim:
    // "all the methods can obtain the same overall accuracy").
    let (tensor, spec) = planted_3d(5, 15_000);
    let mut rng = Rng::new(6);
    let (train, test) = train_test_split(&tensor, 0.1, &mut rng);

    let mut results: Vec<(&str, f64)> = Vec::new();

    // FastTucker (Kruskal core).
    {
        let mut rng = Rng::new(7);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 4, 4);
        let mut a = FastTucker::with_defaults();
        a.config.hyper.lr_factor = LrSchedule::constant(0.02);
        a.config.hyper.lr_core = LrSchedule::constant(0.01);
        a.config.hyper.lambda_factor = 1e-4;
        a.config.hyper.lambda_core = 1e-4;
        for e in 0..30 {
            a.train_epoch(&mut model, &train, e, &mut rng).unwrap();
        }
        results.push(("fasttucker", rmse_mae(&model, &test).0));
    }
    // Dense-core SGD methods.
    {
        let mut rng = Rng::new(7);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, 4);
        let mut a = CuTucker::with_defaults();
        a.hyper.lr_factor = LrSchedule::constant(0.02);
        a.hyper.lr_core = LrSchedule::constant(0.01);
        a.hyper.lambda_factor = 1e-4;
        a.hyper.lambda_core = 1e-4;
        for e in 0..30 {
            a.train_epoch(&mut model, &train, e, &mut rng).unwrap();
        }
        results.push(("cutucker", rmse_mae(&model, &test).0));
    }
    {
        let mut rng = Rng::new(7);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, 4);
        let mut a = SgdTucker::with_defaults();
        a.hyper.lr_factor = LrSchedule::constant(0.02);
        a.hyper.lr_core = LrSchedule::constant(0.01);
        a.hyper.lambda_factor = 1e-4;
        a.hyper.lambda_core = 1e-4;
        for e in 0..30 {
            a.train_epoch(&mut model, &train, e, &mut rng).unwrap();
        }
        results.push(("sgd_tucker", rmse_mae(&model, &test).0));
    }
    // ALS / CCD with the true core handed over (they don't learn cores).
    {
        let mut rng = Rng::new(8);
        let p = {
            let mut prng = Rng::new(5);
            planted_tucker(&mut prng, &spec)
        };
        let mut model = TuckerModel {
            factors: fasttucker::model::factors::FactorMatrices::random(
                &mut rng, &spec.dims, 4, 0.5,
            ),
            core: CoreRepr::Dense(p.truth_core.to_dense()),
        };
        let mut a = PTucker::with_defaults();
        for e in 0..6 {
            a.train_epoch(&mut model, &train, e, &mut rng).unwrap();
        }
        results.push(("ptucker", rmse_mae(&model, &test).0));

        let mut model2 = TuckerModel {
            factors: fasttucker::model::factors::FactorMatrices::random(
                &mut rng, &spec.dims, 4, 0.5,
            ),
            core: CoreRepr::Dense(p.truth_core.to_dense()),
        };
        let mut v = Vest::with_defaults();
        for e in 0..10 {
            v.train_epoch(&mut model2, &train, e, &mut rng).unwrap();
        }
        results.push(("vest", rmse_mae(&model2, &test).0));
    }

    eprintln!("final test RMSEs: {results:?}");
    for (name, r) in &results {
        assert!(*r < 0.5, "{name} failed to fit: rmse {r}");
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let (tensor, spec) = planted_3d(9, 6000);
    let mut rng = Rng::new(10);
    let (train, test) = train_test_split(&tensor, 0.1, &mut rng);
    let mut cfg = TrainConfig::default();
    cfg.j = spec.j;
    cfg.r_core = spec.r_core;
    cfg.epochs = 5;
    let dims = tensor.dims().to_vec();
    let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
    trainer.opts.verbose = false;
    trainer.train(&mut model, &train, &test, &mut rng).unwrap();

    let dir = std::env::temp_dir().join("fasttucker_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trained.ftck");
    fasttucker::model::checkpoint::save(&model, &path).unwrap();
    let loaded = fasttucker::model::checkpoint::load(&path).unwrap();
    let (r1, m1) = rmse_mae(&model, &test);
    let (r2, m2) = rmse_mae(&loaded, &test);
    assert!((r1 - r2).abs() < 1e-9);
    assert!((m1 - m2).abs() < 1e-9);
    std::fs::remove_file(&path).ok();
}

#[test]
fn registry_datasets_train_without_panic() {
    // Smoke: every registry dataset at small scale goes through one epoch
    // of the default trainer.
    for name in ["tiny", "small", "synth-order3", "synth-order5"] {
        let mut rng = Rng::new(11);
        let tensor = Dataset::by_name(name, 0.05).unwrap().build(&mut rng).unwrap();
        let (train, test) = train_test_split(&tensor, 0.1, &mut rng);
        let mut cfg = TrainConfig::default();
        cfg.epochs = 1;
        cfg.j = 4;
        cfg.r_core = 4;
        let dims = tensor.dims().to_vec();
        let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
        trainer.opts.verbose = false;
        trainer.train(&mut model, &train, &test, &mut rng).unwrap();
    }
}

#[test]
fn pjrt_engine_matches_native_engine_numerically() {
    // The AOT JAX/Pallas path and the native Rust path implement the same
    // math; with the same sample order (sample_frac 1.0, same rng) and
    // batch semantics they should land at similar accuracy.
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.tsv").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = PlantedSpec {
        dims: vec![60, 50, 40],
        nnz: 20_000,
        j: 8,
        r_core: 8,
        noise: 0.05,
        clamp: None,
    };
    let mut rng = Rng::new(12);
    let tensor = planted_tucker(&mut rng, &spec).tensor;

    let mut cfg = TrainConfig::default();
    cfg.j = 8;
    cfg.r_core = 8;
    cfg.epochs = 8;
    cfg.hyper.lr_factor = LrSchedule::constant(0.02);
    cfg.hyper.lr_core = LrSchedule::constant(0.01);
    cfg.hyper.lambda_factor = 1e-4;
    cfg.hyper.lambda_core = 1e-4;
    cfg.artifacts_dir = artifacts.to_string_lossy().to_string();
    cfg.pjrt_batch_cap = Some(256); // small workload: see engine.rs scatter note

    let run = |engine: EngineKind| {
        let mut cfg = cfg.clone();
        cfg.engine = engine;
        let mut rng = Rng::new(13);
        let dims = tensor.dims().to_vec();
        let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
        trainer.opts.verbose = false;
        let (train, test) = {
            let mut srng = Rng::new(14);
            train_test_split(&tensor, 0.1, &mut srng)
        };
        let report = trainer.train(&mut model, &train, &test, &mut rng).unwrap();
        report.final_rmse()
    };
    let native = run(EngineKind::Native);
    let pjrt = run(EngineKind::Pjrt);
    eprintln!("native={native:.5} pjrt={pjrt:.5}");
    assert!(
        (native - pjrt).abs() < 0.3 * native.max(0.05),
        "native {native} vs pjrt {pjrt}"
    );
}

#[test]
fn split_group_training_trajectories_on_hollow_workload() {
    // ISSUE 3 satellite: seeded end-to-end train on a hollow workload,
    // comparing serial-exact, parallel-split-exact, and relaxed paths.
    // The exact parallel paths (split vs unsplit) must be EQUAL — same
    // per-epoch loss trajectory and bitwise-identical factors — because
    // exact split-group cuts land on fiber sub-run boundaries; serial
    // and relaxed agree within tolerance.
    let spec = PlantedSpec {
        dims: vec![2000, 300, 300],
        nnz: 8000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: Some((1.0, 5.0)),
    };
    let mut prng = Rng::new(61);
    let tensor = planted_tucker(&mut prng, &spec).tensor;

    let run_parallel = |exactness: fasttucker::kernel::Exactness, split: usize| {
        let mut rng = Rng::new(62);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.exactness = exactness;
        opts.split = split;
        opts.hyper.lr_factor = LrSchedule::constant(0.01);
        opts.hyper.lr_core = LrSchedule::constant(0.005);
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(63);
        let mut trajectory = Vec::new();
        for epoch in 0..8 {
            engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
            trajectory.push(rmse(&model, &tensor));
        }
        (model, trajectory, engine.plan_accum)
    };

    let (m_unsplit, traj_unsplit, acc_unsplit) =
        run_parallel(fasttucker::kernel::Exactness::Exact, 1);
    let (m_split, traj_split, acc_split) =
        run_parallel(fasttucker::kernel::Exactness::Exact, 64);
    assert_eq!(acc_unsplit.splits, 0);
    assert!(acc_split.splits > 0, "split rule never engaged: {acc_split:?}");
    for (e, (a, b)) in traj_unsplit.iter().zip(traj_split.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: exact split trajectory diverged ({a} vs {b})"
        );
    }
    for n in 0..3 {
        for (a, b) in m_unsplit
            .factors
            .mat(n)
            .data()
            .iter()
            .zip(m_split.factors.mat(n).data().iter())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
        }
    }

    // Serial exact (planner-batched) on the same data: different sample
    // order, same accuracy ballpark, and both must actually descend.
    let serial_final = {
        let mut rng = Rng::new(62);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_auto_batch();
        algo.config.hyper.lr_factor = LrSchedule::constant(0.01);
        algo.config.hyper.lr_core = LrSchedule::constant(0.005);
        let mut rng2 = Rng::new(63);
        let before = rmse(&model, &tensor);
        for epoch in 0..8 {
            algo.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
        }
        let after = rmse(&model, &tensor);
        assert!(after < before, "serial path failed to descend");
        after
    };
    let split_final = *traj_split.last().unwrap();
    assert!(split_final < traj_split[0] * 1.0001, "parallel path failed to descend");
    assert!(
        (serial_final - split_final).abs() < 0.35 * serial_final.max(0.05),
        "serial {serial_final} vs parallel-split {split_final}"
    );

    // Relaxed (hogwild) split path: within tolerance of the exact path.
    let (_m_rel, traj_rel, _acc) = run_parallel(fasttucker::kernel::Exactness::Relaxed, 64);
    let relaxed_final = *traj_rel.last().unwrap();
    assert!(
        (relaxed_final - split_final).abs() < 0.10 * split_final.max(0.05),
        "relaxed {relaxed_final} vs exact {split_final}"
    );
}

#[test]
fn threaded_training_trajectories_on_hollow_workload() {
    // ISSUE 4 acceptance, end to end: (1) exact-mode in-group threading
    // leaves the multi-epoch parallel-engine trajectory (per-epoch RMSE
    // and final factors) bitwise identical to sequential dispatch;
    // (2) threaded relaxed (hogwild waves racing inside each Latin
    // worker) stays within the 2% RMSE envelope of the exact path —
    // PR 2's relaxed contract, now under real intra-worker concurrency.
    let spec = PlantedSpec {
        dims: vec![2400, 100, 100],
        nnz: 7200,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: Some((1.0, 5.0)),
    };
    let mut prng = Rng::new(91);
    let tensor = planted_tucker(&mut prng, &spec).tensor;

    let run = |exactness: fasttucker::kernel::Exactness, threads: usize| {
        let mut rng = Rng::new(92);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.exactness = exactness;
        opts.split = 8;
        opts.threads = fasttucker::kernel::ThreadCount::Fixed(threads);
        opts.hyper.lr_factor = LrSchedule::constant(0.01);
        opts.hyper.lr_core = LrSchedule::constant(0.005);
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(93);
        let mut trajectory = Vec::new();
        // 30 epochs: far enough into convergence that the 2% relaxed
        // envelope is meaningful (matches relaxed_reaches_exact_quality).
        for epoch in 0..30 {
            engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
            trajectory.push(rmse(&model, &tensor));
        }
        (model, trajectory, engine.plan_accum)
    };

    // Exact: threaded trajectory bitwise-identical to sequential.
    let (m_seq, traj_seq, acc_seq) = run(fasttucker::kernel::Exactness::Exact, 1);
    let (m_thr, traj_thr, acc_thr) = run(fasttucker::kernel::Exactness::Exact, 2);
    assert_eq!(acc_seq.threads, 1);
    assert_eq!(acc_thr.threads, 2, "pool never engaged: {acc_thr:?}");
    assert!(acc_thr.waves > 0, "coloring never ran: {acc_thr:?}");
    for (e, (a, b)) in traj_seq.iter().zip(traj_thr.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: threaded exact trajectory diverged ({a} vs {b})"
        );
    }
    for n in 0..3 {
        for (a, b) in m_seq
            .factors
            .mat(n)
            .data()
            .iter()
            .zip(m_thr.factors.mat(n).data().iter())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
        }
    }

    // Relaxed threaded: hogwild waves stay inside the 2% RMSE envelope.
    // The run is genuinely nondeterministic (real 2-thread racing), so a
    // single pathological interleaving gets one retry before failing —
    // the envelope is a distributional contract, not a bitwise one.
    let exact_final = *traj_thr.last().unwrap();
    let envelope = exact_final * 1.02 + 1e-4;
    let mut relaxed_final = f64::INFINITY;
    for attempt in 0..2 {
        let (_m_rel, traj_rel, acc_rel) = run(fasttucker::kernel::Exactness::Relaxed, 2);
        assert_eq!(acc_rel.threads, 2, "relaxed pool never engaged: {acc_rel:?}");
        assert!(
            *traj_rel.last().unwrap() < traj_rel[0],
            "threaded relaxed failed to descend: {traj_rel:?}"
        );
        relaxed_final = *traj_rel.last().unwrap();
        if relaxed_final <= envelope {
            break;
        }
        eprintln!(
            "threaded relaxed attempt {attempt}: RMSE {relaxed_final} above envelope \
             {envelope}, retrying once (hogwild interleaving variance)"
        );
    }
    assert!(
        relaxed_final <= envelope,
        "threaded relaxed RMSE {relaxed_final} not within 2% of exact {exact_final} \
         after retry"
    );
}

#[test]
fn sharded_relaxed_training_stays_inside_the_accuracy_envelope() {
    // ISSUE 5 satellite (relaxed leg): on a device grid, relaxed mode
    // swaps the flat Eq. 17 fold for the two-stage device tree and sizes
    // plans per shard — no bitwise contract, but the trained quality
    // must stay within the established 2% RMSE envelope of the exact
    // path at every device count, and must actually descend.
    let spec = PlantedSpec {
        dims: vec![2400, 100, 100],
        nnz: 7200,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: Some((1.0, 5.0)),
    };
    let mut prng = Rng::new(121);
    let tensor = planted_tucker(&mut prng, &spec).tensor;
    let run = |exactness: fasttucker::kernel::Exactness, devices: usize| {
        let mut rng = Rng::new(122);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = fasttucker::parallel::DeviceCount::Fixed(devices);
        opts.exactness = exactness;
        // Pin the in-group pool off so the relaxed runs stay
        // deterministic under CI's FASTTUCKER_POOL_THREADS=2 leg (the
        // envelope is a single-sample assertion here).
        opts.threads = fasttucker::kernel::ThreadCount::Fixed(1);
        opts.hyper.lr_factor = LrSchedule::constant(0.01);
        opts.hyper.lr_core = LrSchedule::constant(0.005);
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(123);
        let mut trajectory = Vec::new();
        // 30 epochs: far enough into convergence that the 2% envelope is
        // meaningful (matches relaxed_reaches_exact_quality).
        for epoch in 0..30 {
            engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
            trajectory.push(rmse(&model, &tensor));
        }
        trajectory
    };
    let exact = run(fasttucker::kernel::Exactness::Exact, 1);
    let exact_final = *exact.last().unwrap();
    for devices in [1usize, 2, 4] {
        let traj = run(fasttucker::kernel::Exactness::Relaxed, devices);
        let relaxed_final = *traj.last().unwrap();
        assert!(relaxed_final < traj[0], "D={devices}: relaxed failed to descend");
        assert!(
            relaxed_final <= exact_final * 1.02 + 1e-4,
            "D={devices}: relaxed RMSE {relaxed_final} not within 2% of exact \
             {exact_final}"
        );
    }
}

#[test]
fn checkpoint_resume_on_device_grid_matches_uninterrupted_run() {
    // ISSUE 5 satellite: save/load mid-training on a D = 3 grid must
    // resume to the same trajectory as an uninterrupted run — exact
    // mode, bitwise (factors, core, and the post-resume RMSE curve). The
    // engine is rebuilt from scratch after the load, so the test also
    // pins that no hidden engine state (partition, grid, pools, planner
    // caches, gradient accumulators) leaks across the epoch boundary.
    let spec = PlantedSpec {
        dims: vec![60, 45, 45],
        nnz: 8000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut prng = Rng::new(131);
    let tensor = planted_tucker(&mut prng, &spec).tensor;
    let make_engine = || {
        let mut opts = ParallelOptions::default();
        opts.workers = 3;
        opts.devices = fasttucker::parallel::DeviceCount::Fixed(3);
        opts.hyper.lr_factor = LrSchedule::constant(0.02);
        opts.hyper.lr_core = LrSchedule::constant(0.01);
        ParallelFastTucker::new(opts)
    };

    // Uninterrupted: 6 epochs through one engine.
    let mut rng = Rng::new(132);
    let mut continuous = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
    let mut engine = make_engine();
    let mut rng2 = Rng::new(133);
    let mut cont_traj = Vec::new();
    for epoch in 0..6 {
        engine.train_epoch(&mut continuous, &tensor, epoch, &mut rng2).unwrap();
        cont_traj.push(rmse(&continuous, &tensor));
    }

    // Interrupted: 3 epochs, checkpoint to disk, reload into a FRESH
    // engine, 3 more epochs continuing the same RNG stream.
    let mut rng = Rng::new(132);
    let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
    let mut engine = make_engine();
    let mut rng2 = Rng::new(133);
    let mut resumed_traj = Vec::new();
    for epoch in 0..3 {
        engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
        resumed_traj.push(rmse(&model, &tensor));
    }
    let dir = std::env::temp_dir().join("fasttucker_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sharded_mid_train.ftck");
    fasttucker::model::checkpoint::save(&model, &path).unwrap();
    let mut resumed = fasttucker::model::checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut engine = make_engine();
    for epoch in 3..6 {
        engine.train_epoch(&mut resumed, &tensor, epoch, &mut rng2).unwrap();
        resumed_traj.push(rmse(&resumed, &tensor));
    }

    for (e, (a, b)) in cont_traj.iter().zip(resumed_traj.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: resumed trajectory diverged ({a} vs {b})"
        );
    }
    for n in 0..3 {
        for (a, b) in continuous
            .factors
            .mat(n)
            .data()
            .iter()
            .zip(resumed.factors.mat(n).data().iter())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged after resume");
        }
    }
    let (ck, cr) = match (&continuous.core, &resumed.core) {
        (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
        _ => unreachable!(),
    };
    for n in 0..3 {
        for (a, b) in ck.factor(n).data().iter().zip(cr.factor(n).data().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core mode {n} diverged after resume");
        }
    }
}

#[test]
fn threads_and_simulated_execution_identical() {
    let spec = PlantedSpec {
        dims: vec![30, 30, 30],
        nnz: 5000,
        j: 4,
        r_core: 4,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(15);
    let tensor = planted_tucker(&mut rng, &spec).tensor;
    let run = |execution| {
        let mut rng = Rng::new(16);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 4, 4);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.execution = execution;
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(17);
        for e in 0..3 {
            engine.train_epoch(&mut model, &tensor, e, &mut rng2).unwrap();
        }
        rmse(&model, &tensor)
    };
    let a = run(Execution::Threads);
    let b = run(Execution::Simulated);
    assert!((a - b).abs() < 1e-12, "{a} vs {b}");
}

#[test]
fn elastic_recovery_after_device_death_resumes_bitwise_on_new_grid() {
    // ISSUE 7 tentpole e2e: a device dies mid-epoch on a channel grid →
    // train_epoch surfaces a typed DeviceDead error (no silent
    // corruption, no panic); reloading the last checkpoint into a FRESH
    // engine re-sharded to a DIFFERENT device count and resuming at the
    // same epoch indices is bitwise-equal to a never-interrupted run —
    // elastic recovery rides on the grid's device-count invariance.
    use fasttucker::algo::AlgoError;
    use fasttucker::parallel::{
        DeviceCount, FaultKinds, FaultPlan, KillSpec, TransportError, TransportKind,
    };

    let spec = PlantedSpec {
        dims: vec![60, 45, 45],
        nnz: 8000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut prng = Rng::new(171);
    let tensor = planted_tucker(&mut prng, &spec).tensor;
    let make_engine = |devices: usize, fault: Option<FaultPlan>| {
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = DeviceCount::Fixed(devices);
        opts.transport = TransportKind::Channel;
        opts.fault = fault;
        opts.hyper.lr_factor = LrSchedule::constant(0.02);
        opts.hyper.lr_core = LrSchedule::constant(0.01);
        ParallelFastTucker::new(opts)
    };

    // Phase 1: two healthy epochs on a D = 2 channel grid, then
    // checkpoint model + RNG position.
    let mut rng = Rng::new(172);
    let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
    let mut engine = make_engine(2, None);
    let mut rng2 = Rng::new(173);
    for epoch in 0..2 {
        engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
    }
    let dir = std::env::temp_dir().join("fasttucker_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("elastic_kill.ftck");
    fasttucker::model::checkpoint::save(&model, &path).unwrap();
    let rng_at_ckpt = rng2.clone();

    // Reference: uninterrupted continuation, same D = 2 grid.
    let mut reference = fasttucker::model::checkpoint::load(&path).unwrap();
    let mut engine = make_engine(2, None);
    let mut rng2 = rng_at_ckpt.clone();
    let mut ref_traj = Vec::new();
    for epoch in 2..4 {
        engine.train_epoch(&mut reference, &tensor, epoch, &mut rng2).unwrap();
        ref_traj.push(rmse(&reference, &tensor));
    }

    // The failure: device 1 is killed mid-epoch. The epoch must surface
    // the named DeviceDead error from train_epoch.
    let mut victim = fasttucker::model::checkpoint::load(&path).unwrap();
    let kill = FaultPlan {
        seed: 1,
        rate: 0.0,
        kinds: FaultKinds::NONE,
        kill: Some(KillSpec { device: 1, after_sends: 3 }),
    };
    let mut engine = make_engine(2, Some(kill));
    let mut rng2 = rng_at_ckpt.clone();
    let err = engine.train_epoch(&mut victim, &tensor, 2, &mut rng2).unwrap_err();
    assert!(
        matches!(
            err,
            AlgoError::Transport(TransportError::DeviceDead { device: 1 })
        ),
        "expected DeviceDead for device 1, got {err:?}"
    );

    // Elastic recovery: reload the checkpoint into a fresh engine
    // re-sharded to D = 3 (the dead device's capacity is gone) and
    // resume at the same epoch indices.
    let mut recovered = fasttucker::model::checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut engine = make_engine(3, None);
    let mut rng2 = rng_at_ckpt;
    let mut rec_traj = Vec::new();
    for epoch in 2..4 {
        engine.train_epoch(&mut recovered, &tensor, epoch, &mut rng2).unwrap();
        rec_traj.push(rmse(&recovered, &tensor));
    }

    for (i, (a, b)) in ref_traj.iter().zip(rec_traj.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {}: recovered trajectory diverged ({a} vs {b})",
            i + 2
        );
    }
    for n in 0..3 {
        for (a, b) in reference
            .factors
            .mat(n)
            .data()
            .iter()
            .zip(recovered.factors.mat(n).data().iter())
        {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "mode {n} factors diverged after elastic recovery"
            );
        }
    }
    let (ck, cr) = match (&reference.core, &recovered.core) {
        (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
        _ => unreachable!(),
    };
    for n in 0..3 {
        for (a, b) in ck.factor(n).data().iter().zip(cr.factor(n).data().iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "core mode {n} diverged after elastic recovery"
            );
        }
    }
}

#[test]
fn transport_soak_long_run() {
    // ISSUE 8 satellite: long-run transport soak. The dedup-window bug
    // this PR fixes only bit once the per-peer sequence stream had
    // wrapped far past the window (early frames' seqs slid below the
    // floor and fresh frames were misjudged as duplicates), so this
    // test pins the fix at soak length: a deliberately tiny window
    // (floored to 2 by the Exchanger) under MANY times that window's
    // worth of frames, across enough epochs that every peer pair wraps
    // repeatedly. A healthy channel under that pressure must report
    // zero faults, drop nothing, and stay bitwise-identical to the
    // direct handover — both with the synchronous exchange and with
    // async double-buffered prefetch on top. (Skipped in the chaos CI
    // leg: injected faults falsify the zero-fault assertions; the
    // faulted-channel soak lives in the fault-matrix property test.)
    use fasttucker::parallel::{DeviceCount, PrefetchMode, TransportKind};

    let spec = PlantedSpec {
        dims: vec![50, 40, 40],
        nnz: 6000,
        j: 4,
        r_core: 4,
        noise: 0.05,
        clamp: None,
    };
    let mut prng = Rng::new(241);
    let tensor = planted_tucker(&mut prng, &spec).tensor;
    const WINDOW: usize = 4;
    const EPOCHS: usize = 12;
    let run = |transport: TransportKind, prefetch: PrefetchMode| {
        let mut rng = Rng::new(242);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = DeviceCount::Fixed(2);
        opts.transport = transport;
        opts.prefetch = prefetch;
        opts.dedup_window = Some(WINDOW);
        opts.hyper.lr_factor = LrSchedule::constant(0.02);
        opts.hyper.lr_core = LrSchedule::constant(0.01);
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(243);
        let mut trajectory = Vec::new();
        for epoch in 0..EPOCHS {
            engine.train_epoch(&mut model, &tensor, epoch, &mut rng2).unwrap();
            trajectory.push(rmse(&model, &tensor));
        }
        (model, trajectory, engine.plan_accum)
    };

    let (direct, dtraj, _) = run(TransportKind::Direct, PrefetchMode::Off);
    let (sync, straj, sacc) = run(TransportKind::Channel, PrefetchMode::Off);
    let (asy, atraj, aacc) = run(TransportKind::Channel, PrefetchMode::Async);

    for (label, acc) in [("sync", &sacc), ("async", &aacc)] {
        // Soak pressure: the stream must wrap the window many times over,
        // and a healthy channel under that pressure reports nothing.
        assert!(
            acc.frames_sent as usize > 10 * WINDOW,
            "{label}: soak too short to wrap the dedup window \
             ({} frames vs window {WINDOW})",
            acc.frames_sent
        );
        assert_eq!(
            acc.frames_delivered, acc.frames_sent,
            "{label}: healthy soak dropped frames"
        );
        assert_eq!(acc.transport_faults(), 0, "{label}: healthy soak reported faults");
        assert_eq!(acc.degraded, 0, "{label}: healthy soak degraded");
    }
    assert_eq!(sacc.prefetch_issued, 0, "sync soak must not prefetch");
    assert!(aacc.prefetch_issued > 0, "async soak never prefetched");

    for (e, ((a, b), c)) in dtraj.iter().zip(straj.iter()).zip(atraj.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "epoch {e}: sync soak trajectory diverged");
        assert_eq!(a.to_bits(), c.to_bits(), "epoch {e}: async soak trajectory diverged");
    }
    for n in 0..3 {
        let d = direct.factors.mat(n).data();
        for ((a, b), c) in d
            .iter()
            .zip(sync.factors.mat(n).data().iter())
            .zip(asy.factors.mat(n).data().iter())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "mode {n}: sync soak factors diverged");
            assert_eq!(a.to_bits(), c.to_bits(), "mode {n}: async soak factors diverged");
        }
    }
    let (dk, sk, ak) = match (&direct.core, &sync.core, &asy.core) {
        (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b), CoreRepr::Kruskal(c)) => (a, b, c),
        _ => unreachable!(),
    };
    for n in 0..3 {
        for ((a, b), c) in dk
            .factor(n)
            .data()
            .iter()
            .zip(sk.factor(n).data().iter())
            .zip(ak.factor(n).data().iter())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "core mode {n}: sync soak diverged");
            assert_eq!(a.to_bits(), c.to_bits(), "core mode {n}: async soak diverged");
        }
    }
}
