//! Kernel microbenches (perf-pass instrumentation, EXPERIMENTS.md §Perf):
//! * the Thm-1/2 contraction throughput (samples/sec) vs (J, R_core),
//!   Packed vs Strided;
//! * **batched vs scalar kernel** — one full pass over a *tall* and a
//!   *hollow* synthetic tensor through `kernel::batched`
//!   (scalar / single-fiber / planner-tiled / relaxed-hogwild plans) vs
//!   `kernel::scalar` over the identical sample order, with plan
//!   observability (mean group length, fibers per group, occupancy); the
//!   acceptance bar is the batched path beating scalar on BOTH shapes —
//!   on hollow tensors only fiber tiling gets it there;
//! * PJRT `train_step` batch execution vs the native batch loop;
//! * evaluation throughput.
//!
//! Flags (after `--` with `cargo bench --bench bench_kernels`):
//! * `--quick` — CI smoke mode: only the batched-vs-scalar sweep at a
//!   reduced scale (unless `FASTTUCKER_BENCH_SCALE` overrides).
//! * `--json PATH` — write the batched-vs-scalar sweep as a
//!   `BENCH_kernels.json` throughput snapshot (the perf-trajectory
//!   artifact CI uploads).
//! * `--check PATH` — bench-regression gate: compare this run's
//!   `speedup_vs_scalar` per pinned `(workload, path, cap)` against the
//!   committed `BENCH_baseline.json`; exit 1 on a drop beyond
//!   `FASTTUCKER_BENCH_TOLERANCE` (default 0.15). Refresh the baseline
//!   with `--quick --json BENCH_baseline.json` when a change
//!   intentionally moves throughput (see `bench_support::regression`).

use std::time::Instant;

use fasttucker::algo::fasttucker::{build_strided, contract_staged, CoreLayout, Workspace};
use fasttucker::algo::SgdHyper;
use fasttucker::bench_support::{bench_scale, Table};
use fasttucker::coordinator::PjrtEngine;
use fasttucker::data::synth::{self, planted_tucker, PlantedSpec};
use fasttucker::bench_support::regression;
use fasttucker::kernel::{
    batched, planner, scalar, BatchPlan, BatchWorkspace, DispatchPool, Exactness, FiberStats,
    Lanes, PlanParams, SimdLevel,
};
use fasttucker::kruskal::KruskalCore;
use fasttucker::model::{CoreRepr, TuckerModel};
use fasttucker::parallel::shared::{SharedFactors, SharedRowAccess};
use fasttucker::util::Rng;

fn contraction_bench() {
    println!("\n== Thm-1/2 contraction throughput (order 3) ==");
    let mut table = Table::new(&["J", "R", "layout", "Msamples/sec", "ns/sample"]);
    let mut rng = Rng::new(1);
    for (j, r) in [(4usize, 4usize), (8, 8), (16, 16), (32, 32), (8, 32), (32, 8)] {
        let core = KruskalCore::random(&mut rng, 3, j, r, 0.5);
        let strided = build_strided(&core);
        let rows: Vec<f32> = (0..3 * j).map(|_| rng.normal()).collect();
        for layout in [CoreLayout::Packed, CoreLayout::Strided] {
            let mut ws = Workspace::new(3, r, j);
            for n in 0..3 {
                ws.stage_row(n, &rows[n * j..(n + 1) * j]);
            }
            let iters = 2_000_000 / (j * r / 16 + 1);
            let t0 = Instant::now();
            let mut acc = 0.0f32;
            for _ in 0..iters {
                acc += contract_staged(&mut ws, &core, &strided, layout, 1.0);
            }
            let secs = t0.elapsed().as_secs_f64();
            std::hint::black_box(acc);
            table.row(&[
                j.to_string(),
                r.to_string(),
                format!("{layout:?}"),
                format!("{:.2}", iters as f64 / secs / 1e6),
                format!("{:.0}", secs / iters as f64 * 1e9),
            ]);
        }
    }
    table.print();
}

/// One timed path of the batched-vs-scalar sweep.
struct PathResult {
    path: String,
    cap: Option<usize>,
    tile: Option<usize>,
    mean_group_len: f64,
    mean_fibers_per_group: f64,
    occupancy: f64,
    secs_per_pass: f64,
    msamples_per_sec: f64,
    speedup_vs_scalar: f64,
    /// In-group pool threads (1 for the sequential paths).
    threads: usize,
}

/// One workload of the sweep (what `--json` serializes).
struct WorkloadResult {
    name: String,
    dims: Vec<usize>,
    nnz: usize,
    mean_fiber_len: f64,
    paths: Vec<PathResult>,
}

fn run_workload(name: &str, dims: Vec<usize>, nnz: usize, reps: usize) -> WorkloadResult {
    let (j, r) = (16usize, 16usize);
    println!("\n== batched vs scalar kernel: {name} (full pass, J=R=16, dims {dims:?}, nnz {nnz}) ==");
    let mut rng = Rng::new(7);
    let tensor = synth::random_uniform(&mut rng, &dims, nnz, 1.0, 5.0);
    let model = TuckerModel::init_kruskal(&mut rng, &dims, j, r);
    let core = match &model.core {
        CoreRepr::Kruskal(k) => k.clone(),
        _ => unreachable!(),
    };
    let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
    let (lr, lam) = (0.005f32, 0.001f32);
    let fiber_stats = FiberStats::compute(&tensor, &ids);
    let auto = planner::choose_params(
        &fiber_stats, 3, r, j, Exactness::Exact, Lanes::Auto, SimdLevel::Auto, 1,
    );
    println!(
        "fibers: n={} mean={:.2} p90={} max={}  planner: cap={} tile={} lanes={:?} simd={:?}",
        fiber_stats.n_fibers,
        fiber_stats.mean_len,
        fiber_stats.p90_len,
        fiber_stats.max_len,
        auto.max_batch,
        auto.tile,
        auto.lanes,
        auto.simd
    );

    let mut table = Table::new(&[
        "path",
        "cap",
        "tile",
        "mean group",
        "fibers/grp",
        "occupancy",
        "secs/pass",
        "Msamples/sec",
        "speedup",
    ]);
    let mut result = WorkloadResult {
        name: name.to_string(),
        dims,
        nnz,
        mean_fiber_len: fiber_stats.mean_len,
        paths: Vec::new(),
    };

    // Scalar baseline over the grouped order of a reference plan (same
    // memory-access order for both paths — the comparison isolates the
    // kernel structure, not the sample permutation).
    let ref_plan = BatchPlan::build_params(&tensor, &ids, auto);
    let scalar_secs = {
        let mut factors = model.factors.clone();
        let mut ws = Workspace::new(3, r, j);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let st = scalar::run_ids(
                &mut ws, &tensor, ref_plan.ids(), &core, &[], CoreLayout::Packed,
                &mut factors, lr, lam, true, None,
            );
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.sse);
        }
        table.row(&[
            "scalar".into(),
            "-".into(),
            "-".into(),
            "1.0".into(),
            "-".into(),
            "-".into(),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            "1.00x".into(),
        ]);
        result.paths.push(PathResult {
            path: "scalar".into(),
            cap: None,
            tile: None,
            mean_group_len: 1.0,
            mean_fibers_per_group: 1.0,
            occupancy: 1.0,
            secs_per_pass: best,
            msamples_per_sec: nnz as f64 / best / 1e6,
            speedup_vs_scalar: 1.0,
            threads: 1,
        });
        best
    };

    let cases: Vec<(String, PlanParams)> = vec![
        ("single-fiber".into(), PlanParams::exact(64)),
        ("single-fiber".into(), PlanParams::exact(auto.max_batch)),
        // The scalar-microkernel reference: the planner's plan with the
        // arch intrinsics forced off, so `tiled-simd` below isolates
        // exactly what the SSE2/AVX2/NEON panel kernels buy.
        ("tiled".into(), auto.with_simd(SimdLevel::Scalar)),
        // Real-SIMD ablation (ISSUE 10): the identical plan with
        // runtime-detected arch microkernels — bitwise-identical output
        // by the panel contract, gated strictly above `tiled` by the
        // baseline floors.
        ("tiled-simd".into(), auto),
        // Lane ablation: the same plan forced to 4-wide panel blocks
        // (auto picks 8 at R=16) — the gate pins that the wide kernels
        // never lose to the narrow ones by more than tolerance.
        ("tiled-w4".into(), auto.with_lanes(Lanes::W4)),
        // Split-group refinement: sub-groups cut at fiber sub-run
        // boundaries (bitwise-neutral in exact mode); pins the overhead
        // of the finer dispatch granularity.
        ("tiled-split".into(), auto.with_split(8)),
        // Relaxed path gets the widest tile the cap can hold: with no
        // distinctness splits, group length is limited only by cap/tile.
        (
            "relaxed".into(),
            PlanParams::relaxed(auto.max_batch, planner::MAX_TILE.min(auto.max_batch)),
        ),
    ];
    for (label, params) in cases {
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let stats = plan.stats();
        let mut factors = model.factors.clone();
        let mut bws = BatchWorkspace::new(3, r, j, params.max_batch);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let st = batched::run_plan(
                &mut bws, &tensor, &plan, &core, &[], CoreLayout::Packed,
                &mut factors, lr, lam, true, None,
            );
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.sse);
        }
        table.row(&[
            label.clone(),
            params.max_batch.to_string(),
            params.tile.to_string(),
            format!("{:.1}", stats.mean_group_len()),
            format!("{:.2}", stats.mean_fibers_per_group()),
            format!("{:.2}", stats.occupancy()),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            format!("{:.2}x", scalar_secs / best),
        ]);
        result.paths.push(PathResult {
            path: label,
            cap: Some(params.max_batch),
            tile: Some(params.tile),
            mean_group_len: stats.mean_group_len(),
            mean_fibers_per_group: stats.mean_fibers_per_group(),
            occupancy: stats.occupancy(),
            secs_per_pass: best,
            msamples_per_sec: nnz as f64 / best / 1e6,
            speedup_vs_scalar: scalar_secs / best,
            threads: 1,
        });
    }

    // In-group threaded path (ISSUE 4 tentpole): the tiled-split plan's
    // sub-groups fanned across a DispatchPool as exact coloring waves —
    // bitwise identical to the sequential tiled-split path, timed to pin
    // the wave-dispatch overhead/speedup.
    {
        let mt_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(2, 8);
        let params = auto.with_split(8);
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let coloring = plan.color_subgroups(&tensor);
        let cstats = coloring.stats();
        let stats = plan.stats();
        let mut factors = model.factors.clone();
        let mut pool = DispatchPool::new(mt_threads, 3, r, j, params.max_batch);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let shared = SharedFactors::new(&mut factors);
            let t0 = Instant::now();
            // SAFETY: exact coloring waves have pairwise-disjoint row
            // footprints; nothing else touches the factors.
            let st = pool.execute(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { SharedRowAccess::new(&shared) },
                lr, lam, true, None,
            );
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.sse);
        }
        println!(
            "tiled-split-mt: {} threads, {} waves over {} sub-groups (mean wave {:.1})",
            mt_threads,
            cstats.n_waves,
            cstats.n_groups,
            cstats.parallelism()
        );
        table.row(&[
            format!("tiled-split-mt(x{mt_threads})"),
            params.max_batch.to_string(),
            params.tile.to_string(),
            format!("{:.1}", stats.mean_group_len()),
            format!("{:.2}", stats.mean_fibers_per_group()),
            format!("{:.2}", stats.occupancy()),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            format!("{:.2}x", scalar_secs / best),
        ]);
        result.paths.push(PathResult {
            path: "tiled-split-mt".into(),
            cap: Some(params.max_batch),
            tile: Some(params.tile),
            mean_group_len: stats.mean_group_len(),
            mean_fibers_per_group: stats.mean_fibers_per_group(),
            occupancy: stats.occupancy(),
            secs_per_pass: best,
            msamples_per_sec: nnz as f64 / best / 1e6,
            speedup_vs_scalar: scalar_secs / best,
            threads: mt_threads,
        });
    }

    // Device-sharded engine path (ISSUE 5 tentpole): the full parallel
    // engine on a D=2 grid over 2 Latin workers (split sub-groups pooled
    // across 2 in-group threads per worker) — one epoch = one full pass
    // over the same nonzeros, so the speedup is comparable to the kernel
    // paths while also pinning the device layer's end-to-end overhead
    // (partition, Latin rounds, boundary-exchange bookkeeping, the
    // fixed-order core merge).
    {
        use fasttucker::kernel::ThreadCount;
        use fasttucker::parallel::{DeviceCount, Execution, ParallelFastTucker, ParallelOptions};
        let devices = 2usize;
        let mut opts = ParallelOptions::default();
        opts.workers = devices;
        opts.devices = DeviceCount::Fixed(devices);
        opts.split = 8;
        opts.threads = ThreadCount::Fixed(2);
        opts.execution = Execution::auto();
        let mut engine = ParallelFastTucker::new(opts);
        let mut model = TuckerModel {
            factors: model.factors.clone(),
            core: CoreRepr::Kruskal(core.clone()),
        };
        let mut erng = Rng::new(8);
        let mut best = f64::INFINITY;
        engine.train_epoch(&mut model, &tensor, 0, &mut erng).unwrap(); // warmup
        for rep in 0..reps {
            let t0 = Instant::now();
            let st = engine.train_epoch(&mut model, &tensor, rep + 1, &mut erng).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.samples);
        }
        let acc = engine.plan_accum;
        println!(
            "tiled-split-mt-d{devices}: {} devices x {} workers, cap {}, \
             device occupancy {:.2}, comm {} rows / {} bytes per run",
            acc.devices,
            devices,
            acc.cap,
            acc.device_occupancy(),
            acc.comm_rows,
            acc.comm_bytes
        );
        let label = format!("tiled-split-mt-d{devices}");
        table.row(&[
            label.clone(),
            acc.cap.to_string(),
            acc.tile.to_string(),
            format!("{:.1}", acc.mean_group_len()),
            format!("{:.2}", acc.mean_fibers_per_group()),
            format!("{:.2}", acc.occupancy()),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            format!("{:.2}x", scalar_secs / best),
        ]);
        result.paths.push(PathResult {
            path: label,
            // The gate key pins the dataset-level planner cap (per-device
            // decisions coincide with it on these uniform workloads).
            cap: Some(auto.max_batch),
            tile: Some(acc.tile),
            mean_group_len: acc.mean_group_len(),
            mean_fibers_per_group: acc.mean_fibers_per_group(),
            occupancy: acc.occupancy(),
            secs_per_pass: best,
            msamples_per_sec: nnz as f64 / best / 1e6,
            speedup_vs_scalar: scalar_secs / best,
            threads: 2,
        });
    }

    // Async double-buffered exchange path (ISSUE 8 tentpole): the same
    // D=2 grid, but the boundary rows travel as framed channel messages
    // issued while the previous round computes. Pins the end-to-end cost
    // of the channel + prefetch machinery (the sync channel path would
    // expose the full serialize/validate cost at every barrier; here
    // most of it hides behind compute — the overlap line says how much).
    {
        use fasttucker::kernel::ThreadCount;
        use fasttucker::parallel::{
            DeviceCount, Execution, ParallelFastTucker, ParallelOptions, PrefetchMode,
            TransportKind,
        };
        let devices = 2usize;
        let mut opts = ParallelOptions::default();
        opts.workers = devices;
        opts.devices = DeviceCount::Fixed(devices);
        opts.split = 8;
        opts.threads = ThreadCount::Fixed(2);
        opts.execution = Execution::auto();
        opts.transport = TransportKind::Channel;
        opts.prefetch = PrefetchMode::Async;
        let mut engine = ParallelFastTucker::new(opts);
        let mut model = TuckerModel {
            factors: model.factors.clone(),
            core: CoreRepr::Kruskal(core.clone()),
        };
        let mut erng = Rng::new(9);
        let mut best = f64::INFINITY;
        engine.train_epoch(&mut model, &tensor, 0, &mut erng).unwrap(); // warmup
        for rep in 0..reps {
            let t0 = Instant::now();
            let st = engine.train_epoch(&mut model, &tensor, rep + 1, &mut erng).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.samples);
        }
        let acc = engine.plan_accum;
        println!(
            "tiled-split-mt-d{devices}-async: {} panels prefetched, {:.1}ms hidden / \
             {:.1}ms exposed comm (overlap {}), {} frames / {} bytes shipped",
            acc.prefetch_issued,
            acc.comm_hidden_secs * 1e3,
            acc.comm_exposed_secs * 1e3,
            acc.overlap_efficiency()
                .map(|e| format!("{:.0}%", e * 100.0))
                .unwrap_or_else(|| "n/a".into()),
            acc.frames_sent,
            acc.bytes_sent
        );
        let label = format!("tiled-split-mt-d{devices}-async");
        table.row(&[
            label.clone(),
            acc.cap.to_string(),
            acc.tile.to_string(),
            format!("{:.1}", acc.mean_group_len()),
            format!("{:.2}", acc.mean_fibers_per_group()),
            format!("{:.2}", acc.occupancy()),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            format!("{:.2}x", scalar_secs / best),
        ]);
        result.paths.push(PathResult {
            path: label,
            cap: Some(auto.max_batch),
            tile: Some(acc.tile),
            mean_group_len: acc.mean_group_len(),
            mean_fibers_per_group: acc.mean_fibers_per_group(),
            occupancy: acc.occupancy(),
            secs_per_pass: best,
            msamples_per_sec: nnz as f64 / best / 1e6,
            speedup_vs_scalar: scalar_secs / best,
            threads: 2,
        });
    }
    table.print();
    result
}

fn batched_vs_scalar(quick: bool) -> Vec<WorkloadResult> {
    let scale = if quick && std::env::var("FASTTUCKER_BENCH_SCALE").is_err() {
        0.1
    } else {
        bench_scale()
    };
    let reps = if quick { 2 } else { 3 };
    let nnz = ((1_500_000.0 * scale) as usize).max(10_000);
    vec![
        // Tall trailing modes (long mode-0 fibers): single-fiber groups
        // already work; tiling must not regress it.
        run_workload("tall", vec![256, 60_000, 60_000], nnz, reps),
        // Hollow HOHDST shape (mean fiber length < 4, the common
        // recommender shape): single-fiber plans degenerate to scalar —
        // only fiber tiling batches it.
        run_workload("hollow", vec![nnz / 2, 30_000, 30_000], nnz, reps),
    ]
}

/// Hand-rolled JSON (offline build: no serde) — the `BENCH_kernels.json`
/// throughput snapshot CI archives per commit and the regression gate
/// compares against `BENCH_baseline.json`.
fn render_json(workloads: &[WorkloadResult]) -> String {
    fn opt(v: Option<usize>) -> String {
        v.map(|x| x.to_string()).unwrap_or_else(|| "null".into())
    }
    let mut s = String::from("{\n  \"bench\": \"kernels\",\n  \"workloads\": [\n");
    for (wi, w) in workloads.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"dims\": {:?}, \"nnz\": {}, \"mean_fiber_len\": {:.4}, \"paths\": [\n",
            w.name, w.dims, w.nnz, w.mean_fiber_len
        ));
        for (pi, p) in w.paths.iter().enumerate() {
            s.push_str(&format!(
                "      {{\"path\": \"{}\", \"cap\": {}, \"tile\": {}, \"threads\": {}, \
                 \"mean_group_len\": {:.4}, \
                 \"mean_fibers_per_group\": {:.4}, \"occupancy\": {:.4}, \"secs_per_pass\": {:.6}, \
                 \"msamples_per_sec\": {:.4}, \"speedup_vs_scalar\": {:.4}}}{}\n",
                p.path,
                opt(p.cap),
                opt(p.tile),
                p.threads,
                p.mean_group_len,
                p.mean_fibers_per_group,
                p.occupancy,
                p.secs_per_pass,
                p.msamples_per_sec,
                p.speedup_vs_scalar,
                if pi + 1 == w.paths.len() { "" } else { "," }
            ));
        }
        s.push_str(&format!(
            "    ]}}{}\n",
            if wi + 1 == workloads.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn emit_json(path: &str, json: &str) {
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {path}");
}

/// The bench-regression gate: compare this run's normalized throughput
/// (`speedup_vs_scalar`) against the committed baseline; any pinned
/// `(workload, path, cap)` dropping more than the tolerance (15% by
/// default, `FASTTUCKER_BENCH_TOLERANCE` overrides) fails the process.
/// Refresh the baseline with
/// `cargo bench --bench bench_kernels -- --quick --json BENCH_baseline.json`.
fn check_baseline(baseline_path: &str, json: &str) {
    let baseline_text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("failed to read baseline {baseline_path}: {e}");
            std::process::exit(1);
        }
    };
    let baseline = regression::parse_entries(&baseline_text);
    if baseline.is_empty() {
        eprintln!("baseline {baseline_path} contains no gated entries");
        std::process::exit(1);
    }
    let current = regression::parse_entries(json);
    let tolerance = regression::tolerance_from_env();
    let report = regression::check(&current, &baseline, tolerance);
    println!(
        "\n== bench-regression gate vs {baseline_path} (tolerance {:.0}%) ==",
        tolerance * 100.0
    );
    for note in &report.notes {
        println!("note: {note}");
    }
    if report.passed() {
        println!(
            "gate passed: {} of {} pinned entries compared",
            report.matched,
            baseline.len()
        );
    } else {
        if report.matched == 0 {
            eprintln!(
                "gate compared NOTHING: no (workload, path, cap) key of the current run \
                 matches the baseline — snapshot format drift or a total rename"
            );
        }
        for r in &report.regressions {
            eprintln!("REGRESSION: {r}");
        }
        eprintln!(
            "bench-regression gate failed; if intentional, refresh the baseline:\n  \
             cargo bench --bench bench_kernels -- --quick --json {baseline_path}"
        );
        std::process::exit(1);
    }
}

fn pjrt_vs_native() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.tsv").exists() {
        println!("\n(pjrt bench skipped: run `make artifacts`)");
        return;
    }
    println!("\n== PJRT train_step vs native epoch (J=R=8, order 3) ==");
    let spec = PlantedSpec {
        dims: vec![200, 200, 200],
        nnz: 100_000,
        j: 8,
        r_core: 8,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(2);
    let p = planted_tucker(&mut rng, &spec);
    let mut table = Table::new(&["engine", "secs/epoch", "Msamples/sec"]);

    // Native.
    {
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut algo = fasttucker::algo::FastTucker::with_defaults();
        use fasttucker::algo::Decomposer;
        let mut rr = Rng::new(3);
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rr).unwrap(); // warmup
        let t0 = Instant::now();
        let st = algo.train_epoch(&mut model, &p.tensor, 1, &mut rr).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            "native".into(),
            format!("{secs:.4}"),
            format!("{:.2}", st.samples as f64 / secs / 1e6),
        ]);
    }
    // PJRT.
    {
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut engine = PjrtEngine::new(artifacts, 8, 8, SgdHyper::default()).unwrap();
        let mut rr = Rng::new(3);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rr).unwrap(); // warmup+compile
        let t0 = Instant::now();
        let st = engine.train_epoch(&mut model, &p.tensor, 1, &mut rr).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            format!("pjrt (batch {})", engine.batch()),
            format!("{secs:.4}"),
            format!("{:.2}", st.samples as f64 / secs / 1e6),
        ]);
    }
    table.print();
}

fn eval_bench() {
    println!("\n== evaluation throughput ==");
    let spec = PlantedSpec {
        dims: vec![300, 300, 300],
        nnz: 500_000,
        j: 16,
        r_core: 16,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(4);
    let p = planted_tucker(&mut rng, &spec);
    let model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 16, 16);
    let mut table = Table::new(&["threads", "secs", "Mpred/sec"]);
    for threads in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let (rm, _) = fasttucker::coordinator::eval::rmse_mae_parallel(&model, &p.tensor, threads);
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(rm);
        table.row(&[
            threads.to_string(),
            format!("{secs:.4}"),
            format!("{:.2}", p.tensor.nnz() as f64 / secs / 1e6),
        ]);
    }
    table.print();
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let baseline_path = args
        .iter()
        .position(|a| a == "--check")
        .and_then(|i| args.get(i + 1))
        .cloned();
    if !quick {
        contraction_bench();
    }
    let workloads = batched_vs_scalar(quick);
    let json = render_json(&workloads);
    if let Some(path) = json_path {
        emit_json(&path, &json);
    }
    if !quick {
        pjrt_vs_native();
        eval_bench();
    }
    // The gate runs last so the snapshot is written (and uploaded by CI)
    // even when the gate fails.
    if let Some(path) = baseline_path {
        check_baseline(&path, &json);
    }
}
