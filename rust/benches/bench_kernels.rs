//! Kernel microbenches (perf-pass instrumentation, EXPERIMENTS.md §Perf):
//! * the Thm-1/2 contraction throughput (samples/sec) vs (J, R_core),
//!   Packed vs Strided;
//! * **batched vs scalar kernel** — one full pass over a tall synthetic
//!   tensor through `kernel::batched` (fiber-grouped panels) vs
//!   `kernel::scalar` over the identical sample order; the acceptance bar
//!   is ≥ 1.3× at batch ≥ 64;
//! * PJRT `train_step` batch execution vs the native batch loop;
//! * evaluation throughput.

use std::time::Instant;

use fasttucker::algo::fasttucker::{build_strided, contract_staged, CoreLayout, Workspace};
use fasttucker::algo::SgdHyper;
use fasttucker::bench_support::{bench_scale, Table};
use fasttucker::coordinator::PjrtEngine;
use fasttucker::data::synth::{self, planted_tucker, PlantedSpec};
use fasttucker::kernel::{batched, scalar, BatchPlan, BatchWorkspace};
use fasttucker::kruskal::KruskalCore;
use fasttucker::model::{CoreRepr, TuckerModel};
use fasttucker::util::Rng;

fn contraction_bench() {
    println!("\n== Thm-1/2 contraction throughput (order 3) ==");
    let mut table = Table::new(&["J", "R", "layout", "Msamples/sec", "ns/sample"]);
    let mut rng = Rng::new(1);
    for (j, r) in [(4usize, 4usize), (8, 8), (16, 16), (32, 32), (8, 32), (32, 8)] {
        let core = KruskalCore::random(&mut rng, 3, j, r, 0.5);
        let strided = build_strided(&core);
        let rows: Vec<f32> = (0..3 * j).map(|_| rng.normal()).collect();
        for layout in [CoreLayout::Packed, CoreLayout::Strided] {
            let mut ws = Workspace::new(3, r, j);
            for n in 0..3 {
                ws.stage_row(n, &rows[n * j..(n + 1) * j]);
            }
            let iters = 2_000_000 / (j * r / 16 + 1);
            let t0 = Instant::now();
            let mut acc = 0.0f32;
            for _ in 0..iters {
                acc += contract_staged(&mut ws, &core, &strided, layout, 1.0);
            }
            let secs = t0.elapsed().as_secs_f64();
            std::hint::black_box(acc);
            table.row(&[
                j.to_string(),
                r.to_string(),
                format!("{layout:?}"),
                format!("{:.2}", iters as f64 / secs / 1e6),
                format!("{:.0}", secs / iters as f64 * 1e9),
            ]);
        }
    }
    table.print();
}

fn batched_vs_scalar() {
    println!("\n== batched vs scalar kernel (full pass, J=R=16, order 3) ==");
    // Tall trailing modes (recommender shape): long mode-0 fibers with few
    // intra-group collisions, so the planner can actually form big groups.
    let scale = bench_scale();
    let dims = vec![256usize, 60_000, 60_000];
    let nnz = ((1_500_000.0 * scale) as usize).max(10_000);
    let (j, r) = (16usize, 16usize);
    let mut rng = Rng::new(7);
    let tensor = synth::random_uniform(&mut rng, &dims, nnz, 1.0, 5.0);
    let model = TuckerModel::init_kruskal(&mut rng, &dims, j, r);
    let core = match &model.core {
        CoreRepr::Kruskal(k) => k.clone(),
        _ => unreachable!(),
    };
    let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
    let (lr, lam) = (0.005f32, 0.001f32);
    let reps = 3usize;

    // Scalar baseline over the grouped order of the largest plan (same
    // memory-access order for both paths — the comparison isolates the
    // kernel structure, not the sample permutation).
    let big_plan = BatchPlan::build(&tensor, &ids, 256);
    let mut table = Table::new(&[
        "path",
        "batch cap",
        "mean group",
        "secs/pass",
        "Msamples/sec",
        "speedup vs scalar",
    ]);
    let scalar_secs = {
        let mut factors = model.factors.clone();
        let mut ws = Workspace::new(3, r, j);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let st = scalar::run_ids(
                &mut ws, &tensor, big_plan.ids(), &core, &[], CoreLayout::Packed,
                &mut factors, lr, lam, true, None,
            );
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.sse);
        }
        table.row(&[
            "scalar".into(),
            "-".into(),
            "1.0".into(),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            "1.00x".into(),
        ]);
        best
    };
    for cap in [8usize, 64, 256] {
        let plan = BatchPlan::build(&tensor, &ids, cap);
        let mut factors = model.factors.clone();
        let mut bws = BatchWorkspace::new(3, r, j, cap);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let st = batched::run_plan(
                &mut bws, &tensor, &plan, &core, &[], CoreLayout::Packed,
                &mut factors, lr, lam, true, None,
            );
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(st.sse);
        }
        table.row(&[
            "batched".into(),
            cap.to_string(),
            format!("{:.1}", plan.mean_group_len()),
            format!("{best:.4}"),
            format!("{:.2}", nnz as f64 / best / 1e6),
            format!("{:.2}x", scalar_secs / best),
        ]);
    }
    table.print();
}

fn pjrt_vs_native() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.tsv").exists() {
        println!("\n(pjrt bench skipped: run `make artifacts`)");
        return;
    }
    println!("\n== PJRT train_step vs native epoch (J=R=8, order 3) ==");
    let spec = PlantedSpec {
        dims: vec![200, 200, 200],
        nnz: 100_000,
        j: 8,
        r_core: 8,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(2);
    let p = planted_tucker(&mut rng, &spec);
    let mut table = Table::new(&["engine", "secs/epoch", "Msamples/sec"]);

    // Native.
    {
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut algo = fasttucker::algo::FastTucker::with_defaults();
        use fasttucker::algo::Decomposer;
        let mut rr = Rng::new(3);
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rr).unwrap(); // warmup
        let t0 = Instant::now();
        let st = algo.train_epoch(&mut model, &p.tensor, 1, &mut rr).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            "native".into(),
            format!("{secs:.4}"),
            format!("{:.2}", st.samples as f64 / secs / 1e6),
        ]);
    }
    // PJRT.
    {
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut engine = PjrtEngine::new(artifacts, 8, 8, SgdHyper::default()).unwrap();
        let mut rr = Rng::new(3);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rr).unwrap(); // warmup+compile
        let t0 = Instant::now();
        let st = engine.train_epoch(&mut model, &p.tensor, 1, &mut rr).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            format!("pjrt (batch {})", engine.batch()),
            format!("{secs:.4}"),
            format!("{:.2}", st.samples as f64 / secs / 1e6),
        ]);
    }
    table.print();
}

fn eval_bench() {
    println!("\n== evaluation throughput ==");
    let spec = PlantedSpec {
        dims: vec![300, 300, 300],
        nnz: 500_000,
        j: 16,
        r_core: 16,
        noise: 0.1,
        clamp: None,
    };
    let mut rng = Rng::new(4);
    let p = planted_tucker(&mut rng, &spec);
    let model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 16, 16);
    let mut table = Table::new(&["threads", "secs", "Mpred/sec"]);
    for threads in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let (rm, _) = fasttucker::coordinator::eval::rmse_mae_parallel(&model, &p.tensor, threads);
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(rm);
        table.row(&[
            threads.to_string(),
            format!("{secs:.4}"),
            format!("{:.2}", p.tensor.nnz() as f64 / secs / 1e6),
        ]);
    }
    table.print();
}

fn main() {
    contraction_bench();
    batched_vs_scalar();
    pjrt_vs_native();
    eval_bench();
}
