//! Fig. 8: multi-device speedup stability vs nonzero count at fixed
//! order 3 — the paper's claim that speedup is more stable (closer to
//! linear) on denser tensors, because block load-balance improves with
//! more nonzeros per block.

use fasttucker::bench_support::{bench, bench_scale, Table};
use fasttucker::data::synth;
use fasttucker::model::TuckerModel;
use fasttucker::parallel::{BlockPartition, ParallelFastTucker, ParallelOptions};
use fasttucker::util::Rng;

fn main() {
    let scale = bench_scale();
    let dim = 500usize;
    let mut table = Table::new(&[
        "nnz",
        "workers",
        "secs/iter",
        "speedup",
        "block imbalance",
    ]);
    for nnz in [
        (100_000.0 * scale) as usize,
        (400_000.0 * scale) as usize,
        (1_600_000.0 * scale) as usize,
    ] {
        let mut rng = Rng::new(nnz as u64);
        let tensor = synth::random_uniform(&mut rng, &[dim, dim, dim], nnz, 1.0, 5.0);
        let mut base = None;
        for workers in [1usize, 2, 4] {
            let imb = BlockPartition::build(&tensor, workers).imbalance();
            let mut rng = Rng::new(7);
            let mut model = TuckerModel::init_kruskal(&mut rng, tensor.dims(), 8, 8);
            let mut opts = ParallelOptions::default();
            opts.workers = workers;
            let mut engine = ParallelFastTucker::new(opts);
            let mut secs = 0.0;
            let mut e = 0;
            bench("par", 1, 3, |i| {
                let mut rr = Rng::new(60 + i as u64);
                let st = engine.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                if i >= 1 {
                    secs += st.total_secs();
                }
                e += 1;
            });
            let secs = secs / 3.0;
            let speedup = base.map(|b: f64| b / secs).unwrap_or(1.0);
            if base.is_none() {
                base = Some(secs);
            }
            table.row(&[
                nnz.to_string(),
                workers.to_string(),
                format!("{secs:.4}"),
                format!("{speedup:.2}X"),
                format!("{imb:.3}"),
            ]);
        }
    }
    println!("\nFig. 8 — speedup stability vs nnz (order 3, J = R_core = 8)");
    table.print();
    println!("Expect: speedup closer to the worker count as nnz grows.");
}
