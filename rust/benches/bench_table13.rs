//! Table 13: time to update the factor matrices for one iteration (epoch),
//! for P-Tucker, Vest, SGD_Tucker, cuTucker, cuFastTucker, on the
//! netflix-like and yahoo-like datasets (J = R_core = 4), with speedups
//! relative to cuFastTucker.
//!
//! Paper shape to reproduce: cuFastTucker fastest; cuTucker ~2.6–3.6×
//! slower; SGD_Tucker/P-Tucker/Vest one-to-three orders of magnitude
//! slower.

use fasttucker::algo::{
    CuTucker, Decomposer, FastTucker, PTucker, SgdHyper, SgdTucker, Vest,
};
use fasttucker::bench_support::{bench, bench_scale, Table};
use fasttucker::data::Dataset;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

fn main() {
    let scale = 0.1 * bench_scale();
    let mut table = Table::new(&["dataset", "algorithm", "secs/iter", "vs cuFastTucker"]);

    for ds_name in ["netflix-like", "yahoo-like"] {
        let mut rng = Rng::new(1);
        let tensor = Dataset::by_name(ds_name, scale)
            .unwrap()
            .build(&mut rng)
            .unwrap();
        eprintln!("{ds_name}: dims={:?} nnz={}", tensor.dims(), tensor.nnz());
        let dims = tensor.dims().to_vec();

        // Factor-update timing only (paper: "we only compare the update of
        // the factor matrix here") -> update_core = false for SGD family.
        let mut hyper = SgdHyper::default();
        hyper.update_core = false;

        let mut results: Vec<(String, f64)> = Vec::new();

        // cuFastTucker.
        {
            let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 4);
            let mut algo = FastTucker::with_defaults();
            algo.config.hyper = hyper;
            let mut e = 0;
            let r = bench("fasttucker", 1, 3, |i| {
                let mut rr = Rng::new(100 + i as u64);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            results.push(("cuFastTucker".into(), r.mean_secs));
        }
        // cuTucker.
        {
            let mut model = TuckerModel::init_dense(&mut rng, &dims, 4);
            let mut algo = CuTucker::new(hyper);
            let mut e = 0;
            let r = bench("cutucker", 1, 3, |i| {
                let mut rr = Rng::new(100 + i as u64);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            results.push(("cuTucker".into(), r.mean_secs));
        }
        // SGD_Tucker.
        {
            let mut model = TuckerModel::init_dense(&mut rng, &dims, 4);
            let mut algo = SgdTucker::new(hyper);
            let mut e = 0;
            let r = bench("sgd_tucker", 0, 2, |i| {
                let mut rr = Rng::new(100 + i as u64);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            results.push(("SGD_Tucker".into(), r.mean_secs));
        }
        // P-Tucker (full ALS sweep per iteration).
        {
            let mut model = TuckerModel::init_dense(&mut rng, &dims, 4);
            let mut algo = PTucker::with_defaults();
            let mut e = 0;
            let r = bench("ptucker", 0, 2, |_| {
                let mut rr = Rng::new(100);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            results.push(("P-Tucker".into(), r.mean_secs));
        }
        // Vest (full CCD sweep per iteration).
        {
            let mut model = TuckerModel::init_dense(&mut rng, &dims, 4);
            let mut algo = Vest::with_defaults();
            let mut e = 0;
            let r = bench("vest", 0, 2, |_| {
                let mut rr = Rng::new(100);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            results.push(("Vest".into(), r.mean_secs));
        }

        let fast = results
            .iter()
            .find(|(n, _)| n == "cuFastTucker")
            .unwrap()
            .1;
        // Paper row order: P-Tucker, Vest, SGD_Tucker, cuTucker, cuFastTucker.
        for name in ["P-Tucker", "Vest", "SGD_Tucker", "cuTucker", "cuFastTucker"] {
            let secs = results.iter().find(|(n, _)| n == name).unwrap().1;
            table.row(&[
                ds_name.into(),
                name.into(),
                format!("{secs:.6}"),
                format!("{:.2}X", secs / fast),
            ]);
        }
    }
    println!("\nTable 13 — factor-update time per iteration (J = R_core = 4)");
    table.print();
}
