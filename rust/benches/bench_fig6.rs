//! Fig. 6: convergence — RMSE vs wall-clock time for all five methods
//! (J = R_core = 4) on the netflix-like and yahoo-like datasets.
//!
//! Paper shape: cuFastTucker and cuTucker converge fastest in wall time;
//! P-Tucker drops quickly per iteration but each iteration is orders of
//! magnitude slower; everyone reaches comparable RMSE eventually.

use fasttucker::algo::{
    CuTucker, Decomposer, FastTucker, PTucker, SgdHyper, SgdTucker, Vest,
};
use fasttucker::bench_support::bench_scale;
use fasttucker::data::split::train_test_split;
use fasttucker::data::Dataset;
use fasttucker::kruskal::reconstruct::rmse_mae;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

fn run(
    name: &str,
    algo: &mut dyn Decomposer,
    mut model: TuckerModel,
    train: &fasttucker::SparseTensor,
    test: &fasttucker::SparseTensor,
    epochs: usize,
) {
    let mut rng = Rng::new(9);
    let mut cum = 0.0f64;
    println!("# {name}");
    println!("epoch\tcum_secs\trmse\tmae");
    for epoch in 0..epochs {
        let st = algo.train_epoch(&mut model, train, epoch, &mut rng).unwrap();
        cum += st.total_secs();
        let (rmse, mae) = rmse_mae(&model, test);
        println!("{}\t{cum:.4}\t{rmse:.5}\t{mae:.5}", epoch + 1);
    }
}

fn main() {
    let scale = 0.05 * bench_scale();
    let mut h = SgdHyper::default();
    h.lr_factor = fasttucker::sched::LrSchedule::new(0.02, 0.05);
    h.lr_core = fasttucker::sched::LrSchedule::new(0.01, 0.1);
    h.lambda_factor = 1e-3;
    h.lambda_core = 1e-3;

    for ds in ["netflix-like", "yahoo-like"] {
        let mut rng = Rng::new(1);
        let tensor = Dataset::by_name(ds, scale).unwrap().build(&mut rng).unwrap();
        let (train, test) = train_test_split(&tensor, 0.1, &mut rng);
        println!("\n== Fig. 6 on {ds}: dims={:?} train nnz={} ==", train.dims(), train.nnz());
        let dims = train.dims().to_vec();

        let mut rng2 = Rng::new(2);
        let kmodel = TuckerModel::init_kruskal(&mut rng2, &dims, 4, 4);
        let dmodel = TuckerModel::init_dense(&mut rng2, &dims, 4);

        let mut ft = FastTucker::with_defaults();
        ft.config.hyper = h;
        run("cuFastTucker", &mut ft, kmodel.clone(), &train, &test, 10);

        let mut cu = CuTucker::new(h);
        run("cuTucker", &mut cu, dmodel.clone(), &train, &test, 10);

        let mut sgd = SgdTucker::new(h);
        run("SGD_Tucker", &mut sgd, dmodel.clone(), &train, &test, 6);

        let mut pt = PTucker::with_defaults();
        run("P-Tucker", &mut pt, dmodel.clone(), &train, &test, 4);

        let mut vest = Vest::with_defaults();
        run("Vest", &mut vest, dmodel.clone(), &train, &test, 4);
    }
}
