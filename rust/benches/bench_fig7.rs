//! Fig. 7: (a) scalability with tensor order 3–10 on the synthesis
//! datasets; (b)/(c) multi-device speedup with 1/2/4/5 workers.
//!
//! Paper shape: both methods scale with order, cuTucker far slower
//! (exponential in order through J^N); near-linear device speedup.
//! Run a subset with `cargo bench --bench bench_fig7 -- scalability`
//! or `-- speedup`.

use fasttucker::algo::{CuTucker, Decomposer, FastTucker, SgdHyper};
use fasttucker::bench_support::{bench, bench_filter, bench_scale, Table};
use fasttucker::data::Dataset;
use fasttucker::model::TuckerModel;
use fasttucker::parallel::{ParallelFastTucker, ParallelOptions};
use fasttucker::util::Rng;

fn scalability(scale: f64) {
    let mut table = Table::new(&[
        "order",
        "nnz",
        "cuFastTucker secs/iter",
        "cuTucker secs/iter",
    ]);
    for order in 3..=10usize {
        let mut rng = Rng::new(order as u64);
        let tensor = Dataset::by_name(&format!("synth-order{order}"), 0.2 * scale)
            .unwrap()
            .build(&mut rng)
            .unwrap();
        let dims = tensor.dims().to_vec();

        let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 4);
        let mut algo = FastTucker::with_defaults();
        let mut e = 0;
        let r = bench("ft", 1, 2, |i| {
            let mut rr = Rng::new(40 + i as u64);
            algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
            e += 1;
        });

        // cuTucker: J^order core entries per sample; cap at order <= 6 on
        // CPU (order 7 at J=4 is 16k entries/sample) and say so.
        let cu = if order <= 6 {
            let mut model = TuckerModel::init_dense(&mut rng, &dims, 4);
            let mut algo = CuTucker::new(SgdHyper::default());
            let mut e = 0;
            let r = bench("cu", 0, 1, |i| {
                let mut rr = Rng::new(40 + i as u64);
                algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                e += 1;
            });
            format!("{:.4}", r.mean_secs)
        } else {
            "(skipped: 4^order per sample intractable on CPU)".into()
        };
        table.row(&[
            order.to_string(),
            tensor.nnz().to_string(),
            format!("{:.4}", r.mean_secs),
            cu,
        ]);
    }
    println!("\nFig. 7(a) — scalability vs order (J = R_core = 4)");
    table.print();
}

fn speedup(scale: f64) {
    let mut table = Table::new(&["dataset", "workers", "secs/iter", "speedup", "efficiency"]);
    for ds_name in ["netflix-like", "yahoo-like"] {
        let mut rng = Rng::new(2);
        let tensor = Dataset::by_name(ds_name, 0.25 * scale)
            .unwrap()
            .build(&mut rng)
            .unwrap();
        eprintln!("{ds_name}: dims={:?} nnz={}", tensor.dims(), tensor.nnz());
        let dims = tensor.dims().to_vec();
        let mut base = None;
        for workers in [1usize, 2, 4, 5] {
            let mut rng = Rng::new(3);
            let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 8, 8);
            let mut opts = ParallelOptions::default();
            opts.workers = workers;
            let mut engine = ParallelFastTucker::new(opts);
            // Time from EpochStats (discrete-event device time in the
            // single-core Simulated mode; wall time under Threads).
            let mut secs = 0.0;
            let mut e = 0;
            bench("par", 1, 3, |i| {
                let mut rr = Rng::new(50 + i as u64);
                let st = engine.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                if i >= 1 {
                    secs += st.total_secs();
                }
                e += 1;
            });
            let secs = secs / 3.0;
            let speedup = base.map(|b: f64| b / secs).unwrap_or(1.0);
            if base.is_none() {
                base = Some(secs);
            }
            table.row(&[
                ds_name.into(),
                workers.to_string(),
                format!("{secs:.4}"),
                format!("{speedup:.2}X"),
                format!("{:.0}%", 100.0 * speedup / workers as f64),
            ]);
        }
    }
    println!("\nFig. 7(b,c) — multi-device speedup (J = R_core = 8)");
    table.print();
}

fn main() {
    let scale = bench_scale();
    match bench_filter().as_deref() {
        Some("scalability") => scalability(scale),
        Some("speedup") => speedup(scale),
        _ => {
            scalability(scale);
            speedup(scale);
        }
    }
}
