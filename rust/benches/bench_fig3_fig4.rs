//! Figs. 3 and 4: accuracy (RMSE/MAE per epoch) of cuTucker vs
//! cuFastTucker.
//!
//! Fig. 3 — fixed J, varying R_core ∈ {8, 16, 32}: cuFastTucker matches
//! (or beats) the dense-core cuTucker once R_core = J, demonstrating the
//! core's low-rank inherence.
//! Fig. 4 — J = R_core, 'Factor' (factor-only updates) vs 'Factor+Core'.
//!
//! Run a subset: `cargo bench --bench bench_fig3_fig4 -- fig3` (or fig4).

use fasttucker::algo::{CuTucker, Decomposer, FastTucker, SgdHyper};
use fasttucker::bench_support::{bench_filter, bench_scale};
use fasttucker::data::split::train_test_split;
use fasttucker::data::Dataset;
use fasttucker::kruskal::reconstruct::rmse_mae;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

const EPOCHS: usize = 12;

fn hyper() -> SgdHyper {
    let mut h = SgdHyper::default();
    h.lr_factor = fasttucker::sched::LrSchedule::new(0.02, 0.05);
    h.lr_core = fasttucker::sched::LrSchedule::new(0.01, 0.1);
    h.lambda_factor = 1e-3;
    h.lambda_core = 1e-3;
    h
}

fn dataset(name: &str, scale: f64) -> (fasttucker::SparseTensor, fasttucker::SparseTensor) {
    let mut rng = Rng::new(1);
    let tensor = Dataset::by_name(name, scale).unwrap().build(&mut rng).unwrap();
    train_test_split(&tensor, 0.1, &mut rng)
}

fn curve_fasttucker(
    train: &fasttucker::SparseTensor,
    test: &fasttucker::SparseTensor,
    j: usize,
    r: usize,
    update_core: bool,
) -> Vec<(f64, f64)> {
    let mut rng = Rng::new(2);
    let mut model = TuckerModel::init_kruskal(&mut rng, train.dims(), j, r);
    let mut algo = FastTucker::with_defaults();
    algo.config.hyper = hyper();
    algo.config.hyper.update_core = update_core;
    let mut out = Vec::new();
    for epoch in 0..EPOCHS {
        algo.train_epoch(&mut model, train, epoch, &mut rng).unwrap();
        out.push(rmse_mae(&model, test));
    }
    out
}

fn curve_cutucker(
    train: &fasttucker::SparseTensor,
    test: &fasttucker::SparseTensor,
    j: usize,
    update_core: bool,
) -> Vec<(f64, f64)> {
    let mut rng = Rng::new(2);
    let mut model = TuckerModel::init_dense(&mut rng, train.dims(), j);
    let mut algo = CuTucker::new(hyper());
    algo.hyper.update_core = update_core;
    let mut out = Vec::new();
    for epoch in 0..EPOCHS {
        algo.train_epoch(&mut model, train, epoch, &mut rng).unwrap();
        out.push(rmse_mae(&model, test));
    }
    out
}

fn print_series(label: &str, series: &[(f64, f64)]) {
    print!("{label}\trmse");
    for (r, _) in series {
        print!("\t{r:.4}");
    }
    println!();
    print!("{label}\tmae");
    for (_, m) in series {
        print!("\t{m:.4}");
    }
    println!();
}

fn fig3(scale: f64) {
    println!("\nFig. 3 — accuracy vs epoch, fixed J = 8, varying R_core");
    for ds in ["netflix-like", "yahoo-like"] {
        let (train, test) = dataset(ds, scale);
        eprintln!("{ds}: train nnz={}", train.nnz());
        println!("## {ds} (epochs 1..{EPOCHS})");
        let cu = curve_cutucker(&train, &test, 8, true);
        print_series("cuTucker J=8", &cu);
        for r_core in [8usize, 16, 32] {
            let ft = curve_fasttucker(&train, &test, 8, r_core, true);
            print_series(&format!("cuFastTucker J=8 R={r_core}"), &ft);
        }
    }
}

fn fig4(scale: f64) {
    println!("\nFig. 4 — Factor vs Factor+Core, J = R_core");
    for ds in ["netflix-like", "yahoo-like"] {
        let (train, test) = dataset(ds, scale);
        println!("## {ds} (epochs 1..{EPOCHS})");
        for j in [8usize, 16] {
            let both = curve_fasttucker(&train, &test, j, j, true);
            let factor_only = curve_fasttucker(&train, &test, j, j, false);
            print_series(&format!("cuFastTucker J=R={j} Factor+Core"), &both);
            print_series(&format!("cuFastTucker J=R={j} Factor"), &factor_only);
        }
    }
}

fn main() {
    let scale = 0.05 * bench_scale();
    match bench_filter().as_deref() {
        Some("fig3") => fig3(scale),
        Some("fig4") => fig4(scale),
        _ => {
            fig3(scale);
            fig4(scale);
        }
    }
}
