//! Tables 8–12: the shared-vs-global-memory placement of the hot core
//! factors, reproduced as the Packed (contiguous rows ≈ shared memory)
//! vs Strided (column-major, uncoalesced ≈ global memory) layout ablation
//! of cuFastTucker, for factor updates and core updates separately.
//!
//! Paper shape: the two placements are within ~±10% of each other, with
//! Packed usually slightly ahead (the paper's Tables 9–10) — the Kruskal
//! core is small enough that either tier serves it well, which is itself
//! the paper's point (the dense core of cuTucker does NOT fit).

use fasttucker::algo::{CoreLayout, Decomposer, FastTucker, SgdHyper};
use fasttucker::bench_support::{bench, bench_scale, Table};
use fasttucker::data::Dataset;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

fn main() {
    let scale = 0.05 * bench_scale();
    let mut table = Table::new(&[
        "dataset",
        "J/R_core",
        "layout",
        "factor secs/iter",
        "core secs/iter",
    ]);

    for ds_name in ["netflix-like", "yahoo-like"] {
        let mut rng = Rng::new(1);
        let tensor = Dataset::by_name(ds_name, scale)
            .unwrap()
            .build(&mut rng)
            .unwrap();
        eprintln!("{ds_name}: dims={:?} nnz={}", tensor.dims(), tensor.nnz());
        let dims = tensor.dims().to_vec();

        // The paper's grids: 4/4, 8/4, 8/8 (P100) and 8/8, 16/8, 32/8
        // (TITAN RTX).
        for (j, r_core) in [(4usize, 4usize), (8, 4), (8, 8), (16, 8), (32, 8)] {
            for layout in [CoreLayout::Packed, CoreLayout::Strided] {
                // Factor-only epochs, then factor+core epochs; the core
                // cost is the difference (the core-gradient work is fused
                // into the sample loop, like the paper's fused kernels).
                let mut run = |update_core: bool| {
                    let mut rng = Rng::new(30);
                    let mut model =
                        TuckerModel::init_kruskal(&mut rng, &dims, j, r_core);
                    let mut algo = FastTucker::with_defaults();
                    algo.config.hyper = SgdHyper::default();
                    algo.config.hyper.update_core = update_core;
                    algo.config.layout = layout;
                    let mut e = 0;
                    bench("layout", 1, 3, |i| {
                        let mut rr = Rng::new(30 + i as u64);
                        algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                        e += 1;
                    })
                    .mean_secs
                };
                let fsec = run(false);
                let csec = (run(true) - fsec).max(0.0);
                table.row(&[
                    ds_name.into(),
                    format!("{j}/{r_core}"),
                    format!("{layout:?}"),
                    format!("{fsec:.6}"),
                    format!("{csec:.6}"),
                ]);
            }
        }
    }
    println!("\nTables 8–12 — core-factor placement ablation (Packed ≈ shared memory, Strided ≈ global memory)");
    table.print();
}
