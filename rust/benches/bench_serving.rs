//! Serving-layer bench (ISSUE 9): batched top-k scoring throughput vs
//! the pointwise `predict` loop, with and without the hot-row cache.
//!
//! Reported per path: predictions/sec, cache hit rate, and
//! `speedup_vs_scalar` normalized against the same run's pointwise pass
//! (the serving "scalar"), so the gated metric transfers across CI
//! runners. The batch path is bitwise-identical to pointwise (pinned in
//! `kruskal::predict` and `serve::score`, and spot-checked here before
//! timing) — this bench exists to pin that the *faster* path stays
//! faster.
//!
//! Flags (after `--` with `cargo bench --bench bench_serving`):
//! * `--quick` — CI smoke mode: reduced query count.
//! * `--json PATH` — write the sweep as a `BENCH_serving.json` snapshot.
//! * `--check PATH` — bench-regression gate against the committed
//!   `BENCH_baseline.json` (shared with the kernel bench: unmatched
//!   kernel entries are non-fatal notes; the serving entries gate).

use std::time::Instant;

use fasttucker::bench_support::{bench_scale, regression, Table};
use fasttucker::data::stream::{ArrivalModel, ArrivalSim};
use fasttucker::data::synth::{planted_tucker, PlantedSpec};
use fasttucker::model::TuckerModel;
use fasttucker::serve::{Query, Scorer};
use fasttucker::util::Rng;

struct PathResult {
    path: String,
    cap: usize,
    secs: f64,
    predictions_per_sec: f64,
    cache_hit_rate: f64,
    speedup_vs_scalar: f64,
}

struct ServingResult {
    name: String,
    dims: Vec<usize>,
    queries: usize,
    candidates: usize,
    paths: Vec<PathResult>,
}

/// Deterministic query stream: a pool of repeat users (so the cached
/// path sees hits, like production serving traffic) with fresh random
/// candidate panels per query.
fn make_queries(
    rng: &mut Rng,
    dims: &[usize],
    n_queries: usize,
    pool: usize,
    candidates: usize,
    mode: usize,
) -> Vec<Query> {
    let users: Vec<Vec<u32>> = (0..pool)
        .map(|_| dims.iter().map(|&d| rng.gen_range(d) as u32).collect())
        .collect();
    (0..n_queries)
        .map(|i| Query {
            coords: users[i % pool].clone(),
            candidate_mode: mode,
            candidates: (0..candidates)
                .map(|_| rng.gen_range(dims[mode]) as u32)
                .collect(),
        })
        .collect()
}

/// Pointwise top-k: the oracle loop the batch path must match bitwise
/// and beat on throughput.
fn pointwise_topk(model: &TuckerModel, q: &Query, k: usize) -> Vec<(u32, f32)> {
    let mut full = q.coords.clone();
    let mut ranked: Vec<(u32, f32)> = q
        .candidates
        .iter()
        .map(|&c| {
            full[q.candidate_mode] = c;
            (c, model.predict(&full))
        })
        .collect();
    // NaN-last total order, mirroring `Scorer::top_k` (the old
    // `partial_cmp(..).unwrap_or(Equal)` was not a total order and could
    // rank NaN anywhere; `total_cmp` alone sorts +NaN above +inf).
    ranked.sort_by(|a, b| match (a.1.is_nan(), b.1.is_nan()) {
        (true, true) => a.0.cmp(&b.0),
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)),
    });
    ranked.truncate(k);
    ranked
}

fn run_serving(quick: bool) -> ServingResult {
    let scale = if quick && std::env::var("FASTTUCKER_BENCH_SCALE").is_err() {
        0.25
    } else {
        bench_scale()
    };
    let reps = if quick { 2 } else { 3 };
    let dims = vec![3000usize, 2000, 150];
    let (j, r) = (8usize, 8usize);
    let candidates = 256usize;
    let topk = 10usize;
    let n_queries = ((400.0 * scale) as usize).max(40);
    let pool = (n_queries / 8).max(1);
    println!(
        "\n== serving: batched top-k vs pointwise predict (dims {dims:?}, J={j}, R={r}, \
         {n_queries} queries x {candidates} candidates, pool {pool}) =="
    );

    let mut rng = Rng::new(13);
    let model = TuckerModel::init_kruskal(&mut rng, &dims, j, r);
    let queries = make_queries(&mut rng, &dims, n_queries, pool, candidates, 1);

    // Bitwise sanity before timing: the batch path must reproduce the
    // pointwise oracle exactly on a real query.
    {
        let mut scorer = Scorer::new(0);
        let scores = scorer.score(&model, 1, &queries[0]);
        let mut full = queries[0].coords.clone();
        for (i, &c) in queries[0].candidates.iter().enumerate() {
            full[1] = c;
            assert_eq!(
                scores[i].to_bits(),
                model.predict(&full).to_bits(),
                "batch scorer diverged from the pointwise oracle"
            );
        }
    }

    let mut table = Table::new(&["path", "cap", "secs", "preds/sec", "hit rate", "speedup"]);
    let mut result = ServingResult {
        name: "serving".into(),
        dims,
        queries: n_queries,
        candidates,
        paths: Vec::new(),
    };
    let total_preds = (n_queries * candidates) as f64;

    // Pointwise baseline (the serving "scalar").
    let pointwise_secs = {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let mut acc = 0u64;
            for q in &queries {
                for (item, score) in pointwise_topk(&model, q, topk) {
                    acc = acc.wrapping_add(u64::from(item)) ^ u64::from(score.to_bits());
                }
            }
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(acc);
        }
        best
    };
    table.row(&[
        "pointwise".into(),
        candidates.to_string(),
        format!("{pointwise_secs:.4}"),
        format!("{:.0}", total_preds / pointwise_secs),
        "-".into(),
        "1.00x".into(),
    ]);
    result.paths.push(PathResult {
        path: "pointwise".into(),
        cap: candidates,
        secs: pointwise_secs,
        predictions_per_sec: total_preds / pointwise_secs,
        cache_hit_rate: 0.0,
        speedup_vs_scalar: 1.0,
    });

    // Batched panel scorer, uncached and cached.
    for (label, capacity) in [("batch-topk", 0usize), ("batch-topk-cached", 2 * pool)] {
        let mut best = f64::INFINITY;
        let mut hit_rate = 0.0;
        for _ in 0..reps {
            let mut scorer = Scorer::new(capacity);
            let t0 = Instant::now();
            let mut acc = 0u64;
            for q in &queries {
                for s in scorer.top_k(&model, 1, q, topk) {
                    acc = acc.wrapping_add(u64::from(s.item)) ^ u64::from(s.score.to_bits());
                }
            }
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(acc);
            hit_rate = scorer.cache_counters().hit_rate();
        }
        table.row(&[
            label.into(),
            candidates.to_string(),
            format!("{best:.4}"),
            format!("{:.0}", total_preds / best),
            format!("{hit_rate:.3}"),
            format!("{:.2}x", pointwise_secs / best),
        ]);
        result.paths.push(PathResult {
            path: label.into(),
            cap: candidates,
            secs: best,
            predictions_per_sec: total_preds / best,
            cache_hit_rate: hit_rate,
            speedup_vs_scalar: pointwise_secs / best,
        });
    }
    table.print();
    check_arrival_locality(&model, n_queries);
    result
}

/// ISSUE 10 satellite check: production-shaped (Zipf-skewed) arrival
/// traffic must raise the `HotRowCache` hit rate over uniform arrivals.
/// Query coordinates are drawn through `ArrivalSim` itself, so this also
/// exercises the Zipf sampler end to end. Everything is seeded, so the
/// assertion is deterministic — a failure means the arrival model or the
/// cache keying regressed, not bad luck.
fn check_arrival_locality(model: &TuckerModel, n_queries: usize) {
    let dims: Vec<usize> = model.factors.mats().iter().map(|m| m.rows()).collect();
    let candidates: Vec<u32> = (0..64u32).collect();
    let hit_rate = |arrivals: ArrivalModel| -> f64 {
        let spec = PlantedSpec {
            dims: dims.clone(),
            nnz: 16,
            j: 2,
            r_core: 2,
            noise: 0.0,
            clamp: None,
        };
        let mut rng = Rng::new(21);
        let planted = planted_tucker(&mut rng, &spec);
        let mut sim = ArrivalSim::from_planted(&planted, &spec).with_arrival_model(arrivals);
        let batch = sim.next_batch(&mut rng, n_queries);
        let mut scorer = Scorer::new(256);
        for k in 0..batch.nnz() {
            let q = Query {
                coords: batch.index(k).to_vec(),
                candidate_mode: 1,
                candidates: candidates.clone(),
            };
            scorer.top_k(model, 1, &q, 10);
        }
        scorer.cache_counters().hit_rate()
    };
    let uniform = hit_rate(ArrivalModel::Uniform);
    let zipf = hit_rate(ArrivalModel::Zipf { exponent: 1.5 });
    println!(
        "\n== arrival locality: hot-row cache hit rate, uniform {uniform:.3} vs \
         zipf(1.5) {zipf:.3} over {n_queries} queries =="
    );
    assert!(
        zipf > uniform,
        "zipf-skewed arrivals must beat uniform on cache hit rate \
         (zipf {zipf:.4} <= uniform {uniform:.4})"
    );
}

/// Hand-rolled JSON (offline build: no serde), in the snapshot shape
/// `bench_support::regression::parse_entries` scans — one `"name"` line
/// per workload, one `"path"`/`"cap"`/`"speedup_vs_scalar"` line per
/// gated entry; the serving extras (predictions_per_sec,
/// cache_hit_rate) ride along un-gated.
fn render_json(w: &ServingResult) -> String {
    let mut s = String::from("{\n  \"bench\": \"serving\",\n  \"workloads\": [\n");
    s.push_str(&format!(
        "    {{\"name\": \"{}\", \"dims\": {:?}, \"queries\": {}, \"candidates\": {}, \"paths\": [\n",
        w.name, w.dims, w.queries, w.candidates
    ));
    for (pi, p) in w.paths.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"path\": \"{}\", \"cap\": {}, \"secs\": {:.6}, \
             \"predictions_per_sec\": {:.2}, \"cache_hit_rate\": {:.4}, \
             \"speedup_vs_scalar\": {:.4}}}{}\n",
            p.path,
            p.cap,
            p.secs,
            p.predictions_per_sec,
            p.cache_hit_rate,
            p.speedup_vs_scalar,
            if pi + 1 == w.paths.len() { "" } else { "," }
        ));
    }
    s.push_str("    ]}\n  ]\n}\n");
    s
}

fn emit_json(path: &str, json: &str) {
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {path}");
}

/// The bench-regression gate (same machinery as bench_kernels): compare
/// this run's `speedup_vs_scalar` per `(workload, path, cap)` against
/// the committed baseline; baseline entries this bench doesn't produce
/// (the kernel workloads) are non-fatal notes.
fn check_baseline(baseline_path: &str, json: &str) {
    let baseline_text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("failed to read baseline {baseline_path}: {e}");
            std::process::exit(1);
        }
    };
    let baseline = regression::parse_entries(&baseline_text);
    if baseline.is_empty() {
        eprintln!("baseline {baseline_path} contains no gated entries");
        std::process::exit(1);
    }
    let current = regression::parse_entries(json);
    let tolerance = regression::tolerance_from_env();
    let report = regression::check(&current, &baseline, tolerance);
    println!(
        "\n== bench-regression gate vs {baseline_path} (tolerance {:.0}%) ==",
        tolerance * 100.0
    );
    for note in &report.notes {
        println!("note: {note}");
    }
    if report.passed() {
        println!(
            "gate passed: {} of {} pinned entries compared",
            report.matched,
            baseline.len()
        );
    } else {
        if report.matched == 0 {
            eprintln!(
                "gate compared NOTHING: no (workload, path, cap) key of the current run \
                 matches the baseline — snapshot format drift or a total rename"
            );
        }
        for r in &report.regressions {
            eprintln!("REGRESSION: {r}");
        }
        eprintln!(
            "bench-regression gate failed; if intentional, refresh the serving floors in \
             {baseline_path} from this run's --json snapshot"
        );
        std::process::exit(1);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let baseline_path = args
        .iter()
        .position(|a| a == "--check")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let result = run_serving(quick);
    let json = render_json(&result);
    if let Some(path) = json_path {
        emit_json(&path, &json);
    }
    // The gate runs last so the snapshot is written (and uploaded by CI)
    // even when the gate fails.
    if let Some(path) = baseline_path {
        check_baseline(&path, &json);
    }
}
