//! Fig. 5: training-time growth. (a)/(b): time per iteration vs
//! J ∈ {4, 8, 16, 32} for cuTucker and cuFastTucker (factor and core
//! updates separately); (c)/(d): time vs R_core ∈ {4, 8, 16, 32} for
//! cuFastTucker at fixed J.
//!
//! The paper times the factor-update and core-update kernels separately;
//! here the core-gradient work is fused into the sample loop, so the core
//! cost is measured by differencing epochs with `update_core` on vs off.
//!
//! Paper shape: cuFastTucker grows LINEARLY in J and R_core; cuTucker's
//! updates grow exponentially in J (J^N for fixed N).

use fasttucker::algo::{CuTucker, Decomposer, FastTucker, SgdHyper};
use fasttucker::bench_support::{bench, bench_scale, Table};
use fasttucker::data::Dataset;
use fasttucker::model::TuckerModel;
use fasttucker::util::Rng;

/// (factor secs/iter, core secs/iter) via core-on/off differencing.
fn measure<F>(mut make: F, iters: usize) -> (f64, f64)
where
    F: FnMut(bool) -> Box<dyn FnMut(usize) -> ()>,
{
    let mut run = |update_core: bool| {
        let mut f = make(update_core);
        let r = bench("epoch", 1, iters, |i| f(i));
        r.mean_secs
    };
    let without = run(false);
    let with = run(true);
    (without, (with - without).max(0.0))
}

fn main() {
    let scale = 0.05 * bench_scale();
    let mut rng = Rng::new(1);
    let tensor = Dataset::by_name("netflix-like", scale)
        .unwrap()
        .build(&mut rng)
        .unwrap();
    eprintln!("dims={:?} nnz={}", tensor.dims(), tensor.nnz());
    let dims = tensor.dims().to_vec();
    let tensor = std::rc::Rc::new(tensor);

    // (a)/(b): sweep J with R_core = J.
    let mut t_j = Table::new(&[
        "J",
        "cuFastTucker factor(s)",
        "cuFastTucker core(s)",
        "cuTucker factor(s)",
        "cuTucker core(s)",
    ]);
    for j in [4usize, 8, 16, 32] {
        let dims2 = dims.clone();
        let tensor2 = tensor.clone();
        let (ft_f, ft_c) = measure(
            move |update_core| {
                let mut rng = Rng::new(7);
                let mut model = TuckerModel::init_kruskal(&mut rng, &dims2, j, j);
                let mut algo = FastTucker::with_defaults();
                algo.config.hyper.update_core = update_core;
                let tensor = tensor2.clone();
                let mut e = 0;
                Box::new(move |i| {
                    let mut rr = Rng::new(10 + i as u64);
                    algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                    e += 1;
                })
            },
            3,
        );

        // cuTucker: J=32 dense core is 32^3 entries per sample; cap at
        // J <= 16 on CPU and report the cap explicitly.
        let (cu_f, cu_c) = if j <= 16 {
            let dims2 = dims.clone();
            let tensor2 = tensor.clone();
            let (f, c) = measure(
                move |update_core| {
                    let mut rng = Rng::new(7);
                    let mut model = TuckerModel::init_dense(&mut rng, &dims2, j);
                    let mut algo = CuTucker::new(SgdHyper::default());
                    algo.hyper.update_core = update_core;
                    let tensor = tensor2.clone();
                    let mut e = 0;
                    Box::new(move |i| {
                        let mut rr = Rng::new(10 + i as u64);
                        algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                        e += 1;
                    })
                },
                if j <= 8 { 3 } else { 1 },
            );
            (format!("{f:.6}"), format!("{c:.6}"))
        } else {
            ("(skipped: J^N intractable on CPU)".into(), "-".into())
        };
        t_j.row(&[
            j.to_string(),
            format!("{ft_f:.6}"),
            format!("{ft_c:.6}"),
            cu_f,
            cu_c,
        ]);
    }
    println!("\nFig. 5(a,b) — time per iteration vs J (R_core = J)");
    t_j.print();

    // (c)/(d): sweep R_core at fixed J = 8.
    let mut t_r = Table::new(&["R_core", "cuFastTucker factor(s)", "cuFastTucker core(s)"]);
    for r_core in [4usize, 8, 16, 32] {
        let dims2 = dims.clone();
        let tensor2 = tensor.clone();
        let (f, c) = measure(
            move |update_core| {
                let mut rng = Rng::new(8);
                let mut model = TuckerModel::init_kruskal(&mut rng, &dims2, 8, r_core);
                let mut algo = FastTucker::with_defaults();
                algo.config.hyper.update_core = update_core;
                let tensor = tensor2.clone();
                let mut e = 0;
                Box::new(move |i| {
                    let mut rr = Rng::new(20 + i as u64);
                    algo.train_epoch(&mut model, &tensor, e, &mut rr).unwrap();
                    e += 1;
                })
            },
            3,
        );
        t_r.row(&[r_core.to_string(), format!("{f:.6}"), format!("{c:.6}")]);
    }
    println!("\nFig. 5(c,d) — time per iteration vs R_core (J = 8)");
    t_r.print();
    println!(
        "\nExpect: cuFastTucker columns grow ~linearly in J and R_core; \
         cuTucker grows superlinearly (J^3 core term)."
    );
}
