//! The L3 coordinator: ties datasets, algorithms, engines (native /
//! multi-device / PJRT), evaluation, and checkpointing into the training
//! loop the CLI and the experiment drivers invoke.

pub mod engine;
pub mod trainer;
pub mod eval;

pub use engine::{Engine, PjrtEngine};
pub use trainer::{EpochRecord, TrainOptions, TrainReport, Trainer};
