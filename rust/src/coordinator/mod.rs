//! The L3 coordinator: ties datasets, algorithms, engines (native /
//! multi-device / PJRT), evaluation, checkpointing, and long-lived
//! sessions into the training loop the CLI and the experiment drivers
//! invoke.
//!
//! Two entry shapes:
//!
//! * **One-shot** ([`Trainer`]) — build engine + model from a
//!   [`TrainConfig`](crate::config::TrainConfig), run the epoch loop,
//!   return the history. The launcher (`train` subcommand) and the
//!   experiment drivers use this.
//! * **Long-lived** ([`session::Session`]) — the trainer plus ownership
//!   of the training tensor and a serving scorer, for the streaming
//!   loop: serve top-k, append arrival batches between epochs,
//!   warm-start more epochs from the live factors. The session is where
//!   the cache-invalidation contract lives (appends touch the engines'
//!   data-keyed caches, training touches the model-keyed serving
//!   cache — each exactly, nothing else). The `serve` subcommand and
//!   `bench_serving` use this.

pub mod engine;
pub mod trainer;
pub mod eval;
pub mod session;

pub use engine::{Engine, PjrtEngine};
pub use session::Session;
pub use trainer::{EpochRecord, TrainOptions, TrainReport, Trainer};
