//! Compute engines: every way one epoch of training can be executed.
//!
//! * [`Engine::Native`] — any [`Decomposer`] on the pure-Rust order-N path.
//! * [`Engine::Parallel`] — the multi-device FastTucker simulation.
//! * [`Engine::Pjrt`] — the artifact path: gather factor rows in Rust,
//!   execute the `train_step` artifact through the step runtime (the AOT
//!   JAX/Pallas graph on PJRT builds; the in-crate batched kernel on this
//!   offline build — same math, same buffers), scatter the updated rows
//!   back. Order-3, shapes fixed at artifact build time.

use crate::util::error::{bail, Context, Result};

use crate::algo::{Decomposer, EpochStats, SgdHyper};
use crate::model::{CoreRepr, TuckerModel};
use crate::parallel::ParallelFastTucker;
use crate::runtime::PjrtRuntime;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// A training engine.
pub enum Engine {
    Native(Box<dyn Decomposer + Send>),
    Parallel(ParallelFastTucker),
    Pjrt(PjrtEngine),
}

impl Engine {
    pub fn name(&self) -> String {
        match self {
            Engine::Native(d) => format!("native/{}", d.name()),
            Engine::Parallel(p) => format!("parallel×{}", p.opts.workers),
            Engine::Pjrt(_) => "pjrt/fasttucker".to_string(),
        }
    }

    pub fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> Result<EpochStats> {
        Ok(match self {
            Engine::Native(d) => d.train_epoch(model, train, epoch, rng)?,
            Engine::Parallel(p) => p.train_epoch(model, train, epoch, rng)?,
            Engine::Pjrt(p) => p.train_epoch(model, train, epoch, rng)?,
        })
    }
}

/// The three-layer engine: Rust gather/scatter + PJRT-executed JAX step.
pub struct PjrtEngine {
    runtime: PjrtRuntime,
    pub hyper: SgdHyper,
    j: usize,
    r_core: usize,
    batch: usize,
    /// Gather buffers (B×J per mode) reused across batches.
    gather: [Vec<f32>; 3],
    vals: Vec<f32>,
    /// Core-gradient accumulation ([n][r][j] flattened) + sample count.
    core_grad: Vec<f32>,
    core_grad_count: usize,
    /// Native fallback workspace for the ragged tail batch.
    tail_ws: crate::algo::fasttucker::Workspace,
}

impl PjrtEngine {
    /// Load artifacts for shape (J, R); fails with a remediation hint if
    /// the variant was not AOT-compiled. Picks the largest compiled batch
    /// (best throughput on large tensors); use [`Self::with_batch_cap`]
    /// for small workloads where huge batches would average away too many
    /// duplicate-row updates.
    pub fn new(artifacts_dir: &std::path::Path, j: usize, r_core: usize, hyper: SgdHyper) -> Result<Self> {
        Self::with_batch_cap(artifacts_dir, j, r_core, hyper, usize::MAX)
    }

    /// Like [`Self::new`] but sizes the mini-batch cap from the training
    /// workload through the planner cost model
    /// ([`crate::kernel::planner::pjrt_batch_cap`]) — the launcher's
    /// default when no explicit `pjrt_batch_cap` is configured.
    pub fn auto(
        artifacts_dir: &std::path::Path,
        j: usize,
        r_core: usize,
        hyper: SgdHyper,
        train_nnz: usize,
    ) -> Result<Self> {
        Self::with_batch_cap(
            artifacts_dir,
            j,
            r_core,
            hyper,
            crate::kernel::planner::pjrt_batch_cap(train_nnz),
        )
    }

    /// Like [`Self::new`] but only considers artifacts with batch ≤ `cap`.
    pub fn with_batch_cap(
        artifacts_dir: &std::path::Path,
        j: usize,
        r_core: usize,
        hyper: SgdHyper,
        cap: usize,
    ) -> Result<Self> {
        let mut runtime = PjrtRuntime::new(artifacts_dir)?;
        runtime.set_batch_cap(cap);
        let entry = runtime
            .load("train_step", j, r_core)
            .context("loading train_step artifact")?;
        let batch = entry.entry.batch;
        runtime.load("predict", j, r_core).context("loading predict artifact")?;
        Ok(PjrtEngine {
            runtime,
            hyper,
            j,
            r_core,
            batch,
            gather: [
                vec![0.0; batch * j],
                vec![0.0; batch * j],
                vec![0.0; batch * j],
            ],
            vals: vec![0.0; batch],
            core_grad: vec![0.0; 3 * r_core * j],
            core_grad_count: 0,
            tail_ws: crate::algo::fasttucker::Workspace::new(3, r_core, j),
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// One epoch: full batches through the AOT artifact, the ragged tail
    /// through the bit-identical native math.
    pub fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> Result<EpochStats> {
        if model.order() != 3 {
            bail!("the PJRT engine supports order-3 tensors (artifacts are fixed-shape)");
        }
        if model.rank() != self.j {
            bail!("model rank {} != artifact J {}", model.rank(), self.j);
        }
        let h = self.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);

        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        let mut ids: Vec<usize> = if h.sample_frac >= 1.0 {
            (0..train.nnz()).collect()
        } else {
            crate::sched::Sampler::new(train.nnz()).one_step(rng, m)
        };
        if h.sample_frac >= 1.0 {
            rng.shuffle(&mut ids);
        }

        let t0 = std::time::Instant::now();
        let b = self.batch;
        let n_full = ids.len() / b;
        for bi in 0..n_full {
            self.run_batch(model, train, &ids[bi * b..(bi + 1) * b], lr_f)?;
        }
        // Ragged tail: native math (identical update rule).
        let tail = &ids[n_full * b..];
        if !tail.is_empty() {
            self.run_tail(model, train, tail, lr_f);
        }
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        if h.update_core && self.core_grad_count > 0 {
            let mcount = self.core_grad_count as f32;
            let core = match &mut model.core {
                CoreRepr::Kruskal(k) => k,
                CoreRepr::Dense(_) => bail!("PJRT engine requires a Kruskal core"),
            };
            for n in 0..3 {
                for r in 0..self.r_core {
                    let base = (n * self.r_core + r) * self.j;
                    let g = &self.core_grad[base..base + self.j];
                    let row = core.row_mut(n, r);
                    for (bv, &gv) in row.iter_mut().zip(g.iter()) {
                        *bv = (1.0 - lr_c * h.lambda_core) * *bv - lr_c * gv / mcount;
                    }
                }
            }
            self.core_grad.fill(0.0);
            self.core_grad_count = 0;
        }
        let core_secs = t1.elapsed().as_secs_f64();

        Ok(EpochStats { samples: ids.len(), factor_secs, core_secs })
    }

    fn run_batch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        ids: &[usize],
        lr_f: f32,
    ) -> Result<()> {
        let (j, r, b) = (self.j, self.r_core, self.batch);
        debug_assert_eq!(ids.len(), b);
        // Gather.
        for (s, &k) in ids.iter().enumerate() {
            let coords = train.index(k);
            for n in 0..3 {
                self.gather[n][s * j..(s + 1) * j]
                    .copy_from_slice(model.factors.row(n, coords[n] as usize));
            }
            self.vals[s] = train.value(k);
        }
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k,
            CoreRepr::Dense(_) => bail!("PJRT engine requires a Kruskal core"),
        };
        let row_shape = [b as i64, j as i64];
        let b_shape = [r as i64, j as i64];
        let scalar: [i64; 0] = [];
        let lr_buf = [lr_f];
        let lam_buf = [self.hyper.lambda_factor];
        let exe = self.runtime.load("train_step", j, r)?;
        let outs = exe.run(&[
            (&self.gather[0], &row_shape),
            (&self.gather[1], &row_shape),
            (&self.gather[2], &row_shape),
            (core.factor(0).data(), &b_shape),
            (core.factor(1).data(), &b_shape),
            (core.factor(2).data(), &b_shape),
            (&self.vals, &[b as i64]),
            (&lr_buf, &scalar),
            (&lam_buf, &scalar),
        ])?;
        // Scatter: deltas of duplicate rows within a batch accumulate
        // additively — the exact mini-batch (sum) gradient. Like any
        // sum-reduced mini-batch SGD, very large batches relative to a
        // mode's dimension need a smaller learning rate; cap the batch
        // via `TrainConfig::pjrt_batch_cap` / `with_batch_cap` when the
        // workload is small. (The paper's CUDA kernels race concurrent
        // writers hogwild-style; summed deltas are the deterministic
        // analogue.)
        for n in 0..3 {
            let new_rows = &outs[n];
            for (s, &k) in ids.iter().enumerate() {
                let coords = train.index(k);
                let old = &self.gather[n][s * j..(s + 1) * j];
                let row = model.factors.row_mut(n, coords[n] as usize);
                for jj in 0..j {
                    row[jj] += new_rows[s * j + jj] - old[jj];
                }
            }
        }
        if self.hyper.update_core {
            for n in 0..3 {
                let gb = &outs[3 + n];
                let base = n * self.r_core * self.j;
                for (slot, &g) in self.core_grad[base..base + r * j].iter_mut().zip(gb) {
                    *slot += g;
                }
            }
            self.core_grad_count += ids.len();
        }
        Ok(())
    }

    fn run_tail(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        ids: &[usize],
        lr_f: f32,
    ) {
        use crate::algo::fasttucker::CoreLayout;
        // The ragged tail goes through the shared scalar kernel — the
        // identical update rule the full batches encode.
        let ids32: Vec<u32> = ids.iter().map(|&k| k as u32).collect();
        {
            let core = match &model.core {
                CoreRepr::Kruskal(c) => c,
                CoreRepr::Dense(_) => unreachable!(),
            };
            crate::kernel::scalar::run_ids(
                &mut self.tail_ws,
                train,
                &ids32,
                core,
                &[],
                CoreLayout::Packed,
                &mut model.factors,
                lr_f,
                self.hyper.lambda_factor,
                self.hyper.update_core,
                None,
            );
        }
        // Fold the tail workspace's core grads into the engine accumulator.
        if self.hyper.update_core {
            let (grad, count) = self.tail_ws.core_grad_mut();
            for (slot, &g) in self.core_grad.iter_mut().zip(grad.iter()) {
                *slot += g;
            }
            self.core_grad_count += *count;
            grad.fill(0.0);
            *count = 0;
        }
    }

    /// Batched prediction through the `predict` artifact (used by eval).
    pub fn predict_batch(
        &mut self,
        model: &TuckerModel,
        test: &SparseTensor,
        ids: &[usize],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let (j, r, b) = (self.j, self.r_core, self.batch);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k,
            CoreRepr::Dense(_) => bail!("PJRT engine requires a Kruskal core"),
        };
        out.clear();
        let mut pos = 0;
        while pos < ids.len() {
            let chunk = (ids.len() - pos).min(b);
            for s in 0..b {
                // Pad by repeating the last sample; padded outputs are
                // discarded below.
                let k = ids[pos + s.min(chunk - 1)];
                let coords = test.index(k);
                for n in 0..3 {
                    self.gather[n][s * j..(s + 1) * j]
                        .copy_from_slice(model.factors.row(n, coords[n] as usize));
                }
            }
            let row_shape = [b as i64, j as i64];
            let b_shape = [r as i64, j as i64];
            let exe = self.runtime.load("predict", j, r)?;
            let outs = exe.run(&[
                (&self.gather[0], &row_shape),
                (&self.gather[1], &row_shape),
                (&self.gather[2], &row_shape),
                (core.factor(0).data(), &b_shape),
                (core.factor(1).data(), &b_shape),
                (core.factor(2).data(), &b_shape),
            ])?;
            out.extend_from_slice(&outs[0][..chunk]);
            pos += chunk;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.tsv").exists()
    }

    #[test]
    fn pjrt_engine_converges_and_matches_native_shape() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let spec = PlantedSpec {
            dims: vec![50, 40, 30],
            nnz: 4000,
            j: 8,
            r_core: 8,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut hyper = SgdHyper::default();
        hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        // Small workload: cap the batch so duplicate-row averaging does
        // not swallow the per-epoch progress.
        let mut engine =
            PjrtEngine::with_batch_cap(&artifacts_dir(), 8, 8, hyper, 256).unwrap();
        let before = rmse(&model, &p.tensor);
        for epoch in 0..8 {
            engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.7 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn pjrt_predict_matches_native_predict() {
        if !have_artifacts() {
            return;
        }
        let spec = PlantedSpec {
            dims: vec![30, 30, 30],
            nnz: 700, // not a multiple of the 256 batch: exercises padding
            j: 8,
            r_core: 8,
            noise: 0.3,
            clamp: None,
        };
        let mut rng = Rng::new(2);
        let p = planted_tucker(&mut rng, &spec);
        let model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 8, 8);
        let mut engine = PjrtEngine::new(&artifacts_dir(), 8, 8, SgdHyper::default()).unwrap();
        let ids: Vec<usize> = (0..p.tensor.nnz()).collect();
        let mut out = Vec::new();
        engine.predict_batch(&model, &p.tensor, &ids, &mut out).unwrap();
        assert_eq!(out.len(), p.tensor.nnz());
        for k in [0usize, 123, 699] {
            let want = model.predict(p.tensor.index(k));
            assert!((out[k] - want).abs() < 1e-3, "{} vs {}", out[k], want);
        }
    }
}
