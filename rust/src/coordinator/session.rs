//! Long-lived engine sessions: train, serve, append, warm-start — one
//! owner for the model, the engine, and the (growing) training data.
//!
//! A [`Session`] is the unit of the streaming story (ISSUE 9). It owns
//! the pieces the one-shot launcher wires up and then discards, and it
//! enforces the boundary that keeps exact-mode training bitwise:
//!
//! * **Appends land between epochs.** [`Session::append`] grows the
//!   training tensor (checked, all-or-nothing) and bumps its content
//!   revision; the engines' partition/planner caches key on that
//!   revision, so *exactly* the data-derived caches rebuild on the next
//!   epoch — nothing mid-epoch ever changes, and the post-append epoch
//!   is bitwise-identical to a fresh engine run on the merged tensor.
//! * **Training bumps the model revision.** [`Session::train_epochs`]
//!   resumes from the live factors (warm start — epoch numbering
//!   continues, so schedules see the true epoch index) and bumps the
//!   session's model revision; the serving scorer's
//!   [`HotRowCache`](crate::serve::HotRowCache) fingerprints on it, so
//!   *exactly* the model-derived cache drops. Appends alone leave the
//!   hot-row cache untouched (staged rows are cut from factors, not
//!   data) and training alone leaves the partition caches untouched —
//!   each mutation invalidates what it dirtied and nothing else.
//! * **Serving is the bitwise batch path.** [`Session::top_k`] /
//!   [`Session::score`] go through [`serve::Scorer`](crate::serve::Scorer),
//!   pinned bitwise against the pointwise
//!   [`predict`](crate::model::TuckerModel::predict) oracle.

use crate::algo::EpochStats;
use crate::config::TrainConfig;
use crate::coordinator::engine::Engine;
use crate::coordinator::eval::rmse_mae_parallel;
use crate::coordinator::trainer::{EpochRecord, TrainReport, Trainer};
use crate::log_info;
use crate::model::TuckerModel;
use crate::parallel::EngineRebuilds;
use crate::serve::{CacheCounters, Query, ScoredItem, Scorer};
use crate::tensor::SparseTensor;
use crate::util::error::Result;
use crate::util::Rng;

/// A live training/serving session. See the module docs for the
/// invalidation contract.
pub struct Session {
    trainer: Trainer,
    model: TuckerModel,
    train: SparseTensor,
    test: SparseTensor,
    rng: Rng,
    scorer: Scorer,
    /// Monotone fingerprint of the factor state; bumped by every
    /// [`train_epochs`](Session::train_epochs) call that ran ≥ 1 epoch.
    model_revision: u64,
    /// Total epochs run over the session's lifetime (continues across
    /// appends — warm-start epochs see the true epoch index).
    epochs_run: usize,
}

impl Session {
    /// Build a session from a config and the initial train/test split.
    /// `cache_capacity` bounds the serving hot-row cache (0 = uncached).
    pub fn new(
        cfg: &TrainConfig,
        train: SparseTensor,
        test: SparseTensor,
        cache_capacity: usize,
        rng: &mut Rng,
    ) -> Result<Session> {
        let dims = train.dims().to_vec();
        let (trainer, model) = Trainer::from_config_for(cfg, &dims, Some(train.nnz()), rng)?;
        Ok(Session {
            trainer,
            model,
            train,
            test,
            rng: rng.fork(),
            scorer: Scorer::new(cache_capacity),
            model_revision: 1,
            epochs_run: 0,
        })
    }

    pub fn model(&self) -> &TuckerModel {
        &self.model
    }

    pub fn train_tensor(&self) -> &SparseTensor {
        &self.train
    }

    pub fn model_revision(&self) -> u64 {
        self.model_revision
    }

    pub fn epochs_run(&self) -> usize {
        self.epochs_run
    }

    pub fn engine_name(&self) -> String {
        self.trainer.engine.name()
    }

    pub fn cache_counters(&self) -> CacheCounters {
        self.scorer.cache_counters()
    }

    /// Engine-side rebuild counters (partition/planner cache misses) —
    /// the observable half of the append-invalidation contract. `None`
    /// for engines without decision caches at this layer.
    pub fn engine_rebuilds(&self) -> Option<EngineRebuilds> {
        match &self.trainer.engine {
            Engine::Parallel(p) => Some(p.rebuilds()),
            _ => None,
        }
    }

    pub fn set_verbose(&mut self, verbose: bool) {
        self.trainer.opts.verbose = verbose;
    }

    /// Evaluate the live model on the held-out split: `(rmse, mae)`.
    pub fn evaluate(&self) -> (f64, f64) {
        rmse_mae_parallel(&self.model, &self.test, self.trainer.opts.eval_threads)
    }

    /// Append an arrival batch to the training tensor (checked,
    /// all-or-nothing; dims must match). Runs at the session boundary —
    /// never mid-epoch — so exact-mode training stays bitwise. The
    /// tensor's content revision bumps, which is what invalidates the
    /// engine's partition/planner caches on the next epoch; the serving
    /// cache is deliberately *not* touched (the model didn't move).
    pub fn append(&mut self, batch: &SparseTensor) -> Result<()> {
        self.train.append_tensor(batch)
    }

    /// Run `epochs` more training epochs from the live factors (warm
    /// start), evaluating per `eval_every`. Epoch numbering continues
    /// from the session total. Bumps the model revision afterward so
    /// the serving cache re-stages against the updated factors.
    pub fn train_epochs(&mut self, epochs: usize) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let mut cum = EpochStats::default();
        let start = self.epochs_run;
        for k in 0..epochs {
            let epoch = start + k;
            let stats =
                self.trainer
                    .engine
                    .train_epoch(&mut self.model, &self.train, epoch, &mut self.rng)?;
            cum.merge(&stats);
            if (k + 1) % self.trainer.opts.eval_every == 0 || k + 1 == epochs {
                let (rmse, mae) =
                    rmse_mae_parallel(&self.model, &self.test, self.trainer.opts.eval_threads);
                report.history.push(EpochRecord {
                    epoch: epoch + 1,
                    rmse,
                    mae,
                    train_secs: cum.total_secs(),
                    factor_secs: cum.factor_secs,
                    core_secs: cum.core_secs,
                });
                if self.trainer.opts.verbose {
                    log_info!(
                        "session epoch {}: rmse={rmse:.5} mae={mae:.5} t={:.3}s ({})",
                        epoch + 1,
                        cum.total_secs(),
                        self.trainer.engine.name()
                    );
                }
            }
        }
        self.epochs_run += epochs;
        if epochs > 0 {
            self.model_revision += 1;
        }
        report.total_stats = cum;
        Ok(report)
    }

    /// Batch-score one query's candidate panel (bitwise-equal to the
    /// pointwise oracle).
    pub fn score(&mut self, query: &Query) -> Vec<f32> {
        self.scorer.score(&self.model, self.model_revision, query)
    }

    /// Rank one query's candidates: top-k by `(score desc, item asc)`.
    pub fn top_k(&mut self, query: &Query, k: usize) -> Vec<ScoredItem> {
        self.scorer.top_k(&self.model, self.model_revision, query, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::split::train_test_split;
    use crate::data::stream::ArrivalSim;
    use crate::data::synth::{planted_tucker, Planted, PlantedSpec};

    fn spec() -> PlantedSpec {
        PlantedSpec {
            dims: vec![25, 25, 25],
            nnz: 4000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        }
    }

    fn quick_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.j = 4;
        cfg.r_core = 4;
        cfg.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        cfg.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        cfg
    }

    fn planted_session(seed: u64, cfg: &TrainConfig) -> (Session, Planted) {
        let mut rng = Rng::new(seed);
        let p = planted_tucker(&mut rng, &spec());
        let (train, test) = train_test_split(&p.tensor, 0.1, &mut rng);
        let mut s = Session::new(cfg, train, test, 32, &mut rng).unwrap();
        s.set_verbose(false);
        (s, p)
    }

    #[test]
    fn session_trains_and_serves() {
        let (mut s, _) = planted_session(1, &quick_cfg());
        let (rmse0, _) = s.evaluate();
        s.train_epochs(4).unwrap();
        let (rmse1, _) = s.evaluate();
        assert!(rmse1 < rmse0, "rmse {rmse0} -> {rmse1} did not descend");
        assert_eq!(s.epochs_run(), 4);
        let q = Query { coords: vec![3, 0, 7], candidate_mode: 1, candidates: (0..25).collect() };
        let top = s.top_k(&q, 5);
        assert_eq!(top.len(), 5);
        // Bitwise against the pointwise oracle through the session API.
        let scores = s.score(&q);
        let mut full = q.coords.clone();
        for (i, &c) in q.candidates.iter().enumerate() {
            full[1] = c;
            assert_eq!(scores[i].to_bits(), s.model().predict(&full).to_bits());
        }
    }

    #[test]
    fn training_invalidates_serving_cache_and_appends_do_not() {
        let (mut s, p) = planted_session(2, &quick_cfg());
        s.train_epochs(1).unwrap();
        let q = Query { coords: vec![5, 0, 2], candidate_mode: 1, candidates: (0..25).collect() };
        s.top_k(&q, 3);
        s.top_k(&q, 3);
        let c = s.cache_counters();
        assert_eq!((c.hits, c.misses, c.invalidations), (1, 1, 0));

        // Append: model untouched, staged rows stay valid.
        let mut sim = ArrivalSim::from_planted(&p, &spec());
        let mut rng = Rng::new(99);
        let batch = sim.next_batch(&mut rng, 100);
        let nnz0 = s.train_tensor().nnz();
        s.append(&batch).unwrap();
        assert_eq!(s.train_tensor().nnz(), nnz0 + 100);
        s.top_k(&q, 3);
        let c = s.cache_counters();
        assert_eq!((c.hits, c.invalidations), (2, 0));

        // Warm-start training: model moved, cache must drop.
        s.train_epochs(1).unwrap();
        s.top_k(&q, 3);
        let c = s.cache_counters();
        assert_eq!(c.invalidations, 1);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn parallel_engine_rebuild_counters_track_appends() {
        let mut cfg = quick_cfg();
        cfg.engine = EngineKind::Parallel;
        cfg.workers = 2;
        let (mut s, p) = planted_session(3, &cfg);
        s.train_epochs(2).unwrap();
        let r0 = s.engine_rebuilds().unwrap();
        // Two epochs over unchanged data: one partition build, reused.
        assert_eq!(r0.partition, 1);
        let mut sim = ArrivalSim::from_planted(&p, &spec());
        let mut rng = Rng::new(42);
        s.append(&sim.next_batch(&mut rng, 200)).unwrap();
        s.train_epochs(1).unwrap();
        let r1 = s.engine_rebuilds().unwrap();
        assert_eq!(r1.partition, 2, "append must force exactly one partition rebuild");
        // And no further rebuilds while the data stays put.
        s.train_epochs(1).unwrap();
        assert_eq!(s.engine_rebuilds().unwrap().partition, 2);
    }
}
