//! Multi-threaded evaluation: RMSE/MAE over the test set Γ, parallelized
//! over nonzeros (read-only, embarrassingly parallel).

use crate::model::TuckerModel;
use crate::tensor::SparseTensor;

/// RMSE and MAE of `model` on `test`, computed with `threads` workers.
pub fn rmse_mae_parallel(model: &TuckerModel, test: &SparseTensor, threads: usize) -> (f64, f64) {
    if test.nnz() == 0 {
        return (0.0, 0.0);
    }
    let threads = threads.max(1).min(test.nnz());
    if threads == 1 {
        return crate::kruskal::reconstruct::rmse_mae(model, test);
    }
    let chunk = test.nnz().div_ceil(threads);
    let mut partials = vec![(0.0f64, 0.0f64); threads];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(test.nnz());
            handles.push(scope.spawn(move || {
                let (mut se, mut ae) = (0.0f64, 0.0f64);
                for k in start..end {
                    let e = (crate::kruskal::predict::predict(
                        &model.factors,
                        &model.core,
                        test.index(k),
                    ) - test.value(k)) as f64;
                    se += e * e;
                    ae += e.abs();
                }
                (se, ae)
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            partials[t] = h.join().expect("eval worker panicked");
        }
    });
    let se: f64 = partials.iter().map(|p| p.0).sum();
    let ae: f64 = partials.iter().map(|p| p.1).sum();
    let n = test.nnz() as f64;
    ((se / n).sqrt(), ae / n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::util::Rng;

    #[test]
    fn parallel_matches_serial() {
        let spec = PlantedSpec {
            dims: vec![20, 20, 20],
            nnz: 5000,
            j: 4,
            r_core: 4,
            noise: 0.5,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        let model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 4, 4);
        let (r1, m1) = crate::kruskal::reconstruct::rmse_mae(&model, &p.tensor);
        for threads in [1, 2, 4, 7] {
            let (r, m) = rmse_mae_parallel(&model, &p.tensor, threads);
            assert!((r - r1).abs() < 1e-9, "threads {threads}");
            assert!((m - m1).abs() < 1e-9, "threads {threads}");
        }
    }

    #[test]
    fn empty_test_set() {
        let mut rng = Rng::new(2);
        let model = TuckerModel::init_kruskal(&mut rng, &[4, 4], 2, 2);
        let empty = SparseTensor::empty(vec![4, 4]);
        assert_eq!(rmse_mae_parallel(&model, &empty, 4), (0.0, 0.0));
    }
}
