//! The training orchestrator: builds the engine from a [`TrainConfig`],
//! runs the epoch loop with periodic evaluation, collects the history the
//! experiment drivers plot, and writes checkpoints.

use crate::util::error::{bail, Result};

use crate::algo::{CuTucker, Decomposer, EpochStats, FastTucker, FastTuckerConfig, PTucker, SgdTucker, Vest};
use crate::config::{AlgoKind, EngineKind, TrainConfig};
use crate::coordinator::engine::{Engine, PjrtEngine};
use crate::coordinator::eval::rmse_mae_parallel;
use crate::model::TuckerModel;
use crate::parallel::{ParallelFastTucker, ParallelOptions};
use crate::tensor::SparseTensor;
use crate::util::Rng;
use crate::log_info;

/// Options the trainer needs beyond the model/data (a subset of
/// [`TrainConfig`], so drivers can construct it directly).
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub epochs: usize,
    pub eval_every: usize,
    pub eval_threads: usize,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { epochs: 20, eval_every: 1, eval_threads: 4, verbose: true }
    }
}

/// One evaluated point of the training curve.
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub rmse: f64,
    pub mae: f64,
    /// Cumulative training seconds up to this point (excludes eval).
    pub train_secs: f64,
    pub factor_secs: f64,
    pub core_secs: f64,
}

/// The full result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub history: Vec<EpochRecord>,
    pub total_stats: EpochStats,
}

impl TrainReport {
    pub fn final_rmse(&self) -> f64 {
        self.history.last().map(|r| r.rmse).unwrap_or(f64::NAN)
    }

    pub fn final_mae(&self) -> f64 {
        self.history.last().map(|r| r.mae).unwrap_or(f64::NAN)
    }

    pub fn total_train_secs(&self) -> f64 {
        self.total_stats.total_secs()
    }
}

/// The trainer: an engine plus loop options.
pub struct Trainer {
    pub engine: Engine,
    pub opts: TrainOptions,
}

impl Trainer {
    /// Build engine + model from a full config (the launcher path).
    /// Equivalent to [`Self::from_config_for`] without a workload hint:
    /// planner-sized knobs that need the training nnz (the PJRT
    /// mini-batch cap) fall back to their legacy behavior.
    pub fn from_config(cfg: &TrainConfig, dims: &[usize], rng: &mut Rng) -> Result<(Self, TuckerModel)> {
        Self::from_config_for(cfg, dims, None, rng)
    }

    /// [`Self::from_config`] with the training workload size, letting the
    /// planner size the PJRT mini-batch cap when the config leaves it
    /// unset.
    pub fn from_config_for(
        cfg: &TrainConfig,
        dims: &[usize],
        train_nnz: Option<usize>,
        rng: &mut Rng,
    ) -> Result<(Self, TuckerModel)> {
        let model = match cfg.algo {
            AlgoKind::FastTucker => TuckerModel::init_kruskal(rng, dims, cfg.j, cfg.r_core),
            _ => TuckerModel::init_dense(rng, dims, cfg.j),
        };
        let engine = match cfg.engine {
            EngineKind::Native => {
                let decomposer: Box<dyn Decomposer + Send> = match cfg.algo {
                    AlgoKind::FastTucker => {
                        let fc = FastTuckerConfig {
                            hyper: cfg.hyper,
                            batch: cfg.batch,
                            exactness: cfg.exactness,
                            lanes: cfg.lanes,
                            simd: cfg.simd,
                            wide_accum: cfg.wide_accum,
                            split: cfg.split,
                            threads: cfg.threads,
                            devices: cfg.devices,
                            ..Default::default()
                        };
                        Box::new(FastTucker::new(fc))
                    }
                    AlgoKind::CuTucker => Box::new(CuTucker::new(cfg.hyper)),
                    AlgoKind::SgdTucker => Box::new(SgdTucker::new(cfg.hyper)),
                    AlgoKind::PTucker => Box::new(PTucker::new(cfg.hyper.lambda_factor)),
                    AlgoKind::Vest => Box::new(Vest::new(cfg.hyper.lambda_factor)),
                };
                Engine::Native(decomposer)
            }
            EngineKind::Parallel => {
                if cfg.algo != AlgoKind::FastTucker {
                    bail!("parallel engine requires algo = fasttucker");
                }
                let po = ParallelOptions {
                    workers: cfg.workers,
                    hyper: cfg.hyper,
                    batch: cfg.batch,
                    exactness: cfg.exactness,
                    lanes: cfg.lanes,
                    simd: cfg.simd,
                    wide_accum: cfg.wide_accum,
                    split: cfg.split,
                    threads: cfg.threads,
                    devices: cfg.devices,
                    transport: cfg.transport,
                    prefetch: cfg.prefetch,
                    staleness: cfg.staleness,
                    ..Default::default()
                };
                Engine::Parallel(ParallelFastTucker::new(po))
            }
            EngineKind::Pjrt => {
                if cfg.algo != AlgoKind::FastTucker {
                    bail!("pjrt engine requires algo = fasttucker");
                }
                let dir = std::path::Path::new(&cfg.artifacts_dir);
                let engine = match (cfg.pjrt_batch_cap, train_nnz) {
                    (Some(cap), _) => {
                        PjrtEngine::with_batch_cap(dir, cfg.j, cfg.r_core, cfg.hyper, cap)?
                    }
                    (None, Some(nnz)) => PjrtEngine::auto(dir, cfg.j, cfg.r_core, cfg.hyper, nnz)?,
                    (None, None) => {
                        PjrtEngine::with_batch_cap(dir, cfg.j, cfg.r_core, cfg.hyper, usize::MAX)?
                    }
                };
                Engine::Pjrt(engine)
            }
        };
        // `validate()` already rejected zeros loudly — no silent clamps.
        let opts = TrainOptions {
            epochs: cfg.epochs,
            eval_every: cfg.eval_every,
            eval_threads: cfg.eval_threads,
            verbose: true,
        };
        Ok((Trainer { engine, opts }, model))
    }

    /// Run the training loop.
    pub fn train(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        test: &SparseTensor,
        rng: &mut Rng,
    ) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let mut cum = EpochStats::default();
        // Epoch 0 baseline point.
        let (rmse0, mae0) = rmse_mae_parallel(model, test, self.opts.eval_threads);
        report.history.push(EpochRecord {
            epoch: 0,
            rmse: rmse0,
            mae: mae0,
            train_secs: 0.0,
            factor_secs: 0.0,
            core_secs: 0.0,
        });
        if self.opts.verbose {
            log_info!("epoch 0 (init): rmse={rmse0:.5} mae={mae0:.5}");
        }
        for epoch in 0..self.opts.epochs {
            let stats = self.engine.train_epoch(model, train, epoch, rng)?;
            cum.merge(&stats);
            if (epoch + 1) % self.opts.eval_every == 0 || epoch + 1 == self.opts.epochs {
                let (rmse, mae) = rmse_mae_parallel(model, test, self.opts.eval_threads);
                report.history.push(EpochRecord {
                    epoch: epoch + 1,
                    rmse,
                    mae,
                    train_secs: cum.total_secs(),
                    factor_secs: cum.factor_secs,
                    core_secs: cum.core_secs,
                });
                if self.opts.verbose {
                    log_info!(
                        "epoch {}: rmse={rmse:.5} mae={mae:.5} t={:.3}s ({})",
                        epoch + 1,
                        cum.total_secs(),
                        self.engine.name()
                    );
                }
            }
        }
        report.total_stats = cum;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::split::train_test_split;
    use crate::data::synth::{planted_tucker, PlantedSpec};

    fn quick_cfg(algo: AlgoKind) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.algo = algo;
        cfg.j = 4;
        cfg.r_core = 4;
        cfg.epochs = 6;
        cfg.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        cfg.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        cfg
    }

    fn quick_data(seed: u64) -> (SparseTensor, SparseTensor, Vec<usize>) {
        let spec = PlantedSpec {
            dims: vec![25, 25, 25],
            nnz: 4000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        let p = planted_tucker(&mut rng, &spec);
        let (train, test) = train_test_split(&p.tensor, 0.1, &mut rng);
        (train, test, spec.dims)
    }

    #[test]
    fn all_native_algorithms_train_and_descend() {
        for algo in [
            AlgoKind::FastTucker,
            AlgoKind::CuTucker,
            AlgoKind::SgdTucker,
            AlgoKind::PTucker,
            AlgoKind::Vest,
        ] {
            let cfg = quick_cfg(algo);
            let (train, test, dims) = quick_data(1);
            let mut rng = Rng::new(2);
            let (mut trainer, mut model) =
                Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
            trainer.opts.verbose = false;
            let report = trainer.train(&mut model, &train, &test, &mut rng).unwrap();
            let first = report.history.first().unwrap().rmse;
            let last = report.final_rmse();
            assert!(
                last < first,
                "{}: rmse {first} -> {last} did not descend",
                algo.name()
            );
        }
    }

    #[test]
    fn parallel_engine_from_config() {
        let mut cfg = quick_cfg(AlgoKind::FastTucker);
        cfg.engine = EngineKind::Parallel;
        cfg.workers = 2;
        let (train, test, dims) = quick_data(3);
        let mut rng = Rng::new(4);
        let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
        trainer.opts.verbose = false;
        let report = trainer.train(&mut model, &train, &test, &mut rng).unwrap();
        assert!(report.final_rmse() < report.history[0].rmse);
    }

    #[test]
    fn history_records_monotone_time() {
        let cfg = quick_cfg(AlgoKind::FastTucker);
        let (train, test, dims) = quick_data(5);
        let mut rng = Rng::new(6);
        let (mut trainer, mut model) = Trainer::from_config(&cfg, &dims, &mut rng).unwrap();
        trainer.opts.verbose = false;
        let report = trainer.train(&mut model, &train, &test, &mut rng).unwrap();
        let times: Vec<f64> = report.history.iter().map(|r| r.train_secs).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(report.history.len(), 7); // init + 6 epochs
    }
}
