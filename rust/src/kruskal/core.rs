//! The Kruskal-factored core: N matrices `B^(n)`, stored **transposed**
//! (`R_core × J_n`, one rank-1 component per row) — the paper's coalesced
//! layout (`B^(n)T ∈ R^{R_core × J_n}`, Section 5.1 Memory Coalescing):
//! the SGD inner loop walks `b_r^(n)` as a contiguous slice.

use crate::kruskal::DenseCore;
use crate::model::factors::Matrix;
use crate::tensor::indexing;
use crate::util::Rng;

/// Kruskal core factors, transposed layout.
#[derive(Clone, Debug)]
pub struct KruskalCore {
    /// One `R_core × J` matrix per mode.
    factors: Vec<Matrix>,
    rank: usize,
}

impl KruskalCore {
    pub fn random(rng: &mut Rng, order: usize, j: usize, r_core: usize, scale: f32) -> Self {
        let factors = (0..order)
            .map(|_| Matrix::random(rng, r_core, j, scale))
            .collect();
        KruskalCore { factors, rank: r_core }
    }

    pub fn zeros(order: usize, j: usize, r_core: usize) -> Self {
        let factors = (0..order).map(|_| Matrix::zeros(r_core, j)).collect();
        KruskalCore { factors, rank: r_core }
    }

    pub fn from_factors(factors: Vec<Matrix>) -> Self {
        let rank = factors.first().map(|m| m.rows()).unwrap_or(0);
        assert!(factors.iter().all(|m| m.rows() == rank));
        KruskalCore { factors, rank }
    }

    /// R_core.
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn order(&self) -> usize {
        self.factors.len()
    }

    /// Per-mode J (may differ across modes in principle; equal in practice).
    pub fn j(&self, n: usize) -> usize {
        self.factors[n].cols()
    }

    /// `b_r^(n)` as a contiguous slice.
    #[inline]
    pub fn row(&self, n: usize, r: usize) -> &[f32] {
        self.factors[n].row(r)
    }

    #[inline]
    pub fn row_mut(&mut self, n: usize, r: usize) -> &mut [f32] {
        self.factors[n].row_mut(r)
    }

    pub fn factor(&self, n: usize) -> &Matrix {
        &self.factors[n]
    }

    pub fn factor_mut(&mut self, n: usize) -> &mut Matrix {
        &mut self.factors[n]
    }

    /// Σ_n R·J_n parameters (vs ∏ J_n dense) — the compression the paper
    /// reports as `(Σ_n R_core J_n) / (∏_n J_n)`.
    pub fn param_count(&self) -> usize {
        self.factors.iter().map(|m| m.rows() * m.cols()).sum()
    }

    /// Paper's compression rate relative to the dense core.
    pub fn compression_rate(&self) -> f64 {
        let dense: f64 = self.factors.iter().map(|m| m.cols() as f64).product();
        self.param_count() as f64 / dense
    }

    /// Materialize the dense core `G[j_1..j_N] = Σ_r Π_n b^(n)_{r,j_n}`.
    /// Exponential in N — used by baselines and oracle tests only.
    pub fn to_dense(&self) -> DenseCore {
        let dims: Vec<usize> = self.factors.iter().map(|m| m.cols()).collect();
        let len: usize = dims.iter().product();
        let mut data = vec![0.0f32; len];
        let mut coords = vec![0u32; self.order()];
        for (idx, slot) in data.iter_mut().enumerate() {
            indexing::dense_coords(idx, &dims, &mut coords);
            let mut acc = 0.0f32;
            for r in 0..self.rank {
                let mut prod = 1.0f32;
                for n in 0..self.order() {
                    prod *= self.factors[n].get(r, coords[n] as usize);
                }
                acc += prod;
            }
            *slot = acc;
        }
        DenseCore::from_data(dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_params() {
        let mut rng = Rng::new(1);
        let k = KruskalCore::random(&mut rng, 3, 4, 2, 1.0);
        assert_eq!(k.order(), 3);
        assert_eq!(k.rank(), 2);
        assert_eq!(k.j(0), 4);
        assert_eq!(k.param_count(), 3 * 2 * 4);
        assert!((k.compression_rate() - 24.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn to_dense_matches_definition() {
        let mut rng = Rng::new(2);
        let k = KruskalCore::random(&mut rng, 3, 3, 2, 1.0);
        let d = k.to_dense();
        // Check a few entries against the rank-1 sum by hand.
        for coords in [[0u32, 0, 0], [2, 1, 0], [1, 2, 2]] {
            let mut want = 0.0f32;
            for r in 0..2 {
                want += k.row(0, r)[coords[0] as usize]
                    * k.row(1, r)[coords[1] as usize]
                    * k.row(2, r)[coords[2] as usize];
            }
            assert!((d.get(&coords) - want).abs() < 1e-5);
        }
    }

    #[test]
    fn rank_one_dense_is_outer_product() {
        let b0 = Matrix::from_data(1, 2, vec![2.0, 3.0]);
        let b1 = Matrix::from_data(1, 2, vec![5.0, 7.0]);
        let k = KruskalCore::from_factors(vec![b0, b1]);
        let d = k.to_dense();
        assert_eq!(d.get(&[0, 0]), 10.0);
        assert_eq!(d.get(&[1, 0]), 15.0);
        assert_eq!(d.get(&[0, 1]), 14.0);
        assert_eq!(d.get(&[1, 1]), 21.0);
    }
}
