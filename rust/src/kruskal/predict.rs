//! Prediction over the Kruskal-factored model — the one oracle-pinned
//! path every layer scores through (ISSUE 9 tentpole, move 1).
//!
//! Three tiers, each bitwise-identical to the one below it:
//!
//! * [`predict_one`] — the pointwise oracle (Eq. 9 / Theorem 1):
//!   `x̂ = Σ_r Π_n (a^(n)_{i_n} · b^(n)_r)`, every dot through
//!   [`crate::util::linalg::dot`]. This is the function that *defines*
//!   the model's value at a coordinate; the planted-data generator, the
//!   evaluators, and the dense reconstruction oracle all call it.
//! * [`predict`] — the [`CoreRepr`] dispatch (Kruskal fast path / dense
//!   baseline core), deduplicating the match that was hand-copied into
//!   `model/mod.rs`, `coordinator/eval.rs`, and `kruskal/reconstruct.rs`.
//! * [`StagedQuery`] + [`score_panel`] — the batched serving scorer: a
//!   user's fixed coordinates are staged **once** (per-rank prefix
//!   products over the modes before the candidate mode, plus the
//!   individual suffix dots after it), then a whole candidate panel is
//!   scored at `O(R·J)` per candidate instead of `O(N·R·J)`, with the
//!   candidate-mode dots computed in lane blocks of four ranks
//!   ([`candidate_dot_panel`], the `kernel/panel.rs` shape over the
//!   core's transposed `R_core × J` factor).
//!
//! # Why the panel scorer is bitwise against the pointwise oracle
//!
//! f32 addition and multiplication are deterministic; only *association*
//! can diverge. [`predict_one`] evaluates, for each rank `r`,
//! `((1.0 · d_0) · d_1) ⋯ · d_{N-1}` and accumulates ranks sequentially.
//! [`stage_query`] computes `pre[r] = ((1.0 · d_0) ⋯) · d_{m-1}` with the
//! same left fold and stores each suffix dot `d_n` (`n > m`) unreduced;
//! [`score_panel`] continues the fold `((pre[r] · d_m) · d_{m+1}) ⋯` in
//! mode order and accumulates ranks in the same sequence. Every `d_n` is
//! produced by `dot`'s own association (the lane-blocked panel keeps four
//! partial sums per rank and reduces `(acc0 + acc1) + (acc2 + acc3) +
//! tail`, exactly `dot`), so every intermediate is bit-equal and the
//! final scores match `predict_one` bitwise — property-pinned below over
//! layouts, orders, and candidate counts.
//!
//! Serving always reads the f32 instantiation of the (now generic, see
//! [`crate::util::element::Element`]) factor storage: prediction is the
//! bitwise contract surface, so it takes no `SimdLevel`/`wide_accum`
//! dependence — those knobs live entirely in the training kernels.

use crate::kruskal::KruskalCore;
use crate::model::factors::FactorMatrices;
use crate::model::CoreRepr;
use crate::util::linalg::dot;

/// Pointwise prediction for one coordinate through the Kruskal core
/// (Eq. 9, the linear Theorem-1 path). The crate's prediction oracle.
pub fn predict_one(factors: &FactorMatrices, core: &KruskalCore, coords: &[u32]) -> f32 {
    let r_core = core.rank();
    let mut acc = 0.0f32;
    for r in 0..r_core {
        let mut prod = 1.0f32;
        for n in 0..factors.order() {
            let a_row = factors.row(n, coords[n] as usize);
            let b_row = core.row(n, r);
            prod *= dot(a_row, b_row);
        }
        acc += prod;
    }
    acc
}

/// Predict one entry through whichever core representation is held —
/// the single Kruskal/Dense dispatch (formerly triplicated).
pub fn predict(factors: &FactorMatrices, core: &CoreRepr, coords: &[u32]) -> f32 {
    match core {
        CoreRepr::Kruskal(k) => predict_one(factors, k, coords),
        CoreRepr::Dense(d) => d.predict(factors, coords),
    }
}

/// A staged serving query: the per-rank state of [`predict_one`]'s fold
/// with one mode (the candidate mode) left open. Built once per user,
/// reused for every candidate — and cached across requests by
/// [`crate::serve::HotRowCache`].
#[derive(Clone, Debug)]
pub struct StagedQuery {
    /// The open (candidate) mode `m`.
    mode: usize,
    /// `pre[r] = ((1.0 · d_0) · d_1) ⋯ · d_{m-1}` — the oracle's fold up
    /// to the candidate mode.
    pre: Vec<f32>,
    /// Suffix dots `d_n` for `n > m`, unreduced (rank-major:
    /// `suf[r * n_suf + (n - m - 1)]`); multiplied into the fold in mode
    /// order per candidate.
    suf: Vec<f32>,
    n_suf: usize,
}

impl StagedQuery {
    pub fn mode(&self) -> usize {
        self.mode
    }

    /// Bytes held (cache accounting).
    pub fn footprint_bytes(&self) -> usize {
        (self.pre.len() + self.suf.len()) * std::mem::size_of::<f32>()
    }
}

/// Stage a user's fixed coordinates, leaving `mode` open for candidates.
/// `coords[mode]` is ignored. Cost: one `O(N·R·J)` pass — the same work
/// [`predict_one`] would spend on a *single* candidate.
pub fn stage_query(
    factors: &FactorMatrices,
    core: &KruskalCore,
    coords: &[u32],
    mode: usize,
) -> StagedQuery {
    let order = factors.order();
    assert!(mode < order, "candidate mode {mode} out of range for order {order}");
    let r_core = core.rank();
    let n_suf = order - mode - 1;
    let mut pre = Vec::with_capacity(r_core);
    let mut suf = vec![0.0f32; r_core * n_suf];
    for r in 0..r_core {
        let mut prod = 1.0f32;
        for n in 0..mode {
            prod *= dot(factors.row(n, coords[n] as usize), core.row(n, r));
        }
        pre.push(prod);
        for n in mode + 1..order {
            suf[r * n_suf + (n - mode - 1)] =
                dot(factors.row(n, coords[n] as usize), core.row(n, r));
        }
    }
    StagedQuery { mode, pre, suf, n_suf }
}

/// Candidate-mode dot panel: `out[r] = a · b^(m)_r` for every rank, in
/// lane blocks of four ranks over the core factor's contiguous
/// `R_core × J` rows (the `kernel/panel.rs` block shape). Each rank's
/// reduction keeps `dot`'s exact association — four partial sums over
/// `j`-quads, reduced `(p0 + p1) + (p2 + p3) + tail` — so the panel is
/// bitwise-identical to calling [`dot`] per rank.
fn candidate_dot_panel(core: &KruskalCore, mode: usize, a_row: &[f32], out: &mut [f32]) {
    let r_core = core.rank();
    let j = core.j(mode);
    debug_assert_eq!(out.len(), r_core);
    debug_assert_eq!(a_row.len(), j);
    let bm = core.factor(mode).data();
    let quads = j / 4;
    let mut r = 0;
    while r + 4 <= r_core {
        // Four ranks per block, four partial lanes per rank.
        let mut acc = [[0.0f32; 4]; 4];
        for q in 0..quads {
            let base = q * 4;
            for (w, accw) in acc.iter_mut().enumerate() {
                let b_row = &bm[(r + w) * j + base..(r + w) * j + base + 4];
                accw[0] += a_row[base] * b_row[0];
                accw[1] += a_row[base + 1] * b_row[1];
                accw[2] += a_row[base + 2] * b_row[2];
                accw[3] += a_row[base + 3] * b_row[3];
            }
        }
        for (w, accw) in acc.iter().enumerate() {
            let mut tail = 0.0f32;
            for i in quads * 4..j {
                tail += a_row[i] * bm[(r + w) * j + i];
            }
            out[r + w] = (accw[0] + accw[1]) + (accw[2] + accw[3]) + tail;
        }
        r += 4;
    }
    // Rank tail: plain `dot` (the same association by definition).
    for w in r..r_core {
        out[w] = dot(a_row, core.row(mode, w));
    }
}

/// Score one candidate against a staged query. Bitwise-identical to
/// [`predict_one`] with the candidate substituted into the open mode.
pub fn score_one(
    staged: &StagedQuery,
    factors: &FactorMatrices,
    core: &KruskalCore,
    candidate: u32,
) -> f32 {
    let a_row = factors.row(staged.mode, candidate as usize);
    let r_core = core.rank();
    let mut acc = 0.0f32;
    for r in 0..r_core {
        let mut prod = staged.pre[r] * dot(a_row, core.row(staged.mode, r));
        for i in 0..staged.n_suf {
            prod *= staged.suf[r * staged.n_suf + i];
        }
        acc += prod;
    }
    acc
}

/// Score a whole candidate panel against a staged query, writing
/// `out[s] = x̂(coords with candidates[s])`. The hot serving loop: the
/// candidate-mode dots come from the lane-blocked
/// [`candidate_dot_panel`]; the fold and rank accumulation replay
/// [`predict_one`]'s association, so every score is bitwise-identical to
/// the pointwise oracle.
pub fn score_panel(
    staged: &StagedQuery,
    factors: &FactorMatrices,
    core: &KruskalCore,
    candidates: &[u32],
    out: &mut Vec<f32>,
) {
    let r_core = core.rank();
    out.clear();
    out.reserve(candidates.len());
    let mut dots = vec![0.0f32; r_core];
    for &c in candidates {
        let a_row = factors.row(staged.mode, c as usize);
        candidate_dot_panel(core, staged.mode, a_row, &mut dots);
        let mut acc = 0.0f32;
        for r in 0..r_core {
            let mut prod = staged.pre[r] * dots[r];
            for i in 0..staged.n_suf {
                prod *= staged.suf[r * staged.n_suf + i];
            }
            acc += prod;
        }
        out.push(acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TuckerModel;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn kruskal_parts(model: &TuckerModel) -> &KruskalCore {
        match &model.core {
            CoreRepr::Kruskal(k) => k,
            _ => unreachable!(),
        }
    }

    #[test]
    fn predict_dispatches_both_reprs() {
        let mut rng = Rng::new(1);
        let m = TuckerModel::init_kruskal(&mut rng, &[8, 9, 10], 4, 4);
        let k = kruskal_parts(&m).clone();
        let dense = k.to_dense();
        let md = TuckerModel { factors: m.factors.clone(), core: CoreRepr::Dense(dense) };
        let coords = [3u32, 4, 5];
        let a = predict(&m.factors, &m.core, &coords);
        let b = predict(&md.factors, &md.core, &coords);
        assert!((a - b).abs() < 1e-4);
        assert_eq!(a.to_bits(), predict_one(&m.factors, kruskal_parts(&m), &coords).to_bits());
    }

    #[test]
    fn score_one_is_bitwise_predict_one() {
        let mut rng = Rng::new(2);
        let m = TuckerModel::init_kruskal(&mut rng, &[12, 30, 9], 8, 8);
        let core = kruskal_parts(&m);
        let staged = stage_query(&m.factors, core, &[5, 0, 7], 1);
        for c in 0..30u32 {
            let want = predict_one(&m.factors, core, &[5, c, 7]);
            let got = score_one(&staged, &m.factors, core, c);
            assert_eq!(got.to_bits(), want.to_bits(), "candidate {c}");
        }
    }

    #[test]
    fn prop_panel_scorer_bitwise_over_layouts() {
        // The acceptance pin: panel scores == pointwise oracle, bit for
        // bit, over random orders, mode sizes, J / R_core (hitting both
        // the 4-rank lane blocks and the rank/quad tails), candidate
        // modes, and candidate counts (with repeats).
        forall("batch panel scorer bitwise vs predict_one", 40, |rng| {
            let order = 2 + rng.gen_range(4); // 2..=5
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(20)).collect();
            let j = 1 + rng.gen_range(12); // exercises quad tails
            let r_core = 1 + rng.gen_range(11); // exercises rank tails
            let mut r2 = Rng::new(rng.next_u64());
            let model = TuckerModel::init_kruskal(&mut r2, &dims, j, r_core);
            let core = kruskal_parts(&model);
            let mode = rng.gen_range(order);
            let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
            let n_cand = 1 + rng.gen_range(2 * dims[mode]); // duplicates allowed
            let candidates: Vec<u32> =
                (0..n_cand).map(|_| rng.gen_range(dims[mode]) as u32).collect();

            let staged = stage_query(&model.factors, core, &coords, mode);
            let mut scores = Vec::new();
            score_panel(&staged, &model.factors, core, &candidates, &mut scores);
            assert_eq!(scores.len(), candidates.len());
            let mut full = coords.clone();
            for (s, &c) in candidates.iter().enumerate() {
                full[mode] = c;
                let want = predict_one(&model.factors, core, &full);
                assert_eq!(
                    scores[s].to_bits(),
                    want.to_bits(),
                    "order {order} dims {dims:?} j {j} r {r_core} mode {mode} cand {c}"
                );
                let one = score_one(&staged, &model.factors, core, c);
                assert_eq!(one.to_bits(), want.to_bits());
            }
        });
    }

    #[test]
    fn staged_footprint_is_small() {
        let mut rng = Rng::new(3);
        let m = TuckerModel::init_kruskal(&mut rng, &[10, 10, 10], 4, 6);
        let staged = stage_query(&m.factors, kruskal_parts(&m), &[1, 0, 2], 1);
        // pre: R floats; suf: R * (order - mode - 1) floats.
        assert_eq!(staged.footprint_bytes(), (6 + 6) * 4);
        assert_eq!(staged.mode(), 1);
    }
}
