//! Full reconstruction of small tensors (oracle for tests) and error
//! measurement against sparse observations.

use crate::kruskal::KruskalCore;
use crate::model::factors::FactorMatrices;
use crate::model::TuckerModel;
use crate::tensor::{indexing, DenseTensor, SparseTensor};

/// Reconstruct the entire dense tensor `X̂ = G ×_1 A^(1) … ×_N A^(N)`
/// from a Kruskal-cored model. Exponential — tests only.
pub fn reconstruct_dense(factors: &FactorMatrices, core: &KruskalCore) -> DenseTensor {
    let dims = factors.dims();
    let mut out = DenseTensor::zeros(dims.clone());
    let mut coords = vec![0u32; dims.len()];
    let len = out.len();
    for idx in 0..len {
        indexing::dense_coords(idx, &dims, &mut coords);
        out.data_mut()[idx] = crate::kruskal::predict::predict_one(factors, core, &coords);
    }
    out
}

/// RMSE of a model against a sparse test set Γ (the paper's metric).
pub fn rmse(model: &TuckerModel, test: &SparseTensor) -> f64 {
    if test.nnz() == 0 {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (coords, v) in test.iter() {
        let e = (model.predict(coords) - v) as f64;
        acc += e * e;
    }
    (acc / test.nnz() as f64).sqrt()
}

/// MAE of a model against a sparse test set Γ.
pub fn mae(model: &TuckerModel, test: &SparseTensor) -> f64 {
    if test.nnz() == 0 {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (coords, v) in test.iter() {
        acc += ((model.predict(coords) - v) as f64).abs();
    }
    acc / test.nnz() as f64
}

/// Both metrics in one pass (evaluation hot path).
pub fn rmse_mae(model: &TuckerModel, test: &SparseTensor) -> (f64, f64) {
    if test.nnz() == 0 {
        return (0.0, 0.0);
    }
    let (mut se, mut ae) = (0.0f64, 0.0f64);
    for (coords, v) in test.iter() {
        let e = (crate::kruskal::predict::predict(&model.factors, &model.core, coords) - v)
            as f64;
        se += e * e;
        ae += e.abs();
    }
    let n = test.nnz() as f64;
    ((se / n).sqrt(), ae / n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CoreRepr;
    use crate::util::Rng;

    #[test]
    fn zero_error_on_planted_truth() {
        let mut rng = Rng::new(6);
        let spec = crate::data::synth::PlantedSpec {
            dims: vec![10, 12, 8],
            nnz: 200,
            j: 3,
            r_core: 2,
            noise: 0.0,
            clamp: None,
        };
        let p = crate::data::synth::planted_tucker(&mut rng, &spec);
        let model = TuckerModel {
            factors: p.truth_factors.clone(),
            core: CoreRepr::Kruskal(p.truth_core.clone()),
        };
        assert!(rmse(&model, &p.tensor) < 1e-4);
        assert!(mae(&model, &p.tensor) < 1e-4);
    }

    #[test]
    fn rmse_mae_consistent_with_singles() {
        let mut rng = Rng::new(7);
        let spec = crate::data::synth::PlantedSpec {
            dims: vec![10, 10, 10],
            nnz: 100,
            j: 3,
            r_core: 2,
            noise: 0.5,
            clamp: None,
        };
        let p = crate::data::synth::planted_tucker(&mut rng, &spec);
        let model = TuckerModel::init_kruskal(&mut rng, &[10, 10, 10], 3, 2);
        let (r, m) = rmse_mae(&model, &p.tensor);
        assert!((r - rmse(&model, &p.tensor)).abs() < 1e-9);
        assert!((m - mae(&model, &p.tensor)).abs() < 1e-9);
        assert!(r >= m); // RMSE dominates MAE.
    }

    #[test]
    fn reconstruct_matches_pointwise_predict() {
        let mut rng = Rng::new(8);
        let model = TuckerModel::init_kruskal(&mut rng, &[4, 5, 6], 3, 2);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k,
            _ => unreachable!(),
        };
        let dense = reconstruct_dense(&model.factors, core);
        for coords in [[0u32, 0, 0], [3, 4, 5], [2, 2, 2]] {
            assert!((dense.get(&coords) - model.predict(&coords)).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_test_set_is_zero_error() {
        let mut rng = Rng::new(9);
        let model = TuckerModel::init_kruskal(&mut rng, &[4, 4], 2, 2);
        let empty = SparseTensor::empty(vec![4, 4]);
        assert_eq!(rmse(&model, &empty), 0.0);
        assert_eq!(rmse_mae(&model, &empty), (0.0, 0.0));
    }
}
