//! Kruskal (CP) approximation of the Tucker core — the paper's central
//! memory/compute reduction (Eq. 9): `G ≈ Σ_r b^(1)_r ∘ … ∘ b^(N)_r`.

pub mod core;
pub mod dense_core;
pub mod predict;
pub mod reconstruct;

pub use core::KruskalCore;
pub use dense_core::DenseCore;
