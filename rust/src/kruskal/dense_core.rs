//! Explicit dense Tucker core `G ∈ R^{J_1 × … × J_N}` — the representation
//! the baselines (cuTucker, SGD_Tucker, P-Tucker, Vest) carry, with the
//! exponential-cost contraction the paper's Kruskal strategy replaces.

use crate::model::factors::FactorMatrices;
use crate::tensor::{indexing, DenseTensor};
use crate::util::Rng;

/// Dense core tensor.
#[derive(Clone, Debug)]
pub struct DenseCore {
    tensor: DenseTensor,
}

impl DenseCore {
    pub fn random(rng: &mut Rng, order: usize, j: usize, scale: f32) -> Self {
        let dims = vec![j; order];
        let len: usize = dims.iter().product();
        let data = (0..len).map(|_| scale * rng.normal()).collect();
        DenseCore { tensor: DenseTensor::from_data(dims, data) }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        DenseCore { tensor: DenseTensor::zeros(dims) }
    }

    pub fn from_data(dims: Vec<usize>, data: Vec<f32>) -> Self {
        DenseCore { tensor: DenseTensor::from_data(dims, data) }
    }

    pub fn dims(&self) -> &[usize] {
        self.tensor.dims()
    }

    pub fn len(&self) -> usize {
        self.tensor.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensor.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        self.tensor.data()
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.tensor.data_mut()
    }

    #[inline]
    pub fn get(&self, coords: &[u32]) -> f32 {
        self.tensor.get(coords)
    }

    /// Predict one entry by the full contraction
    /// `x̂ = Σ_{j_1..j_N} G[j..] Π_n a^(n)_{i_n, j_n}` — O(∏ J) per entry,
    /// the exponential path the paper's Theorems remove.
    pub fn predict(&self, factors: &FactorMatrices, coords: &[u32]) -> f32 {
        let dims = self.dims();
        let order = dims.len();
        let mut core_coords = vec![0u32; order];
        let mut acc = 0.0f64;
        for (idx, &g) in self.data().iter().enumerate() {
            indexing::dense_coords(idx, dims, &mut core_coords);
            let mut prod = g as f64;
            for n in 0..order {
                prod *= factors.row(n, coords[n] as usize)[core_coords[n] as usize] as f64;
            }
            acc += prod;
        }
        acc as f32
    }

    /// The per-sample mode-`n` coefficient vector through the dense core:
    /// `D^(n)[j_n] = Σ_{j_m, m≠n} G[j..] Π_{m≠n} a^(m)_{i_m, j_m}`
    /// (the paper's `D = G^(n) S^T` column for one sample). Cost O(∏ J).
    pub fn mode_coeff(
        &self,
        factors: &FactorMatrices,
        coords: &[u32],
        n: usize,
        out: &mut [f32],
    ) {
        let dims = self.dims();
        let order = dims.len();
        assert_eq!(out.len(), dims[n]);
        out.fill(0.0);
        let mut core_coords = vec![0u32; order];
        for (idx, &g) in self.data().iter().enumerate() {
            indexing::dense_coords(idx, dims, &mut core_coords);
            let mut prod = g;
            for m in 0..order {
                if m != n {
                    prod *= factors.row(m, coords[m] as usize)[core_coords[m] as usize];
                }
            }
            out[core_coords[n] as usize] += prod;
        }
    }

    /// Gradient direction of the core for one sample: `Π_n a^(n)_{i_n, j_n}`
    /// accumulated into `grad` scaled by `scale` (typically `e`).
    pub fn accumulate_core_grad(
        &self,
        factors: &FactorMatrices,
        coords: &[u32],
        scale: f32,
        grad: &mut [f32],
    ) {
        let dims = self.dims();
        let order = dims.len();
        assert_eq!(grad.len(), self.len());
        let mut core_coords = vec![0u32; order];
        for (idx, slot) in grad.iter_mut().enumerate() {
            indexing::dense_coords(idx, dims, &mut core_coords);
            let mut prod = scale;
            for n in 0..order {
                prod *= factors.row(n, coords[n] as usize)[core_coords[n] as usize];
            }
            *slot += prod;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::dot;

    #[test]
    fn predict_equals_mode_coeff_dot_row() {
        // x̂ = a^(n) · D^(n) must hold for every n.
        let mut rng = Rng::new(3);
        let dims = [6usize, 7, 8];
        let factors = FactorMatrices::random(&mut rng, &dims, 3, 1.0);
        let core = DenseCore::random(&mut rng, 3, 3, 1.0);
        let coords = [5u32, 6, 7];
        let xhat = core.predict(&factors, &coords);
        for n in 0..3 {
            let mut d = vec![0.0f32; 3];
            core.mode_coeff(&factors, &coords, n, &mut d);
            let via = dot(factors.row(n, coords[n] as usize), &d);
            assert!((xhat - via).abs() < 1e-4, "mode {n}: {xhat} vs {via}");
        }
    }

    #[test]
    fn core_grad_is_outer_product_of_rows() {
        let mut rng = Rng::new(4);
        let factors = FactorMatrices::random(&mut rng, &[4, 5], 2, 1.0);
        let core = DenseCore::random(&mut rng, 2, 2, 1.0);
        let coords = [1u32, 2];
        let mut grad = vec![0.0f32; core.len()];
        core.accumulate_core_grad(&factors, &coords, 2.0, &mut grad);
        let a0 = factors.row(0, 1);
        let a1 = factors.row(1, 2);
        // Layout: mode-0 fastest.
        for j1 in 0..2 {
            for j0 in 0..2 {
                let want = 2.0 * a0[j0] * a1[j1];
                assert!((grad[j1 * 2 + j0] - want).abs() < 1e-5);
            }
        }
    }
}
