//! Step-executable runtime: resolves the AOT artifact manifest produced by
//! `python/compile/aot.py` and executes the step functions. On builds with
//! a PJRT client this executed the compiled HLO; this offline build lowers
//! each artifact to the in-crate batched kernel ([`crate::kernel::batched`])
//! with the same buffer interface — python never runs here either way.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactEntry, Manifest};
pub use pjrt::{PjrtRuntime, StepExecutable};
