//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate. This is the only bridge between the Rust coordinator
//! and the JAX/Pallas compute path — python never runs here.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactEntry, Manifest};
pub use pjrt::{PjrtRuntime, StepExecutable};
