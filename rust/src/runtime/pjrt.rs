//! The step-executable runtime behind the PJRT engine.
//!
//! Historically this module compiled the AOT HLO text artifacts
//! (`python/compile/aot.py`) through the `xla` crate's PJRT CPU client.
//! This build is fully offline with no `xla` crate available, so the
//! runtime **lowers each artifact to the in-crate batched kernel**
//! ([`crate::kernel::batched`]) instead: the artifact manifest still
//! selects the entry point and its compile-time shapes `(J, R, B)`, and
//! [`StepExecutable::run`] executes the same mini-batch math the JAX
//! `train_step`/`predict` graphs encode (python/compile/model.py), with
//! the same buffer interface — so the engine layer is agnostic to which
//! backend actually ran.
//!
//! Native step conventions (mirroring aot.py's lowering):
//!
//! * `train_step`: inputs `a1 a2 a3 (B×J) | b1 b2 b3 (R×J) | x (B) |
//!   lr () | lam ()`, outputs `a1' a2' a3' | gb1 gb2 gb3 (R×J) | e (B)`
//!   (7 outputs).
//! * `predict`: inputs `a1 a2 a3 | b1 b2 b3`, output `x̂ (B)` (1 output).

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{anyhow, bail, Context, Result};

use crate::kernel::batched::{minibatch_predict, minibatch_train_step};
use crate::runtime::artifacts::{ArtifactEntry, Manifest};

/// Which native step an artifact lowers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NativeStep {
    /// 9 inputs → 7 outputs (updated rows, core grads, residuals).
    TrainStep,
    /// 6 inputs → 1 output (predictions).
    Predict,
}

impl NativeStep {
    fn from_entry(entry: &ArtifactEntry) -> Result<NativeStep> {
        let (step, n_outputs) = match entry.name.as_str() {
            "train_step" => (NativeStep::TrainStep, 7),
            "predict" => (NativeStep::Predict, 1),
            // factor_step is lowered by aot.py but unused by the engine.
            other => bail!("no native lowering for artifact {other:?}"),
        };
        if entry.n_outputs != n_outputs {
            bail!(
                "artifact {} declares {} outputs, native lowering produces {}",
                entry.name,
                entry.n_outputs,
                n_outputs
            );
        }
        Ok(step)
    }
}

/// A compiled step function plus its shape metadata.
pub struct StepExecutable {
    pub entry: ArtifactEntry,
    step: NativeStep,
}

impl StepExecutable {
    /// Execute with raw f32 buffers. `inputs` are (data, shape) pairs in
    /// the artifact's argument order; outputs come back as flat vecs.
    pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        for (idx, (data, shape)) in inputs.iter().enumerate() {
            let expected: i64 = shape.iter().product();
            if expected != data.len() as i64 {
                return Err(anyhow!(
                    "input {idx}: shape {:?} does not match buffer length {}",
                    shape,
                    data.len()
                ));
            }
        }
        let (j, r, b) = (self.entry.j, self.entry.r_core, self.entry.batch);
        let order = 3usize; // artifacts are order-3, fixed at build time
        match self.step {
            NativeStep::TrainStep => {
                if inputs.len() != 9 {
                    bail!(
                        "train_step expects 9 inputs (a×3, b×3, x, lr, lam), got {}",
                        inputs.len()
                    );
                }
                let a_panels: Vec<&[f32]> = (0..order).map(|n| inputs[n].0).collect();
                let b_mats: Vec<&[f32]> = (0..order).map(|n| inputs[3 + n].0).collect();
                let vals = inputs[6].0;
                let lr = *inputs[7]
                    .0
                    .first()
                    .ok_or_else(|| anyhow!("empty lr buffer"))?;
                let lam = *inputs[8]
                    .0
                    .first()
                    .ok_or_else(|| anyhow!("empty lambda buffer"))?;
                for (n, a) in a_panels.iter().enumerate() {
                    if a.len() != b * j {
                        bail!("a{} has {} elements, want {}", n + 1, a.len(), b * j);
                    }
                }
                for (n, bm) in b_mats.iter().enumerate() {
                    if bm.len() != r * j {
                        bail!("b{} has {} elements, want {}", n + 1, bm.len(), r * j);
                    }
                }
                if vals.len() != b {
                    bail!("x has {} elements, want {}", vals.len(), b);
                }
                let mut new_rows: Vec<Vec<f32>> =
                    (0..order).map(|_| vec![0.0f32; b * j]).collect();
                let mut core_grads: Vec<Vec<f32>> =
                    (0..order).map(|_| vec![0.0f32; r * j]).collect();
                let mut residuals = vec![0.0f32; b];
                minibatch_train_step(
                    order,
                    b,
                    r,
                    j,
                    &a_panels,
                    &b_mats,
                    vals,
                    lr,
                    lam,
                    &mut new_rows,
                    &mut core_grads,
                    &mut residuals,
                );
                let mut outs = new_rows;
                outs.append(&mut core_grads);
                outs.push(residuals);
                Ok(outs)
            }
            NativeStep::Predict => {
                if inputs.len() != 6 {
                    bail!("predict expects 6 inputs (a×3, b×3), got {}", inputs.len());
                }
                let a_panels: Vec<&[f32]> = (0..order).map(|n| inputs[n].0).collect();
                let b_mats: Vec<&[f32]> = (0..order).map(|n| inputs[3 + n].0).collect();
                for (n, a) in a_panels.iter().enumerate() {
                    if a.len() != b * j {
                        bail!("a{} has {} elements, want {}", n + 1, a.len(), b * j);
                    }
                }
                for (n, bm) in b_mats.iter().enumerate() {
                    if bm.len() != r * j {
                        bail!("b{} has {} elements, want {}", n + 1, bm.len(), r * j);
                    }
                }
                let mut out = vec![0.0f32; b];
                minibatch_predict(order, b, r, j, &a_panels, &b_mats, &mut out);
                Ok(vec![out])
            }
        }
    }
}

/// The runtime: the artifact manifest plus a cache of lowered executables.
pub struct PjrtRuntime {
    manifest: Manifest,
    cache: HashMap<String, StepExecutable>,
    /// Only consider artifacts with batch ≤ this when resolving variants.
    batch_cap: usize,
}

impl PjrtRuntime {
    /// Create from an artifacts directory (expects `manifest.tsv`).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(PjrtRuntime { manifest, cache: HashMap::new(), batch_cap: usize::MAX })
    }

    /// Restrict variant resolution to artifacts with batch ≤ `cap`.
    pub fn set_batch_cap(&mut self, cap: usize) {
        self.batch_cap = cap;
    }

    /// Size the mini-batch cap from the workload via the planner cost
    /// model ([`crate::kernel::planner::pjrt_batch_cap`]): the artifact
    /// `train_step` applies a sum-reduced mini-batch gradient, so on
    /// small tensors the largest compiled batch averages away per-epoch
    /// progress. Call before the first [`Self::load`].
    pub fn set_auto_batch_cap(&mut self, train_nnz: usize) {
        self.batch_cap = crate::kernel::planner::pjrt_batch_cap(train_nnz);
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "native-batched-kernel".to_string()
    }

    /// Lower (or fetch from cache) the executable for `(name, j, r)`.
    pub fn load(&mut self, name: &str, j: usize, r_core: usize) -> Result<&StepExecutable> {
        let key = format!("{name}_j{j}_r{r_core}");
        if !self.cache.contains_key(&key) {
            let entry = self
                .manifest
                .find_capped(name, j, r_core, self.batch_cap)
                .with_context(|| {
                    format!(
                        "no artifact for {name} (J={j}, R={r_core}); available: {:?} — \
                         rebuild with `make artifacts` or pass --variants to aot.py",
                        self.manifest.variants(name)
                    )
                })?
                .clone();
            let step = NativeStep::from_entry(&entry)?;
            self.cache.insert(key.clone(), StepExecutable { entry, step });
        }
        Ok(&self.cache[&key])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.tsv").exists()
    }

    fn synthetic_runtime() -> PjrtRuntime {
        // A runtime backed by a manifest literal — the native lowering
        // never opens the HLO files, so tests need no artifacts on disk.
        let manifest = Manifest::parse(
            "train_step\ttrain_step_j8_r8_b64.hlo.txt\t8\t8\t64\t7\n\
             predict\tpredict_j8_r8_b64.hlo.txt\t8\t8\t64\t1\n",
            Path::new("/nonexistent"),
        )
        .unwrap();
        PjrtRuntime { manifest, cache: HashMap::new(), batch_cap: usize::MAX }
    }

    #[test]
    fn predict_executes_and_matches_native() {
        let mut rt = synthetic_runtime();
        let (j, r) = (8usize, 8usize);
        let exe = rt.load("predict", j, r).unwrap();
        let b = exe.entry.batch;

        // Random staged rows; compare against the native Thm-1/2 path.
        let mut rng = crate::util::Rng::new(1);
        let mk = |rng: &mut crate::util::Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal()).collect()
        };
        let a1 = mk(&mut rng, b * j);
        let a2 = mk(&mut rng, b * j);
        let a3 = mk(&mut rng, b * j);
        let b1 = mk(&mut rng, r * j);
        let b2 = mk(&mut rng, r * j);
        let b3 = mk(&mut rng, r * j);
        let row = [b as i64, j as i64];
        let bshape = [r as i64, j as i64];
        let outs = exe
            .run(&[
                (&a1, &row),
                (&a2, &row),
                (&a3, &row),
                (&b1, &bshape),
                (&b2, &bshape),
                (&b3, &bshape),
            ])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let xhat = &outs[0];
        assert_eq!(xhat.len(), b);

        // Native check on a few samples.
        for s in [0usize, 17, b - 1] {
            let mut want = 0.0f32;
            for rr in 0..r {
                let mut prod = 1.0f32;
                for (a, bf) in [(&a1, &b1), (&a2, &b2), (&a3, &b3)] {
                    let mut d = 0.0f32;
                    for jj in 0..j {
                        d += a[s * j + jj] * bf[rr * j + jj];
                    }
                    prod *= d;
                }
                want += prod;
            }
            assert!(
                (xhat[s] - want).abs() < 1e-3,
                "sample {s}: {} vs {want}",
                xhat[s]
            );
        }
    }

    #[test]
    fn train_step_outputs_have_declared_shapes() {
        let mut rt = synthetic_runtime();
        let (j, r) = (8usize, 8usize);
        let exe = rt.load("train_step", j, r).unwrap();
        let b = exe.entry.batch;
        let mut rng = crate::util::Rng::new(2);
        let mk = |rng: &mut crate::util::Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal()).collect()
        };
        let a: Vec<Vec<f32>> = (0..3).map(|_| mk(&mut rng, b * j)).collect();
        let bm: Vec<Vec<f32>> = (0..3).map(|_| mk(&mut rng, r * j)).collect();
        let vals = mk(&mut rng, b);
        let row = [b as i64, j as i64];
        let bshape = [r as i64, j as i64];
        let scalar: [i64; 1] = [1];
        let lr = [0.01f32];
        let lam = [0.001f32];
        let outs = exe
            .run(&[
                (&a[0], &row),
                (&a[1], &row),
                (&a[2], &row),
                (&bm[0], &bshape),
                (&bm[1], &bshape),
                (&bm[2], &bshape),
                (&vals, &[b as i64]),
                (&lr, &scalar),
                (&lam, &scalar),
            ])
            .unwrap();
        assert_eq!(outs.len(), exe.entry.n_outputs);
        for n in 0..3 {
            assert_eq!(outs[n].len(), b * j, "updated rows {n}");
            assert_eq!(outs[3 + n].len(), r * j, "core grads {n}");
        }
        assert_eq!(outs[6].len(), b, "residuals");

        // Oracle: per-sample Thm-1/2 contraction through the kernel layer
        // must reproduce the residuals and the Eq. 13 row updates.
        let core = crate::kruskal::KruskalCore::from_factors(
            bm.iter()
                .map(|d| crate::model::factors::Matrix::from_data(r, j, d.clone()))
                .collect(),
        );
        let mut ws = crate::kernel::Workspace::new(3, r, j);
        for s in [0usize, 31, b - 1] {
            for n in 0..3 {
                ws.stage_row(n, &a[n][s * j..(s + 1) * j]);
            }
            let e = crate::kernel::contract_staged(
                &mut ws,
                &core,
                &[],
                crate::kernel::CoreLayout::Packed,
                vals[s],
            );
            assert!(
                (outs[6][s] - e).abs() < 1e-4,
                "residual {s}: {} vs {e}",
                outs[6][s]
            );
            for n in 0..3 {
                let gs = ws.gs_row(n);
                for jj in 0..j {
                    let want = (1.0 - lr[0] * lam[0]) * a[n][s * j + jj]
                        - lr[0] * e * gs[jj];
                    let got = outs[n][s * j + jj];
                    assert!(
                        (want - got).abs() < 1e-4,
                        "row update mode {n} s {s} j {jj}: {want} vs {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn auto_batch_cap_follows_workload() {
        let mut rt = synthetic_runtime();
        // Small workload: planner cap 64 excludes the only (b=64) variant?
        // No — 64 <= 64, still resolvable.
        rt.set_auto_batch_cap(4_000);
        assert_eq!(rt.batch_cap, 64);
        assert!(rt.load("predict", 8, 8).is_ok());
        // Large workload: cap grows, still bounded.
        let mut rt = synthetic_runtime();
        rt.set_auto_batch_cap(100_000);
        assert_eq!(rt.batch_cap, 2048);
    }

    #[test]
    fn missing_variant_gives_useful_error() {
        let mut rt = synthetic_runtime();
        let err = match rt.load("predict", 3, 3) {
            Ok(_) => panic!("expected missing-variant error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("no artifact"), "{err}");
    }

    #[test]
    fn on_disk_manifest_loads_if_built() {
        if !have_artifacts() {
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        assert!(rt.load("predict", 8, 8).is_ok() || rt.load("predict", 16, 16).is_ok());
    }
}
