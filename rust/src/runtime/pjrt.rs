//! The PJRT executor: HLO text → `HloModuleProto` → compile on the CPU
//! PJRT client → execute with `Literal` buffers.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifacts::{ArtifactEntry, Manifest};

/// A compiled step function plus its shape metadata.
pub struct StepExecutable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl StepExecutable {
    /// Execute with raw f32 buffers. `inputs` are (data, shape) pairs in
    /// the artifact's argument order; outputs come back as flat vecs.
    pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data);
            let expected: i64 = shape.iter().product();
            if expected != data.len() as i64 {
                return Err(anyhow!(
                    "shape {:?} does not match buffer length {}",
                    shape,
                    data.len()
                ));
            }
            let lit = if shape.len() == 1 && shape[0] == data.len() as i64 {
                lit
            } else {
                lit.reshape(shape).map_err(|e| anyhow!("reshape: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack n_outputs elements.
        let parts = tuple.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != self.entry.n_outputs {
            return Err(anyhow!(
                "artifact {} returned {} outputs, manifest says {}",
                self.entry.name,
                parts.len(),
                self.entry.n_outputs
            ));
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// The runtime: one PJRT CPU client plus a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, StepExecutable>,
    /// Only consider artifacts with batch ≤ this when resolving variants.
    batch_cap: usize,
}

impl PjrtRuntime {
    /// Create from an artifacts directory (expects `manifest.tsv`).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtRuntime { client, manifest, cache: HashMap::new(), batch_cap: usize::MAX })
    }

    /// Restrict variant resolution to artifacts with batch ≤ `cap`.
    pub fn set_batch_cap(&mut self, cap: usize) {
        self.batch_cap = cap;
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `(name, j, r)`.
    pub fn load(&mut self, name: &str, j: usize, r_core: usize) -> Result<&StepExecutable> {
        let key = format!("{name}_j{j}_r{r_core}");
        if !self.cache.contains_key(&key) {
            let entry = self
                .manifest
                .find_capped(name, j, r_core, self.batch_cap)
                .with_context(|| {
                    format!(
                        "no artifact for {name} (J={j}, R={r_core}); available: {:?} — \
                         rebuild with `make artifacts` or pass --variants to aot.py",
                        self.manifest.variants(name)
                    )
                })?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| anyhow!("parse {:?}: {e:?}", entry.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
            self.cache.insert(key.clone(), StepExecutable { entry, exe });
        }
        Ok(&self.cache[&key])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.tsv").exists()
    }

    #[test]
    fn predict_executes_and_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        let (j, r) = (8usize, 8usize);
        let exe = rt.load("predict", j, r).unwrap();
        let b = exe.entry.batch;

        // Random staged rows; compare against the native Thm-1/2 path.
        let mut rng = crate::util::Rng::new(1);
        let mk = |rng: &mut crate::util::Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal()).collect()
        };
        let a1 = mk(&mut rng, b * j);
        let a2 = mk(&mut rng, b * j);
        let a3 = mk(&mut rng, b * j);
        let b1 = mk(&mut rng, r * j);
        let b2 = mk(&mut rng, r * j);
        let b3 = mk(&mut rng, r * j);
        let row = [b as i64, j as i64];
        let bshape = [r as i64, j as i64];
        let outs = exe
            .run(&[
                (&a1, &row),
                (&a2, &row),
                (&a3, &row),
                (&b1, &bshape),
                (&b2, &bshape),
                (&b3, &bshape),
            ])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let xhat = &outs[0];
        assert_eq!(xhat.len(), b);

        // Native check on a few samples.
        for s in [0usize, 17, b - 1] {
            let mut want = 0.0f32;
            for rr in 0..r {
                let mut prod = 1.0f32;
                for (a, bf) in [(&a1, &b1), (&a2, &b2), (&a3, &b3)] {
                    let mut d = 0.0f32;
                    for jj in 0..j {
                        d += a[s * j + jj] * bf[rr * j + jj];
                    }
                    prod *= d;
                }
                want += prod;
            }
            assert!(
                (xhat[s] - want).abs() < 1e-3,
                "sample {s}: {} vs {want}",
                xhat[s]
            );
        }
    }

    #[test]
    fn missing_variant_gives_useful_error() {
        if !have_artifacts() {
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        let err = match rt.load("predict", 3, 3) {
            Ok(_) => panic!("expected missing-variant error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("no artifact"), "{err}");
    }
}
