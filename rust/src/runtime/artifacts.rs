//! The artifact manifest: `artifacts/manifest.tsv` written by aot.py,
//! mapping entry points to HLO files and their compile-time shapes.
//!
//! Format (tab-separated): `name  file  J  R  B  n_outputs`.

use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};

/// One AOT-compiled entry point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub j: usize,
    pub r_core: usize,
    pub batch: usize,
    pub n_outputs: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactEntry>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 6 {
                bail!("manifest line {}: expected 6 fields, got {}", lineno + 1, f.len());
            }
            entries.push(ArtifactEntry {
                name: f[0].to_string(),
                file: dir.join(f[1]),
                j: f[2].parse().context("bad J")?,
                r_core: f[3].parse().context("bad R")?,
                batch: f[4].parse().context("bad B")?,
                n_outputs: f[5].parse().context("bad n_outputs")?,
            });
        }
        if entries.is_empty() {
            bail!("empty manifest");
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Find an entry by name and shape. When several batch variants are
    /// compiled, prefer the largest batch (amortizes per-execute overhead;
    /// perf pass iteration 5, EXPERIMENTS.md §Perf).
    pub fn find(&self, name: &str, j: usize, r_core: usize) -> Option<&ArtifactEntry> {
        self.find_capped(name, j, r_core, usize::MAX)
    }

    /// [`Self::find`] restricted to batch ≤ `cap`.
    pub fn find_capped(
        &self,
        name: &str,
        j: usize,
        r_core: usize,
        cap: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.name == name && e.j == j && e.r_core == r_core && e.batch <= cap)
            .max_by_key(|e| e.batch)
    }

    /// Shape variants available for `name`.
    pub fn variants(&self, name: &str) -> Vec<(usize, usize, usize)> {
        self.entries
            .iter()
            .filter(|e| e.name == name)
            .map(|e| (e.j, e.r_core, e.batch))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str =
        "train_step\ttrain_step_j8_r8_b256.hlo.txt\t8\t8\t256\t7\n\
         predict\tpredict_j8_r8_b256.hlo.txt\t8\t8\t256\t1\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.find("train_step", 8, 8).unwrap();
        assert_eq!(e.batch, 256);
        assert_eq!(e.n_outputs, 7);
        assert!(e.file.ends_with("train_step_j8_r8_b256.hlo.txt"));
        assert!(m.find("train_step", 16, 16).is_none());
    }

    #[test]
    fn variants_listed() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.variants("predict"), vec![(8, 8, 256)]);
        assert!(m.variants("nope").is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("bad line", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("", Path::new("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Runs only when `make artifacts` has produced the files.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("train_step", 8, 8).is_some());
        }
    }
}
