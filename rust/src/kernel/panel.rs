//! Panel microkernels: fixed-lane-width, SIMD-shaped inner loops over the
//! batched executor's `batch × J` / `batch × R_core` panels.
//!
//! The batched executor ([`crate::kernel::batched`]) defers the mode-≥1
//! contraction steps of a whole group and runs them panel-wide:
//!
//! * **c-panel** — `c[s][n][r] = b_r^(n) · a[s][n]` for every sample `s`
//!   of the group (step 1 of Thm 1/2, the paper's warp-shuffle dot);
//! * **gs-panel** — `GS[s][n] = Σ_r w[s][n][r] · b_r^(n)` (step 3, the
//!   factor-update coefficient).
//!
//! This module owns those inner loops as **lane-blocked microkernels**:
//! the `R_core` dimension is processed in fixed-width blocks of
//! [`Lanes`] rows (4 or 8), each block keeping one scalar accumulator
//! per row so LLVM sees straight-line, associativity-preserving code it
//! can autovectorize today, and `std::simd` can replace verbatim once
//! stable (each lane block is exactly one future `f32x4`/`f32x8`
//! register group; cuFasterTucker's register blocking, arXiv:2210.06014,
//! is the GPU analogue).
//!
//! **The bitwise contract.** Exact-mode batched execution must stay
//! bit-identical to the scalar executor, so every microkernel reproduces
//! the float association of the scalar path's primitives
//! ([`matvec_rowmajor`] / [`weighted_rowsum`] / [`dot`] / [`axpy`]):
//!
//! * rows `0 .. R - R%4` (the scalar primitives' full-quad region) are
//!   plain sequential sums over `j`, one accumulator per row — widening
//!   the lane block from 4 to 8 changes *which rows share a pass*, never
//!   the per-row reduction order;
//! * tail rows `R - R%4 .. R` go through [`dot`] (c-panel) and [`axpy`]
//!   (gs-panel), the exact tail association of the scalar primitives;
//! * an 8-lane gs block adds its two 4-term partial sums to `out[j]`
//!   **separately**, matching the two quad passes of
//!   [`weighted_rowsum`] bit for bit.
//!
//! Pinned by this module's unit tests (every lane width × tail length)
//! and end-to-end by
//! `tests/properties.rs::prop_panel_microkernel_bitwise_matches_scalar`.
//!
//! Under [`CoreLayout::Strided`](crate::kernel::contract::CoreLayout) the
//! panels walk the column-major core mirror per sample via the shared
//! strided primitives — lane width does not apply there (the strided walk
//! is the paper's uncoalesced global-memory ablation, kept structurally
//! identical to the scalar path by construction).

use crate::util::linalg::{axpy, dot, matvec_rowmajor, weighted_rowsum};

/// Lane width of the panel microkernels: how many `R_core` rows one
/// register block carries. [`Lanes::Auto`] is resolved per plan by the
/// planner ([`crate::kernel::planner::choose_params`]) from `R_core`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lanes {
    /// Let the planner pick from `R_core` (8 when a full 8-block exists,
    /// else 4).
    #[default]
    Auto,
    /// 4-row blocks (one future `f32x4` group; the legacy shape).
    W4,
    /// 8-row blocks (one future `f32x8` / AVX2 group).
    W8,
}

impl Lanes {
    /// Concrete width for a given `R_core`. `Auto` takes 8 only when at
    /// least one full 8-block exists; tiny ranks stay at 4.
    #[inline]
    pub fn resolve(self, r_core: usize) -> usize {
        match self {
            Lanes::W4 => 4,
            Lanes::W8 => 8,
            Lanes::Auto => {
                if r_core >= 8 {
                    8
                } else {
                    4
                }
            }
        }
    }

    /// Width as configured (0 = auto), for observability snapshots.
    #[inline]
    pub fn code(self) -> usize {
        match self {
            Lanes::Auto => 0,
            Lanes::W4 => 4,
            Lanes::W8 => 8,
        }
    }

    /// Parse a config/CLI spelling (`"auto"`, `"4"`, `"8"`).
    pub fn parse(s: &str) -> Option<Lanes> {
        match s {
            "auto" => Some(Lanes::Auto),
            "4" => Some(Lanes::W4),
            "8" => Some(Lanes::W8),
            _ => None,
        }
    }
}

/// Batched c-panel (Packed layout): `c[s][n] = B^(n) a[s][n]` for samples
/// `0..b`, `B` rows lane-blocked by `width` (4 or 8). Per-(sample, row)
/// accumulation is bitwise identical to [`matvec_rowmajor`]: sequential
/// sums for rows below `r - r % 4`, [`dot`] association for the tail.
#[allow(clippy::too_many_arguments)]
pub fn c_panel_packed(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
    width: usize,
) {
    debug_assert!(width == 4 || width == 8);
    let mut rr = 0;
    if width == 8 {
        while rr + 8 <= r {
            let rows: [&[f32]; 8] = [
                &bm[rr * j..(rr + 1) * j],
                &bm[(rr + 1) * j..(rr + 2) * j],
                &bm[(rr + 2) * j..(rr + 3) * j],
                &bm[(rr + 3) * j..(rr + 4) * j],
                &bm[(rr + 4) * j..(rr + 5) * j],
                &bm[(rr + 5) * j..(rr + 6) * j],
                &bm[(rr + 6) * j..(rr + 7) * j],
                &bm[(rr + 7) * j..(rr + 8) * j],
            ];
            for s in 0..b {
                let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
                let mut acc = [0.0f32; 8];
                for jj in 0..j {
                    let xj = a[jj];
                    acc[0] += rows[0][jj] * xj;
                    acc[1] += rows[1][jj] * xj;
                    acc[2] += rows[2][jj] * xj;
                    acc[3] += rows[3][jj] * xj;
                    acc[4] += rows[4][jj] * xj;
                    acc[5] += rows[5][jj] * xj;
                    acc[6] += rows[6][jj] * xj;
                    acc[7] += rows[7][jj] * xj;
                }
                c_panel[(s * order + n) * r + rr..(s * order + n) * r + rr + 8]
                    .copy_from_slice(&acc);
            }
            rr += 8;
        }
    }
    while rr + 4 <= r {
        let r0 = &bm[rr * j..(rr + 1) * j];
        let r1 = &bm[(rr + 1) * j..(rr + 2) * j];
        let r2 = &bm[(rr + 2) * j..(rr + 3) * j];
        let r3 = &bm[(rr + 3) * j..(rr + 4) * j];
        for s in 0..b {
            let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for jj in 0..j {
                let xj = a[jj];
                a0 += r0[jj] * xj;
                a1 += r1[jj] * xj;
                a2 += r2[jj] * xj;
                a3 += r3[jj] * xj;
            }
            let cbase = (s * order + n) * r + rr;
            c_panel[cbase] = a0;
            c_panel[cbase + 1] = a1;
            c_panel[cbase + 2] = a2;
            c_panel[cbase + 3] = a3;
        }
        rr += 4;
    }
    while rr < r {
        let brow = &bm[rr * j..(rr + 1) * j];
        for s in 0..b {
            let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            c_panel[(s * order + n) * r + rr] = dot(brow, a);
        }
        rr += 1;
    }
}

/// Batched gs-panel (Packed layout): `GS[s][n] = Σ_r w[s][n][r] b_r`,
/// lane-blocked by `width`. Bitwise identical to [`weighted_rowsum`]: an
/// 8-lane block contributes its two quad partial sums to `out[j]` as two
/// separate adds (the two quad passes of the scalar primitive); tail rows
/// go through [`axpy`].
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_packed(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
    width: usize,
) {
    debug_assert!(width == 4 || width == 8);
    for s in 0..b {
        gs_panel[(s * order + n) * j..(s * order + n + 1) * j].fill(0.0);
    }
    let mut rr = 0;
    if width == 8 {
        while rr + 8 <= r {
            let rows: [&[f32]; 8] = [
                &bm[rr * j..(rr + 1) * j],
                &bm[(rr + 1) * j..(rr + 2) * j],
                &bm[(rr + 2) * j..(rr + 3) * j],
                &bm[(rr + 3) * j..(rr + 4) * j],
                &bm[(rr + 4) * j..(rr + 5) * j],
                &bm[(rr + 5) * j..(rr + 6) * j],
                &bm[(rr + 6) * j..(rr + 7) * j],
                &bm[(rr + 7) * j..(rr + 8) * j],
            ];
            for s in 0..b {
                let wbase = (s * order + n) * r + rr;
                let w = &w_panel[wbase..wbase + 8];
                let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
                for jj in 0..j {
                    // Two quad partial sums added separately: the exact
                    // float sequence of two width-4 passes.
                    let q0 =
                        w[0] * rows[0][jj] + w[1] * rows[1][jj] + w[2] * rows[2][jj] + w[3] * rows[3][jj];
                    let q1 =
                        w[4] * rows[4][jj] + w[5] * rows[5][jj] + w[6] * rows[6][jj] + w[7] * rows[7][jj];
                    out[jj] = (out[jj] + q0) + q1;
                }
            }
            rr += 8;
        }
    }
    while rr + 4 <= r {
        let r0 = &bm[rr * j..(rr + 1) * j];
        let r1 = &bm[(rr + 1) * j..(rr + 2) * j];
        let r2 = &bm[(rr + 2) * j..(rr + 3) * j];
        let r3 = &bm[(rr + 3) * j..(rr + 4) * j];
        for s in 0..b {
            let wbase = (s * order + n) * r + rr;
            let (w0, w1, w2, w3) = (
                w_panel[wbase],
                w_panel[wbase + 1],
                w_panel[wbase + 2],
                w_panel[wbase + 3],
            );
            let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
            for jj in 0..j {
                out[jj] += w0 * r0[jj] + w1 * r1[jj] + w2 * r2[jj] + w3 * r3[jj];
            }
        }
        rr += 4;
    }
    while rr < r {
        let brow = &bm[rr * j..(rr + 1) * j];
        for s in 0..b {
            let w = w_panel[(s * order + n) * r + rr];
            let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
            axpy(w, brow, out);
        }
        rr += 1;
    }
}

/// Batched c-panel under the Strided layout: per-sample calls of the
/// shared [`strided_matvec`](crate::kernel::contract::strided_matvec) —
/// bitwise identical to the scalar path by construction (lane width does
/// not apply to the strided walk).
#[allow(clippy::too_many_arguments)]
pub fn c_panel_strided(
    col: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
) {
    for s in 0..b {
        crate::kernel::contract::strided_matvec(
            col,
            r,
            &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
            &mut c_panel[(s * order + n) * r..(s * order + n) * r + r],
        );
    }
}

/// Batched gs-panel under the Strided layout: per-sample calls of the
/// shared
/// [`strided_weighted_sum`](crate::kernel::contract::strided_weighted_sum).
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_strided(
    col: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
) {
    for s in 0..b {
        crate::kernel::contract::strided_weighted_sum(
            col,
            r,
            j,
            &w_panel[(s * order + n) * r..(s * order + n) * r + r],
            &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j],
        );
    }
}

/// Reference c-panel: the scalar primitive applied sample by sample (what
/// the microkernels must reproduce bitwise). Test-support, also used by
/// the bench harness to sanity-check a build.
#[allow(clippy::too_many_arguments)]
pub fn c_panel_reference(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
) {
    for s in 0..b {
        matvec_rowmajor(
            bm,
            r,
            j,
            &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
            &mut c_panel[(s * order + n) * r..(s * order + n) * r + r],
        );
    }
}

/// Reference gs-panel: [`weighted_rowsum`] sample by sample.
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_reference(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
) {
    for s in 0..b {
        weighted_rowsum(
            bm,
            r,
            j,
            &w_panel[(s * order + n) * r..(s * order + n) * r + r],
            &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn lanes_resolve_and_parse() {
        assert_eq!(Lanes::Auto.resolve(16), 8);
        assert_eq!(Lanes::Auto.resolve(8), 8);
        assert_eq!(Lanes::Auto.resolve(7), 4);
        assert_eq!(Lanes::Auto.resolve(1), 4);
        assert_eq!(Lanes::W4.resolve(32), 4);
        assert_eq!(Lanes::W8.resolve(2), 8);
        assert_eq!(Lanes::parse("auto"), Some(Lanes::Auto));
        assert_eq!(Lanes::parse("4"), Some(Lanes::W4));
        assert_eq!(Lanes::parse("8"), Some(Lanes::W8));
        assert_eq!(Lanes::parse("16"), None);
        assert_eq!(Lanes::Auto.code(), 0);
        assert_eq!(Lanes::W8.code(), 8);
    }

    /// Every lane width × every tail length (r mod 4 and r mod 8 both
    /// sweep 0..) × odd j: the microkernels are bitwise equal to the
    /// per-sample scalar primitives.
    #[test]
    fn microkernels_bitwise_match_reference_all_tails() {
        let mut rng = Rng::new(7);
        let (order, n, b) = (3usize, 1usize, 9usize);
        for r in 1..=17 {
            for j in [1usize, 3, 4, 6, 8, 11] {
                let bm: Vec<f32> = (0..r * j).map(|_| rng.normal()).collect();
                let a_panel: Vec<f32> = (0..b * order * j).map(|_| rng.normal()).collect();
                let w_panel: Vec<f32> = (0..b * order * r).map(|_| rng.normal()).collect();

                let mut c_ref = vec![0.0f32; b * order * r];
                c_panel_reference(&bm, r, j, order, n, b, &a_panel, &mut c_ref);
                let mut gs_ref = vec![0.0f32; b * order * j];
                gs_panel_reference(&bm, r, j, order, n, b, &w_panel, &mut gs_ref);

                for width in [4usize, 8] {
                    let mut c = vec![0.0f32; b * order * r];
                    c_panel_packed(&bm, r, j, order, n, b, &a_panel, &mut c, width);
                    for (x, y) in c.iter().zip(c_ref.iter()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "c-panel diverged: r={r} j={j} width={width}"
                        );
                    }
                    let mut gs = vec![0.0f32; b * order * j];
                    gs_panel_packed(&bm, r, j, order, n, b, &w_panel, &mut gs, width);
                    for (x, y) in gs.iter().zip(gs_ref.iter()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "gs-panel diverged: r={r} j={j} width={width}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn strided_panels_match_strided_primitives() {
        // The strided panels are per-sample calls of the shared strided
        // primitives; pin the panel indexing (slot math), not the math.
        let mut rng = Rng::new(9);
        let (order, n, b, r, j) = (3usize, 2usize, 5usize, 6usize, 5usize);
        let core = crate::kruskal::KruskalCore::random(&mut rng, order, j, r, 0.5);
        let strided = crate::kernel::contract::build_strided(&core);
        let a_panel: Vec<f32> = (0..b * order * j).map(|_| rng.normal()).collect();
        let w_panel: Vec<f32> = (0..b * order * r).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f32; b * order * r];
        c_panel_strided(&strided[n], r, j, order, n, b, &a_panel, &mut c);
        let mut gs = vec![0.0f32; b * order * j];
        gs_panel_strided(&strided[n], r, j, order, n, b, &w_panel, &mut gs);
        for s in 0..b {
            let mut c1 = vec![0.0f32; r];
            crate::kernel::contract::strided_matvec(
                &strided[n],
                r,
                &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
                &mut c1,
            );
            assert_eq!(&c[(s * order + n) * r..(s * order + n) * r + r], &c1[..]);
            let mut g1 = vec![0.0f32; j];
            crate::kernel::contract::strided_weighted_sum(
                &strided[n],
                r,
                j,
                &w_panel[(s * order + n) * r..(s * order + n) * r + r],
                &mut g1,
            );
            assert_eq!(&gs[(s * order + n) * j..(s * order + n + 1) * j], &g1[..]);
        }
    }
}
