//! Panel microkernels: fixed-lane-width SIMD inner loops over the
//! batched executor's `batch × J` / `batch × R_core` panels.
//!
//! The batched executor ([`crate::kernel::batched`]) defers the mode-≥1
//! contraction steps of a whole group and runs them panel-wide:
//!
//! * **c-panel** — `c[s][n][r] = b_r^(n) · a[s][n]` for every sample `s`
//!   of the group (step 1 of Thm 1/2, the paper's warp-shuffle dot);
//! * **gs-panel** — `GS[s][n] = Σ_r w[s][n][r] · b_r^(n)` (step 3, the
//!   factor-update coefficient).
//!
//! This module owns those inner loops as **lane-blocked microkernels**:
//! the `R_core` dimension is processed in fixed-width blocks of
//! [`Lanes`] rows (4 or 8), and since ISSUE 10 the full lane blocks
//! execute with **real arch intrinsics** — SSE2/AVX2 on `x86_64`, NEON
//! on `aarch64` — behind runtime feature detection
//! (`is_x86_feature_detected!`). The [`SimdLevel`] knob
//! (`PlanParams::simd` / config `simd = ...` / `--simd` /
//! `FASTTUCKER_SIMD`) selects the vector width: `Scalar` keeps the
//! original straight-line Rust, `V128` uses 128-bit registers
//! (SSE2/NEON), `V256` uses 256-bit AVX2 registers (on hardware without
//! AVX2, or on `aarch64`, `V256` runs as paired 128-bit ops), and
//! `Auto` — the default — picks the widest level the host supports,
//! unless `FASTTUCKER_SIMD` overrides it. cuFasterTucker's register
//! blocking (arXiv:2210.06014) is the GPU analogue of this layout.
//!
//! **The bitwise contract.** Exact-mode batched execution must stay
//! bit-identical to the scalar executor at EVERY level, so the vector
//! paths perform, per lane, exactly the float sequence of the scalar
//! path's primitives ([`matvec_rowmajor`] / [`weighted_rowsum`] /
//! [`dot`] / [`axpy`]):
//!
//! * **c-panel** vectorizes *across* the block's rows: the lane block is
//!   packed column-major once per block (`packed[jj*w + i] =
//!   b_{rr+i}[jj]`, amortized over the group's samples) and each
//!   `acc_vec += col_vec * splat(a[jj])` step is, in every lane `i`,
//!   the scalar `acc[i] += rows[i][jj] * xj` in the same `jj` order;
//! * **gs-panel** vectorizes *along* `j`: each lane `jj` evaluates the
//!   scalar expression verbatim (width-4 block: `out[jj] += ((w0·r0 +
//!   w1·r1) + w2·r2) + w3·r3`; width-8 block: two quad partials added
//!   to `out[jj]` separately), with the leftover `j`-tail running the
//!   identical scalar expression;
//! * **no FMA anywhere** — fused multiply-add rounds once where the
//!   scalar path rounds twice, so the vector paths use separate
//!   mul/add intrinsics only (IEEE-exact, hence bit-equal per lane);
//! * tail rows `R - R%4 .. R` go through [`dot`] (c-panel) and
//!   [`axpy`] (gs-panel) at every level, the exact tail association of
//!   the scalar primitives.
//!
//! Because every level computes identical bits, level resolution is
//! semantically invisible (an unsupported request silently degrades to
//! the widest supported level) and the `FASTTUCKER_SIMD=scalar` CI leg
//! is a whole-suite differential against the intrinsics. Pinned by this
//! module's unit tests (every level × lane width × tail length) and
//! end-to-end by
//! `tests/properties.rs::prop_panel_microkernel_bitwise_matches_scalar`.
//!
//! Under [`CoreLayout::Strided`](crate::kernel::contract::CoreLayout) the
//! panels walk the column-major core mirror per sample via the shared
//! strided primitives — lane width and SIMD level do not apply there
//! (the strided walk is the paper's uncoalesced global-memory ablation,
//! kept structurally identical to the scalar path by construction).

use crate::util::linalg::{axpy, dot, matvec_rowmajor, weighted_rowsum};

/// Lane width of the panel microkernels: how many `R_core` rows one
/// register block carries. [`Lanes::Auto`] is resolved per plan by the
/// planner ([`crate::kernel::planner::choose_params`]) from `R_core`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lanes {
    /// Let the planner pick from `R_core` (8 when a full 8-block exists,
    /// else 4).
    #[default]
    Auto,
    /// 4-row blocks (one `f32x4` group; the legacy shape).
    W4,
    /// 8-row blocks (one `f32x8` / AVX2 group).
    W8,
}

impl Lanes {
    /// Concrete width for a given `R_core`. `Auto` takes 8 only when at
    /// least one full 8-block exists; tiny ranks stay at 4.
    #[inline]
    pub fn resolve(self, r_core: usize) -> usize {
        match self {
            Lanes::W4 => 4,
            Lanes::W8 => 8,
            Lanes::Auto => {
                if r_core >= 8 {
                    8
                } else {
                    4
                }
            }
        }
    }

    /// Width as configured (0 = auto), for observability snapshots.
    #[inline]
    pub fn code(self) -> usize {
        match self {
            Lanes::Auto => 0,
            Lanes::W4 => 4,
            Lanes::W8 => 8,
        }
    }

    /// Parse a config/CLI spelling (`"auto"`, `"4"`, `"8"`).
    pub fn parse(s: &str) -> Option<Lanes> {
        match s {
            "auto" => Some(Lanes::Auto),
            "4" => Some(Lanes::W4),
            "8" => Some(Lanes::W8),
            _ => None,
        }
    }
}

/// The `FASTTUCKER_SIMD` environment variable: overrides
/// [`SimdLevel::Auto`] resolution (the CI forced-scalar differential
/// leg). Accepted spellings: `auto`, `scalar`, `v128`, `v256`. Invalid
/// values abort loudly — a typo'd level must never silently test less
/// than CI thinks (the `FASTTUCKER_FAULT_*` validation precedent).
pub const SIMD_VAR: &str = "FASTTUCKER_SIMD";

/// Vector width of the panel microkernels' full lane blocks. Every
/// level computes **identical bits** (see the module docs), so the knob
/// trades only speed; resolution degrades unsupported requests to the
/// widest supported level without changing results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdLevel {
    /// Widest level the host supports (AVX2 → `V256`, else SSE2/NEON →
    /// `V128`, else `Scalar`), unless `FASTTUCKER_SIMD` overrides.
    #[default]
    Auto,
    /// The straight-line Rust lane blocks (the pre-ISSUE-10 code path;
    /// the oracle the vector paths are differential-tested against).
    Scalar,
    /// 128-bit registers: SSE2 (`x86_64` baseline) or NEON (`aarch64`
    /// baseline).
    V128,
    /// 256-bit AVX2 registers; on non-AVX2 `x86_64` hardware falls back
    /// to `V128`, on `aarch64` runs as paired 128-bit NEON ops
    /// (bit-identical either way).
    V256,
}

impl SimdLevel {
    /// Parse a config/CLI/env spelling.
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s {
            "auto" => Some(SimdLevel::Auto),
            "scalar" => Some(SimdLevel::Scalar),
            "v128" => Some(SimdLevel::V128),
            "v256" => Some(SimdLevel::V256),
            _ => None,
        }
    }

    /// Level as configured, for observability snapshots and cache keys
    /// (0 = auto, 1 = scalar, 4/8 = vector lane floats).
    #[inline]
    pub fn code(self) -> usize {
        match self {
            SimdLevel::Auto => 0,
            SimdLevel::Scalar => 1,
            SimdLevel::V128 => 4,
            SimdLevel::V256 => 8,
        }
    }

    /// Resolve to a concrete, hardware-supported level (never `Auto`).
    /// `Auto` consults `FASTTUCKER_SIMD` (invalid values abort loudly),
    /// else detects the widest supported level; explicit levels are
    /// honored, clamped to what the host can run. Resolution happens
    /// once per plan execution (`run_plan` / the dispatch pool), not in
    /// the hot loop.
    pub fn resolve(self) -> SimdLevel {
        let requested = match self {
            SimdLevel::Auto => match env_simd() {
                Some(SimdLevel::Auto) | None => SimdLevel::detect_best(),
                Some(level) => level,
            },
            other => other,
        };
        SimdLevel::clamp_to_host(requested)
    }

    /// Widest level the host supports.
    fn detect_best() -> SimdLevel {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                SimdLevel::V256
            } else {
                SimdLevel::V128
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON is part of the aarch64 baseline; V256 would only pair
            // two q-registers for the same bits, so Auto stops at V128.
            SimdLevel::V128
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            SimdLevel::Scalar
        }
    }

    /// Clamp an explicit request to what this host can execute. The
    /// degrade is semantically invisible: all levels are bit-identical.
    fn clamp_to_host(requested: SimdLevel) -> SimdLevel {
        match requested {
            SimdLevel::Scalar => SimdLevel::Scalar,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::V256 if !std::arch::is_x86_feature_detected!("avx2") => SimdLevel::V128,
            #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
            other => other,
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            _ => SimdLevel::Scalar,
        }
    }
}

/// Cached `FASTTUCKER_SIMD` parse: `None` when unset, loud panic on an
/// invalid or non-unicode value (never a silent default — the ISSUE 10
/// env-validation rule, matching `FaultPlan::from_env`).
fn env_simd() -> Option<SimdLevel> {
    static ENV: std::sync::OnceLock<Option<SimdLevel>> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| {
        let raw = std::env::var_os(SIMD_VAR)?;
        let Some(s) = raw.to_str() else {
            panic!("{SIMD_VAR} is not valid unicode: {raw:?} (expected auto|scalar|v128|v256)");
        };
        match SimdLevel::parse(s.trim()) {
            Some(level) => Some(level),
            None => panic!("{SIMD_VAR}={s:?} is not a SIMD level (expected auto|scalar|v128|v256)"),
        }
    })
}

/// Stack budget (floats) for the column-major lane-block pack buffer;
/// `j * width` beyond it heap-allocates once per panel call.
const PACK_STACK: usize = 256;

/// Batched c-panel (Packed layout): `c[s][n] = B^(n) a[s][n]` for samples
/// `0..b`, `B` rows lane-blocked by `width` (4 or 8), full blocks
/// executed at `simd` (a **resolved** level — never `Auto`). Per-(sample,
/// row) accumulation is bitwise identical to [`matvec_rowmajor`] at every
/// level: sequential sums for rows below `r - r % 4`, [`dot`] association
/// for the tail.
#[allow(clippy::too_many_arguments)]
pub fn c_panel_packed(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
    width: usize,
    simd: SimdLevel,
) {
    debug_assert!(width == 4 || width == 8);
    debug_assert!(simd != SimdLevel::Auto, "resolve() the level before the hot loop");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if simd != SimdLevel::Scalar {
        c_panel_packed_vector(
            bm,
            r,
            j,
            order,
            n,
            b,
            a_panel,
            c_panel,
            width,
            simd == SimdLevel::V256,
        );
        return;
    }
    let _ = simd;
    let mut rr = 0;
    if width == 8 {
        while rr + 8 <= r {
            let rows: [&[f32]; 8] = [
                &bm[rr * j..(rr + 1) * j],
                &bm[(rr + 1) * j..(rr + 2) * j],
                &bm[(rr + 2) * j..(rr + 3) * j],
                &bm[(rr + 3) * j..(rr + 4) * j],
                &bm[(rr + 4) * j..(rr + 5) * j],
                &bm[(rr + 5) * j..(rr + 6) * j],
                &bm[(rr + 6) * j..(rr + 7) * j],
                &bm[(rr + 7) * j..(rr + 8) * j],
            ];
            for s in 0..b {
                let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
                let mut acc = [0.0f32; 8];
                for jj in 0..j {
                    let xj = a[jj];
                    acc[0] += rows[0][jj] * xj;
                    acc[1] += rows[1][jj] * xj;
                    acc[2] += rows[2][jj] * xj;
                    acc[3] += rows[3][jj] * xj;
                    acc[4] += rows[4][jj] * xj;
                    acc[5] += rows[5][jj] * xj;
                    acc[6] += rows[6][jj] * xj;
                    acc[7] += rows[7][jj] * xj;
                }
                c_panel[(s * order + n) * r + rr..(s * order + n) * r + rr + 8]
                    .copy_from_slice(&acc);
            }
            rr += 8;
        }
    }
    while rr + 4 <= r {
        let r0 = &bm[rr * j..(rr + 1) * j];
        let r1 = &bm[(rr + 1) * j..(rr + 2) * j];
        let r2 = &bm[(rr + 2) * j..(rr + 3) * j];
        let r3 = &bm[(rr + 3) * j..(rr + 4) * j];
        for s in 0..b {
            let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for jj in 0..j {
                let xj = a[jj];
                a0 += r0[jj] * xj;
                a1 += r1[jj] * xj;
                a2 += r2[jj] * xj;
                a3 += r3[jj] * xj;
            }
            let cbase = (s * order + n) * r + rr;
            c_panel[cbase] = a0;
            c_panel[cbase + 1] = a1;
            c_panel[cbase + 2] = a2;
            c_panel[cbase + 3] = a3;
        }
        rr += 4;
    }
    c_panel_row_tail(bm, r, j, order, n, b, a_panel, c_panel, rr);
}

/// Shared `R`-tail of the c-panel (rows `rr..r` through [`dot`]) — one
/// definition so the scalar and vector paths cannot drift.
#[allow(clippy::too_many_arguments)]
fn c_panel_row_tail(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
    mut rr: usize,
) {
    while rr < r {
        let brow = &bm[rr * j..(rr + 1) * j];
        for s in 0..b {
            let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            c_panel[(s * order + n) * r + rr] = dot(brow, a);
        }
        rr += 1;
    }
}

/// Vector c-panel: full lane blocks packed column-major once per block
/// (`packed[jj*w + i] = b_{rr+i}[jj]` — the pack walks `bm` only, so it
/// amortizes over the group's `b` samples), then per sample one
/// `acc += col * splat(a[jj])` step per `jj` — in every lane the exact
/// scalar sequence `acc[i] += rows[i][jj] * xj`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[allow(clippy::too_many_arguments)]
fn c_panel_packed_vector(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
    width: usize,
    wide: bool,
) {
    let mut pack_stack = [0.0f32; PACK_STACK];
    let mut pack_heap: Vec<f32> = Vec::new();
    let packed: &mut [f32] = if j * width <= PACK_STACK {
        &mut pack_stack[..j * width]
    } else {
        pack_heap.resize(j * width, 0.0);
        &mut pack_heap[..]
    };
    let mut rr = 0;
    if width == 8 {
        while rr + 8 <= r {
            for (i, row) in bm[rr * j..(rr + 8) * j].chunks_exact(j).enumerate() {
                for (jj, &v) in row.iter().enumerate() {
                    packed[jj * 8 + i] = v;
                }
            }
            for s in 0..b {
                let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
                let cbase = (s * order + n) * r + rr;
                arch::c_cols8(packed, j, a, &mut c_panel[cbase..cbase + 8], wide);
            }
            rr += 8;
        }
    }
    while rr + 4 <= r {
        for (i, row) in bm[rr * j..(rr + 4) * j].chunks_exact(j).enumerate() {
            for (jj, &v) in row.iter().enumerate() {
                packed[jj * 4 + i] = v;
            }
        }
        for s in 0..b {
            let a = &a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            let cbase = (s * order + n) * r + rr;
            arch::c_cols4(&packed[..j * 4], j, a, &mut c_panel[cbase..cbase + 4]);
        }
        rr += 4;
    }
    c_panel_row_tail(bm, r, j, order, n, b, a_panel, c_panel, rr);
}

/// Batched gs-panel (Packed layout): `GS[s][n] = Σ_r w[s][n][r] b_r`,
/// lane-blocked by `width`, full blocks executed at `simd` (resolved).
/// Bitwise identical to [`weighted_rowsum`] at every level: an 8-lane
/// block contributes its two quad partial sums to `out[j]` as two
/// separate adds (the two quad passes of the scalar primitive); tail
/// rows go through [`axpy`].
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_packed(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
    width: usize,
    simd: SimdLevel,
) {
    debug_assert!(width == 4 || width == 8);
    debug_assert!(simd != SimdLevel::Auto, "resolve() the level before the hot loop");
    for s in 0..b {
        gs_panel[(s * order + n) * j..(s * order + n + 1) * j].fill(0.0);
    }
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    let vector = simd != SimdLevel::Scalar;
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let vector = false;
    let wide = simd == SimdLevel::V256;
    let _ = (vector, wide);
    let mut rr = 0;
    if width == 8 {
        while rr + 8 <= r {
            let rows: [&[f32]; 8] = [
                &bm[rr * j..(rr + 1) * j],
                &bm[(rr + 1) * j..(rr + 2) * j],
                &bm[(rr + 2) * j..(rr + 3) * j],
                &bm[(rr + 3) * j..(rr + 4) * j],
                &bm[(rr + 4) * j..(rr + 5) * j],
                &bm[(rr + 5) * j..(rr + 6) * j],
                &bm[(rr + 6) * j..(rr + 7) * j],
                &bm[(rr + 7) * j..(rr + 8) * j],
            ];
            for s in 0..b {
                let wbase = (s * order + n) * r + rr;
                let w = &w_panel[wbase..wbase + 8];
                let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
                #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
                let jj0 = if vector { arch::gs_rows8(&rows, w, out, j, wide) } else { 0 };
                #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
                let jj0 = 0;
                for jj in jj0..j {
                    // Two quad partial sums added separately: the exact
                    // float sequence of two width-4 passes.
                    let q0 =
                        w[0] * rows[0][jj] + w[1] * rows[1][jj] + w[2] * rows[2][jj] + w[3] * rows[3][jj];
                    let q1 =
                        w[4] * rows[4][jj] + w[5] * rows[5][jj] + w[6] * rows[6][jj] + w[7] * rows[7][jj];
                    out[jj] = (out[jj] + q0) + q1;
                }
            }
            rr += 8;
        }
    }
    while rr + 4 <= r {
        let r0 = &bm[rr * j..(rr + 1) * j];
        let r1 = &bm[(rr + 1) * j..(rr + 2) * j];
        let r2 = &bm[(rr + 2) * j..(rr + 3) * j];
        let r3 = &bm[(rr + 3) * j..(rr + 4) * j];
        for s in 0..b {
            let wbase = (s * order + n) * r + rr;
            let (w0, w1, w2, w3) = (
                w_panel[wbase],
                w_panel[wbase + 1],
                w_panel[wbase + 2],
                w_panel[wbase + 3],
            );
            let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
            #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
            let jj0 = if vector {
                arch::gs_rows4([r0, r1, r2, r3], [w0, w1, w2, w3], out, j, wide)
            } else {
                0
            };
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            let jj0 = 0;
            for jj in jj0..j {
                out[jj] += w0 * r0[jj] + w1 * r1[jj] + w2 * r2[jj] + w3 * r3[jj];
            }
        }
        rr += 4;
    }
    while rr < r {
        let brow = &bm[rr * j..(rr + 1) * j];
        for s in 0..b {
            let w = w_panel[(s * order + n) * r + rr];
            let out = &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j];
            axpy(w, brow, out);
        }
        rr += 1;
    }
}

/// `x86_64` vector primitives (SSE2 baseline + runtime-detected AVX2).
/// Separate mul/add only — never FMA (see the module's bitwise
/// contract). Raw-pointer loads/stores are bounds-justified by each
/// helper's debug-asserted slice lengths.
#[cfg(target_arch = "x86_64")]
mod arch {
    use std::arch::x86_64::*;

    /// One 4-row c-panel accumulation: `out[i] = Σ_jj packed[jj*4+i] *
    /// a[jj]` with per-lane scalar association.
    #[inline]
    pub(super) fn c_cols4(packed: &[f32], j: usize, a: &[f32], out: &mut [f32]) {
        debug_assert!(packed.len() >= j * 4 && a.len() >= j && out.len() >= 4);
        // SAFETY: SSE2 is part of the x86_64 baseline ABI, and every
        // load/store stays in bounds: jj < j so jj*4 + 4 <= packed.len(),
        // and out holds >= 4 floats (both debug-asserted above).
        unsafe {
            let mut acc = _mm_setzero_ps();
            for jj in 0..j {
                let col = _mm_loadu_ps(packed.as_ptr().add(jj * 4));
                acc = _mm_add_ps(acc, _mm_mul_ps(col, _mm_set1_ps(a[jj])));
            }
            _mm_storeu_ps(out.as_mut_ptr(), acc);
        }
    }

    /// One 8-row c-panel accumulation; `wide` selects AVX2 (one ymm
    /// accumulator) vs paired SSE2 xmm accumulators — bit-identical, the
    /// lanes never interact.
    #[inline]
    pub(super) fn c_cols8(packed: &[f32], j: usize, a: &[f32], out: &mut [f32], wide: bool) {
        debug_assert!(packed.len() >= j * 8 && a.len() >= j && out.len() >= 8);
        if wide {
            // SAFETY: `wide` is only set after `is_x86_feature_detected!
            // ("avx2")` succeeded (SimdLevel::resolve clamps V256 away on
            // hosts without it), so the target-feature fn may run here.
            unsafe { c_cols8_avx2(packed, j, a, out) }
        } else {
            // SAFETY: SSE2 baseline; bounds as debug-asserted above
            // (jj*8 + 8 <= packed.len(), out >= 8 floats).
            unsafe {
                let mut acc0 = _mm_setzero_ps();
                let mut acc1 = _mm_setzero_ps();
                for jj in 0..j {
                    let base = packed.as_ptr().add(jj * 8);
                    let xj = _mm_set1_ps(a[jj]);
                    acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(base), xj));
                    acc1 = _mm_add_ps(acc1, _mm_mul_ps(_mm_loadu_ps(base.add(4)), xj));
                }
                _mm_storeu_ps(out.as_mut_ptr(), acc0);
                _mm_storeu_ps(out.as_mut_ptr().add(4), acc1);
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 via runtime feature detection; the
    /// slice bounds of [`c_cols8`] must hold.
    #[target_feature(enable = "avx2")]
    unsafe fn c_cols8_avx2(packed: &[f32], j: usize, a: &[f32], out: &mut [f32]) {
        // SAFETY: AVX2 guaranteed by the caller contract; unaligned
        // loads/stores stay in bounds per c_cols8's debug asserts.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            for jj in 0..j {
                let col = _mm256_loadu_ps(packed.as_ptr().add(jj * 8));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(col, _mm256_set1_ps(a[jj])));
            }
            _mm256_storeu_ps(out.as_mut_ptr(), acc);
        }
    }

    /// Vector body of a width-4 gs block: lanes `0..ret` of `out` get
    /// `out[jj] += ((w0·r0[jj] + w1·r1[jj]) + w2·r2[jj]) + w3·r3[jj]`
    /// (the scalar kernel's exact expression, per lane). Returns the
    /// first unprocessed `jj`; the caller runs the scalar tail from it.
    #[inline]
    pub(super) fn gs_rows4(
        rows: [&[f32]; 4],
        w: [f32; 4],
        out: &mut [f32],
        j: usize,
        wide: bool,
    ) -> usize {
        debug_assert!(rows.iter().all(|r| r.len() >= j) && out.len() >= j);
        let mut jj = 0;
        if wide {
            // SAFETY: `wide` ⇒ AVX2 runtime-detected (see c_cols8).
            unsafe {
                jj = gs_rows4_avx2(rows, w, out, j);
            }
        }
        // SAFETY: SSE2 baseline; every load/store covers jj..jj+4 with
        // jj + 4 <= j <= each slice's length (debug-asserted above).
        unsafe {
            let w0 = _mm_set1_ps(w[0]);
            let w1 = _mm_set1_ps(w[1]);
            let w2 = _mm_set1_ps(w[2]);
            let w3 = _mm_set1_ps(w[3]);
            while jj + 4 <= j {
                let mut q = _mm_mul_ps(w0, _mm_loadu_ps(rows[0].as_ptr().add(jj)));
                q = _mm_add_ps(q, _mm_mul_ps(w1, _mm_loadu_ps(rows[1].as_ptr().add(jj))));
                q = _mm_add_ps(q, _mm_mul_ps(w2, _mm_loadu_ps(rows[2].as_ptr().add(jj))));
                q = _mm_add_ps(q, _mm_mul_ps(w3, _mm_loadu_ps(rows[3].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                _mm_storeu_ps(o, _mm_add_ps(_mm_loadu_ps(o), q));
                jj += 4;
            }
        }
        jj
    }

    /// # Safety
    /// AVX2 must be runtime-detected; slice bounds of [`gs_rows4`].
    #[target_feature(enable = "avx2")]
    unsafe fn gs_rows4_avx2(rows: [&[f32]; 4], w: [f32; 4], out: &mut [f32], j: usize) -> usize {
        let mut jj = 0;
        // SAFETY: AVX2 per the caller contract; loads/stores cover
        // jj..jj+8 with jj + 8 <= j <= slice lengths.
        unsafe {
            let w0 = _mm256_set1_ps(w[0]);
            let w1 = _mm256_set1_ps(w[1]);
            let w2 = _mm256_set1_ps(w[2]);
            let w3 = _mm256_set1_ps(w[3]);
            while jj + 8 <= j {
                let mut q = _mm256_mul_ps(w0, _mm256_loadu_ps(rows[0].as_ptr().add(jj)));
                q = _mm256_add_ps(q, _mm256_mul_ps(w1, _mm256_loadu_ps(rows[1].as_ptr().add(jj))));
                q = _mm256_add_ps(q, _mm256_mul_ps(w2, _mm256_loadu_ps(rows[2].as_ptr().add(jj))));
                q = _mm256_add_ps(q, _mm256_mul_ps(w3, _mm256_loadu_ps(rows[3].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), q));
                jj += 8;
            }
        }
        jj
    }

    /// Vector body of a width-8 gs block: per lane the two quad partials
    /// `q0`/`q1` are built left-associated and added to `out[jj]`
    /// separately — `out[jj] = (out[jj] + q0) + q1`, the scalar kernel's
    /// exact sequence. Returns the first unprocessed `jj`.
    #[inline]
    pub(super) fn gs_rows8(
        rows: &[&[f32]; 8],
        w: &[f32],
        out: &mut [f32],
        j: usize,
        wide: bool,
    ) -> usize {
        debug_assert!(rows.iter().all(|r| r.len() >= j) && out.len() >= j && w.len() >= 8);
        let mut jj = 0;
        if wide {
            // SAFETY: `wide` ⇒ AVX2 runtime-detected (see c_cols8).
            unsafe {
                jj = gs_rows8_avx2(rows, w, out, j);
            }
        }
        // SAFETY: SSE2 baseline; loads/stores cover jj..jj+4 with
        // jj + 4 <= j <= slice lengths (debug-asserted above).
        unsafe {
            while jj + 4 <= j {
                let mut q0 = _mm_mul_ps(_mm_set1_ps(w[0]), _mm_loadu_ps(rows[0].as_ptr().add(jj)));
                q0 = _mm_add_ps(q0, _mm_mul_ps(_mm_set1_ps(w[1]), _mm_loadu_ps(rows[1].as_ptr().add(jj))));
                q0 = _mm_add_ps(q0, _mm_mul_ps(_mm_set1_ps(w[2]), _mm_loadu_ps(rows[2].as_ptr().add(jj))));
                q0 = _mm_add_ps(q0, _mm_mul_ps(_mm_set1_ps(w[3]), _mm_loadu_ps(rows[3].as_ptr().add(jj))));
                let mut q1 = _mm_mul_ps(_mm_set1_ps(w[4]), _mm_loadu_ps(rows[4].as_ptr().add(jj)));
                q1 = _mm_add_ps(q1, _mm_mul_ps(_mm_set1_ps(w[5]), _mm_loadu_ps(rows[5].as_ptr().add(jj))));
                q1 = _mm_add_ps(q1, _mm_mul_ps(_mm_set1_ps(w[6]), _mm_loadu_ps(rows[6].as_ptr().add(jj))));
                q1 = _mm_add_ps(q1, _mm_mul_ps(_mm_set1_ps(w[7]), _mm_loadu_ps(rows[7].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                _mm_storeu_ps(o, _mm_add_ps(_mm_add_ps(_mm_loadu_ps(o), q0), q1));
                jj += 4;
            }
        }
        jj
    }

    /// # Safety
    /// AVX2 must be runtime-detected; slice bounds of [`gs_rows8`].
    #[target_feature(enable = "avx2")]
    unsafe fn gs_rows8_avx2(rows: &[&[f32]; 8], w: &[f32], out: &mut [f32], j: usize) -> usize {
        let mut jj = 0;
        // SAFETY: AVX2 per the caller contract; loads/stores cover
        // jj..jj+8 with jj + 8 <= j <= slice lengths.
        unsafe {
            while jj + 8 <= j {
                let mut q0 =
                    _mm256_mul_ps(_mm256_set1_ps(w[0]), _mm256_loadu_ps(rows[0].as_ptr().add(jj)));
                q0 = _mm256_add_ps(q0, _mm256_mul_ps(_mm256_set1_ps(w[1]), _mm256_loadu_ps(rows[1].as_ptr().add(jj))));
                q0 = _mm256_add_ps(q0, _mm256_mul_ps(_mm256_set1_ps(w[2]), _mm256_loadu_ps(rows[2].as_ptr().add(jj))));
                q0 = _mm256_add_ps(q0, _mm256_mul_ps(_mm256_set1_ps(w[3]), _mm256_loadu_ps(rows[3].as_ptr().add(jj))));
                let mut q1 =
                    _mm256_mul_ps(_mm256_set1_ps(w[4]), _mm256_loadu_ps(rows[4].as_ptr().add(jj)));
                q1 = _mm256_add_ps(q1, _mm256_mul_ps(_mm256_set1_ps(w[5]), _mm256_loadu_ps(rows[5].as_ptr().add(jj))));
                q1 = _mm256_add_ps(q1, _mm256_mul_ps(_mm256_set1_ps(w[6]), _mm256_loadu_ps(rows[6].as_ptr().add(jj))));
                q1 = _mm256_add_ps(q1, _mm256_mul_ps(_mm256_set1_ps(w[7]), _mm256_loadu_ps(rows[7].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                _mm256_storeu_ps(o, _mm256_add_ps(_mm256_add_ps(_mm256_loadu_ps(o), q0), q1));
                jj += 8;
            }
        }
        jj
    }
}

/// `aarch64` vector primitives (NEON is part of the aarch64 baseline).
/// `wide` (V256) runs as paired q-registers — identical bits, the lanes
/// never interact. Separate mul/add only — never FMA.
#[cfg(target_arch = "aarch64")]
mod arch {
    use std::arch::aarch64::*;

    /// One 4-row c-panel accumulation (see the x86_64 twin).
    #[inline]
    pub(super) fn c_cols4(packed: &[f32], j: usize, a: &[f32], out: &mut [f32]) {
        debug_assert!(packed.len() >= j * 4 && a.len() >= j && out.len() >= 4);
        // SAFETY: NEON is baseline on aarch64; every load/store stays in
        // bounds (jj < j ⇒ jj*4 + 4 <= packed.len(); out >= 4 floats).
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            for jj in 0..j {
                let col = vld1q_f32(packed.as_ptr().add(jj * 4));
                acc = vaddq_f32(acc, vmulq_f32(col, vdupq_n_f32(a[jj])));
            }
            vst1q_f32(out.as_mut_ptr(), acc);
        }
    }

    /// One 8-row c-panel accumulation as paired q-registers (`wide` is
    /// accepted for signature parity; both levels run the same ops).
    #[inline]
    pub(super) fn c_cols8(packed: &[f32], j: usize, a: &[f32], out: &mut [f32], _wide: bool) {
        debug_assert!(packed.len() >= j * 8 && a.len() >= j && out.len() >= 8);
        // SAFETY: NEON baseline; bounds as debug-asserted above
        // (jj*8 + 8 <= packed.len(), out >= 8 floats).
        unsafe {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            for jj in 0..j {
                let base = packed.as_ptr().add(jj * 8);
                let xj = vdupq_n_f32(a[jj]);
                acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(base), xj));
                acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(base.add(4)), xj));
            }
            vst1q_f32(out.as_mut_ptr(), acc0);
            vst1q_f32(out.as_mut_ptr().add(4), acc1);
        }
    }

    /// Vector body of a width-4 gs block (see the x86_64 twin; `wide`
    /// changes nothing on NEON). Returns the first unprocessed `jj`.
    #[inline]
    pub(super) fn gs_rows4(
        rows: [&[f32]; 4],
        w: [f32; 4],
        out: &mut [f32],
        j: usize,
        _wide: bool,
    ) -> usize {
        debug_assert!(rows.iter().all(|r| r.len() >= j) && out.len() >= j);
        let mut jj = 0;
        // SAFETY: NEON baseline; loads/stores cover jj..jj+4 with
        // jj + 4 <= j <= slice lengths (debug-asserted above).
        unsafe {
            let w0 = vdupq_n_f32(w[0]);
            let w1 = vdupq_n_f32(w[1]);
            let w2 = vdupq_n_f32(w[2]);
            let w3 = vdupq_n_f32(w[3]);
            while jj + 4 <= j {
                let mut q = vmulq_f32(w0, vld1q_f32(rows[0].as_ptr().add(jj)));
                q = vaddq_f32(q, vmulq_f32(w1, vld1q_f32(rows[1].as_ptr().add(jj))));
                q = vaddq_f32(q, vmulq_f32(w2, vld1q_f32(rows[2].as_ptr().add(jj))));
                q = vaddq_f32(q, vmulq_f32(w3, vld1q_f32(rows[3].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                vst1q_f32(o, vaddq_f32(vld1q_f32(o), q));
                jj += 4;
            }
        }
        jj
    }

    /// Vector body of a width-8 gs block (see the x86_64 twin). Returns
    /// the first unprocessed `jj`.
    #[inline]
    pub(super) fn gs_rows8(
        rows: &[&[f32]; 8],
        w: &[f32],
        out: &mut [f32],
        j: usize,
        _wide: bool,
    ) -> usize {
        debug_assert!(rows.iter().all(|r| r.len() >= j) && out.len() >= j && w.len() >= 8);
        let mut jj = 0;
        // SAFETY: NEON baseline; loads/stores cover jj..jj+4 with
        // jj + 4 <= j <= slice lengths (debug-asserted above).
        unsafe {
            while jj + 4 <= j {
                let mut q0 = vmulq_f32(vdupq_n_f32(w[0]), vld1q_f32(rows[0].as_ptr().add(jj)));
                q0 = vaddq_f32(q0, vmulq_f32(vdupq_n_f32(w[1]), vld1q_f32(rows[1].as_ptr().add(jj))));
                q0 = vaddq_f32(q0, vmulq_f32(vdupq_n_f32(w[2]), vld1q_f32(rows[2].as_ptr().add(jj))));
                q0 = vaddq_f32(q0, vmulq_f32(vdupq_n_f32(w[3]), vld1q_f32(rows[3].as_ptr().add(jj))));
                let mut q1 = vmulq_f32(vdupq_n_f32(w[4]), vld1q_f32(rows[4].as_ptr().add(jj)));
                q1 = vaddq_f32(q1, vmulq_f32(vdupq_n_f32(w[5]), vld1q_f32(rows[5].as_ptr().add(jj))));
                q1 = vaddq_f32(q1, vmulq_f32(vdupq_n_f32(w[6]), vld1q_f32(rows[6].as_ptr().add(jj))));
                q1 = vaddq_f32(q1, vmulq_f32(vdupq_n_f32(w[7]), vld1q_f32(rows[7].as_ptr().add(jj))));
                let o = out.as_mut_ptr().add(jj);
                vst1q_f32(o, vaddq_f32(vaddq_f32(vld1q_f32(o), q0), q1));
                jj += 4;
            }
        }
        jj
    }
}

/// Batched c-panel under the Strided layout: per-sample calls of the
/// shared [`strided_matvec`](crate::kernel::contract::strided_matvec) —
/// bitwise identical to the scalar path by construction (lane width and
/// SIMD level do not apply to the strided walk).
#[allow(clippy::too_many_arguments)]
pub fn c_panel_strided(
    col: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
) {
    for s in 0..b {
        crate::kernel::contract::strided_matvec(
            col,
            r,
            &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
            &mut c_panel[(s * order + n) * r..(s * order + n) * r + r],
        );
    }
}

/// Batched gs-panel under the Strided layout: per-sample calls of the
/// shared
/// [`strided_weighted_sum`](crate::kernel::contract::strided_weighted_sum).
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_strided(
    col: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
) {
    for s in 0..b {
        crate::kernel::contract::strided_weighted_sum(
            col,
            r,
            j,
            &w_panel[(s * order + n) * r..(s * order + n) * r + r],
            &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j],
        );
    }
}

/// Reference c-panel: the scalar primitive applied sample by sample (what
/// the microkernels must reproduce bitwise). Test-support, also used by
/// the bench harness to sanity-check a build.
#[allow(clippy::too_many_arguments)]
pub fn c_panel_reference(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    a_panel: &[f32],
    c_panel: &mut [f32],
) {
    for s in 0..b {
        matvec_rowmajor(
            bm,
            r,
            j,
            &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
            &mut c_panel[(s * order + n) * r..(s * order + n) * r + r],
        );
    }
}

/// Reference gs-panel: [`weighted_rowsum`] sample by sample.
#[allow(clippy::too_many_arguments)]
pub fn gs_panel_reference(
    bm: &[f32],
    r: usize,
    j: usize,
    order: usize,
    n: usize,
    b: usize,
    w_panel: &[f32],
    gs_panel: &mut [f32],
) {
    for s in 0..b {
        weighted_rowsum(
            bm,
            r,
            j,
            &w_panel[(s * order + n) * r..(s * order + n) * r + r],
            &mut gs_panel[(s * order + n) * j..(s * order + n + 1) * j],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn lanes_resolve_and_parse() {
        assert_eq!(Lanes::Auto.resolve(16), 8);
        assert_eq!(Lanes::Auto.resolve(8), 8);
        assert_eq!(Lanes::Auto.resolve(7), 4);
        assert_eq!(Lanes::Auto.resolve(1), 4);
        assert_eq!(Lanes::W4.resolve(32), 4);
        assert_eq!(Lanes::W8.resolve(2), 8);
        assert_eq!(Lanes::parse("auto"), Some(Lanes::Auto));
        assert_eq!(Lanes::parse("4"), Some(Lanes::W4));
        assert_eq!(Lanes::parse("8"), Some(Lanes::W8));
        assert_eq!(Lanes::parse("16"), None);
        assert_eq!(Lanes::Auto.code(), 0);
        assert_eq!(Lanes::W8.code(), 8);
    }

    #[test]
    fn simd_level_resolve_and_parse() {
        assert_eq!(SimdLevel::parse("auto"), Some(SimdLevel::Auto));
        assert_eq!(SimdLevel::parse("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(SimdLevel::parse("v128"), Some(SimdLevel::V128));
        assert_eq!(SimdLevel::parse("v256"), Some(SimdLevel::V256));
        assert_eq!(SimdLevel::parse("avx2"), None);
        assert_eq!(SimdLevel::parse(""), None);
        assert_eq!(SimdLevel::Auto.code(), 0);
        assert_eq!(SimdLevel::Scalar.code(), 1);
        assert_eq!(SimdLevel::V128.code(), 4);
        assert_eq!(SimdLevel::V256.code(), 8);
        // Resolution yields a concrete level and is idempotent; an
        // explicit Scalar request is always honored (the CI forced-
        // scalar leg relies on it).
        let auto = SimdLevel::Auto.resolve();
        assert_ne!(auto, SimdLevel::Auto);
        assert_eq!(auto.resolve(), auto);
        assert_eq!(SimdLevel::Scalar.resolve(), SimdLevel::Scalar);
        for level in [SimdLevel::V128, SimdLevel::V256] {
            let r = level.resolve();
            assert_ne!(r, SimdLevel::Auto);
            assert_eq!(r.resolve(), r);
        }
    }

    /// Every SIMD level × lane width × every tail length (r mod 4 and
    /// r mod 8 both sweep 0..) × odd j: the microkernels are bitwise
    /// equal to the per-sample scalar primitives.
    #[test]
    fn microkernels_bitwise_match_reference_all_tails() {
        let mut rng = Rng::new(7);
        let (order, n, b) = (3usize, 1usize, 9usize);
        let levels = [
            SimdLevel::Scalar,
            SimdLevel::V128.resolve(),
            SimdLevel::V256.resolve(),
        ];
        for r in 1..=17 {
            for j in [1usize, 3, 4, 6, 8, 11] {
                let bm: Vec<f32> = (0..r * j).map(|_| rng.normal()).collect();
                let a_panel: Vec<f32> = (0..b * order * j).map(|_| rng.normal()).collect();
                let w_panel: Vec<f32> = (0..b * order * r).map(|_| rng.normal()).collect();

                let mut c_ref = vec![0.0f32; b * order * r];
                c_panel_reference(&bm, r, j, order, n, b, &a_panel, &mut c_ref);
                let mut gs_ref = vec![0.0f32; b * order * j];
                gs_panel_reference(&bm, r, j, order, n, b, &w_panel, &mut gs_ref);

                for width in [4usize, 8] {
                    for level in levels {
                        let mut c = vec![0.0f32; b * order * r];
                        c_panel_packed(&bm, r, j, order, n, b, &a_panel, &mut c, width, level);
                        for (x, y) in c.iter().zip(c_ref.iter()) {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "c-panel diverged: r={r} j={j} width={width} simd={level:?}"
                            );
                        }
                        let mut gs = vec![0.0f32; b * order * j];
                        gs_panel_packed(&bm, r, j, order, n, b, &w_panel, &mut gs, width, level);
                        for (x, y) in gs.iter().zip(gs_ref.iter()) {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "gs-panel diverged: r={r} j={j} width={width} simd={level:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Wide shapes force the heap pack-buffer path (`j * width >
    /// PACK_STACK`): still bitwise.
    #[test]
    fn microkernels_bitwise_with_heap_pack_buffer() {
        let mut rng = Rng::new(11);
        let (order, n, b, r, j) = (2usize, 0usize, 3usize, 9usize, 40usize);
        assert!(j * 8 > PACK_STACK);
        let bm: Vec<f32> = (0..r * j).map(|_| rng.normal()).collect();
        let a_panel: Vec<f32> = (0..b * order * j).map(|_| rng.normal()).collect();
        let mut c_ref = vec![0.0f32; b * order * r];
        c_panel_reference(&bm, r, j, order, n, b, &a_panel, &mut c_ref);
        for width in [4usize, 8] {
            for level in [SimdLevel::V128.resolve(), SimdLevel::V256.resolve()] {
                let mut c = vec![0.0f32; b * order * r];
                c_panel_packed(&bm, r, j, order, n, b, &a_panel, &mut c, width, level);
                for (x, y) in c.iter().zip(c_ref.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "width={width} simd={level:?}");
                }
            }
        }
    }

    #[test]
    fn strided_panels_match_strided_primitives() {
        // The strided panels are per-sample calls of the shared strided
        // primitives; pin the panel indexing (slot math), not the math.
        let mut rng = Rng::new(9);
        let (order, n, b, r, j) = (3usize, 2usize, 5usize, 6usize, 5usize);
        let core = crate::kruskal::KruskalCore::random(&mut rng, order, j, r, 0.5);
        let strided = crate::kernel::contract::build_strided(&core);
        let a_panel: Vec<f32> = (0..b * order * j).map(|_| rng.normal()).collect();
        let w_panel: Vec<f32> = (0..b * order * r).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f32; b * order * r];
        c_panel_strided(&strided[n], r, j, order, n, b, &a_panel, &mut c);
        let mut gs = vec![0.0f32; b * order * j];
        gs_panel_strided(&strided[n], r, j, order, n, b, &w_panel, &mut gs);
        for s in 0..b {
            let mut c1 = vec![0.0f32; r];
            crate::kernel::contract::strided_matvec(
                &strided[n],
                r,
                &a_panel[(s * order + n) * j..(s * order + n + 1) * j],
                &mut c1,
            );
            assert_eq!(&c[(s * order + n) * r..(s * order + n) * r + r], &c1[..]);
            let mut g1 = vec![0.0f32; j];
            crate::kernel::contract::strided_weighted_sum(
                &strided[n],
                r,
                j,
                &w_panel[(s * order + n) * r..(s * order + n) * r + r],
                &mut g1,
            );
            assert_eq!(&gs[(s * order + n) * j..(s * order + n + 1) * j], &g1[..]);
        }
    }
}
