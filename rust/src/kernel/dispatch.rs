//! In-group thread pool: fan one plan's split sub-groups across T
//! intra-worker threads — the second level of the paper's nested
//! parallelism (inter-GPU Latin rounds × intra-GPU thread blocks over
//! sampled nonzeros, cu_FastTucker §5; same structure in cuFasterTucker,
//! arXiv:2210.06014). The PR 3 split-group machinery made sub-groups the
//! independently dispatchable unit; this module actually dispatches them.
//!
//! [`DispatchPool`] owns T per-thread [`BatchWorkspace`]s plus the tape
//! buffers below, and executes a [`BatchPlan`] as **barrier-separated
//! waves** of a [`SubGroupColoring`]:
//!
//! * **Exact mode** uses the ordered coloring pass
//!   ([`BatchPlan::color_subgroups`]): same-wave sub-groups have pairwise
//!   disjoint factor-row footprints in every mode (safe to run
//!   concurrently, unsynchronized), and waves replay every conflicting
//!   pair in its sequential plan order — so the factor stream is
//!   **bitwise identical** to sequential sub-group execution
//!   ([`batched::run_plan`]).
//! * **Relaxed mode** passes [`SubGroupColoring::single_wave`]: every
//!   sub-group freely concurrent, the paper's hogwild GPU write
//!   semantics. Concurrent row writes may interleave; the result is
//!   pinned (like PR 2's relaxed plans) as a permutation of the sample
//!   multiset that stays within the 2%-RMSE envelope of exact, not as a
//!   bitwise contract.
//!
//! **The plan-order tape.** Residual/SSE/core-gradient accumulation is
//! order-sensitive float arithmetic, so partial-sum merging would break
//! the bitwise contract even under a correct coloring. Instead each
//! thread records its sub-groups' per-sample residuals (and, when the
//! core is being updated, the staged `a`/`w` panels the Eq. 17
//! accumulation reads) into **disjoint plan-order slices** of shared tape
//! buffers; a serial epilogue then replays SSE and the core-gradient
//! accumulation in exact plan order — character-for-character the same
//! loop [`batched::run_plan`] runs inline. Pooled exact execution is
//! therefore bitwise identical to sequential execution at every thread
//! count, including T = 1 (pinned by
//! `tests/properties.rs::prop_threaded_exact_bitwise_matches_sequential`).
//!
//! The pool is persistent (workspaces, tapes, and the coloring scratch
//! are reused across passes); the T worker threads themselves are scoped
//! per executed chunk and synchronize between waves with a panic-aware
//! `WaveBarrier` (waves with no groups in the chunk's range are skipped
//! identically by every thread, so the barrier stays aligned). Work
//! inside a wave is claimed dynamically through an atomic cursor — legal
//! precisely because same-wave sub-groups commute (disjoint rows,
//! disjoint tape slices). Tapes are bounded by [`TAPE_BUDGET_BYTES`]: an
//! oversized plan executes as consecutive group chunks, replayed in plan
//! order, which keeps the bitwise contract while capping memory.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::kernel::batched::{self, BatchWorkspace};
use crate::kernel::contract::CoreLayout;
use crate::kernel::plan::{BatchPlan, ColorScratch, Exactness, PlanScratch, SubGroupColoring};
use crate::kernel::{FactorAccess, KernelStats};
use crate::kruskal::KruskalCore;
use crate::tensor::SparseTensor;

/// How many intra-worker threads an engine's dispatch pool runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ThreadCount {
    /// Measured policy (see
    /// [`resolve_threads`](crate::kernel::planner::resolve_threads)):
    /// the `FASTTUCKER_POOL_THREADS` environment variable when set
    /// (CI's 2-thread differential pass); otherwise **exact** mode opens
    /// a cores-aware pool (`min(available cores, AUTO_MAX_THREADS)`) —
    /// bitwise-neutral by the wave contract, soaked through the CI
    /// differential legs since PR 4 — while **relaxed** (hogwild) mode
    /// stays at 1 so its nondeterminism remains an explicit opt-in.
    #[default]
    Auto,
    /// Exactly `n` threads (≥ 1; 1 = the sequential executor).
    Fixed(usize),
}

impl ThreadCount {
    /// Parse a config/CLI spelling (`"auto"` or a positive integer).
    pub fn parse(s: &str) -> Option<ThreadCount> {
        if s == "auto" {
            return Some(ThreadCount::Auto);
        }
        s.parse::<usize>().ok().filter(|&n| n >= 1).map(ThreadCount::Fixed)
    }
}

/// Budget for one pooled pass's plan-order tapes (64 MiB): a plan whose
/// tape footprint exceeds it executes as consecutive **group chunks**
/// (see [`DispatchPool::execute`]), bounding tape memory at O(budget)
/// instead of O(plan samples) — the serial engine's full-epoch plans
/// would otherwise scale the tapes with total nnz.
pub const TAPE_BUDGET_BYTES: usize = 64 << 20;

/// A panic-aware wave barrier: like `std::sync::Barrier`, but poisonable.
/// When a pool thread panics mid-wave its [`PoisonGuard`] poisons the
/// barrier; every other thread unblocks (notification or the timeout
/// re-check), bails out of the dispatch loop, the thread scope joins, and
/// the original panic propagates — instead of the survivors deadlocking
/// forever on a barrier that can no longer fill.
struct WaveBarrier {
    /// `(waiting, generation)`.
    state: Mutex<(usize, u64)>,
    cv: Condvar,
    threads: usize,
    poisoned: AtomicBool,
}

impl WaveBarrier {
    fn new(threads: usize) -> Self {
        WaveBarrier {
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
            threads,
            poisoned: AtomicBool::new(false),
        }
    }

    /// Wait for every thread to arrive. Returns `false` when the barrier
    /// was poisoned — the caller must abandon the dispatch loop.
    fn wait(&self) -> bool {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        g.0 += 1;
        if g.0 == self.threads {
            g.0 = 0;
            g.1 = g.1.wrapping_add(1);
            self.cv.notify_all();
            return !self.poisoned.load(Ordering::Acquire);
        }
        let gen = g.1;
        while g.1 == gen && !self.poisoned.load(Ordering::Acquire) {
            let (guard, _) = self
                .cv
                .wait_timeout(g, Duration::from_millis(10))
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
        }
        !self.poisoned.load(Ordering::Acquire)
    }

    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// Poisons the wave barrier when dropped during a panic unwind (held by
/// each pool thread for its whole lifetime).
struct PoisonGuard<'a>(&'a WaveBarrier);

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Raw views over the plan-order tape buffers, shared across the scoped
/// worker threads.
///
/// SAFETY: groups partition the plan's sample stream into disjoint
/// index ranges, and each group is claimed by exactly one thread (the
/// atomic wave cursor hands out each index once) — so all writes through
/// these pointers land in pairwise-disjoint slices, and the buffers are
/// only read after the thread scope joins.
struct TapePtrs {
    e: *mut f32,
    w: *mut f32,
    a: *mut f32,
}

unsafe impl Sync for TapePtrs {}

impl TapePtrs {
    /// Copy one finished group's per-sample values into its plan-order
    /// slots. `off` is the group's plan offset, `b` its length.
    ///
    /// # Safety
    /// Caller guarantees exclusive ownership of the range (see the
    /// struct-level SAFETY contract) and that the tapes were sized for
    /// the plan (`with_core` ⇒ `w`/`a` tapes sized too).
    unsafe fn record(
        &self,
        off: usize,
        b: usize,
        ws: &BatchWorkspace,
        with_core: bool,
        order: usize,
        r: usize,
        j: usize,
    ) {
        // SAFETY: source panels hold >= b (resp. b·order·r, b·order·j)
        // initialized elements for the group just executed; the
        // destination ranges are exclusively owned per the fn contract
        // and in-bounds because the tapes were sized for the plan.
        unsafe {
            std::ptr::copy_nonoverlapping(ws.e.as_ptr(), self.e.add(off), b);
            if with_core {
                std::ptr::copy_nonoverlapping(
                    ws.w_panel.as_ptr(),
                    self.w.add(off * order * r),
                    b * order * r,
                );
                std::ptr::copy_nonoverlapping(
                    ws.a_panel.as_ptr(),
                    self.a.add(off * order * j),
                    b * order * j,
                );
            }
        }
    }
}

/// A persistent in-group thread pool: T per-thread workspaces + the
/// plan-order tapes + the coloring scratch, reused across passes. See the
/// module docs for the execution model.
pub struct DispatchPool {
    workspaces: Vec<BatchWorkspace>,
    /// Plan-order residual tape (sized to the current chunk, at most
    /// [`TAPE_BUDGET_BYTES`] worth).
    tape_e: Vec<f32>,
    /// Plan-order `w`/`a` panel tapes (sized only for exact passes that
    /// update the core; the Eq. 17 replay reads them).
    tape_w: Vec<f32>,
    tape_a: Vec<f32>,
    color_scratch: ColorScratch,
    /// Memoized coloring verdicts keyed by
    /// `(plan fingerprint, tensor revision)` — see
    /// [`Self::cached_coloring`]. `Some(c)` = the coloring paid off and
    /// is reusable as-is; `None` = the pays-off gate rejected it
    /// (sequential dispatch). Threads are implicit: a pool is built for
    /// one thread count and rebuilt when it changes.
    color_cache: std::collections::HashMap<(u64, u64), Option<SubGroupColoring>>,
}

/// Soft cap on memoized coloring verdicts per pool: a worker cycles
/// through a handful of per-round plans, so anything past this is churn —
/// the cache is cleared rather than LRU-tracked.
const COLOR_CACHE_CAP: usize = 32;

impl DispatchPool {
    /// Pool with `threads` workspaces shaped `(order, r_core, j, cap)`.
    /// `threads` is clamped to ≥ 1; `threads == 1` makes [`Self::execute`]
    /// a plain sequential [`batched::run_plan`] call on the primary
    /// workspace.
    pub fn new(threads: usize, order: usize, r_core: usize, j: usize, cap: usize) -> Self {
        let threads = threads.max(1);
        DispatchPool {
            workspaces: (0..threads)
                .map(|_| BatchWorkspace::new(order, r_core, j, cap))
                .collect(),
            tape_e: Vec::new(),
            tape_w: Vec::new(),
            tape_a: Vec::new(),
            color_scratch: ColorScratch::new(),
            color_cache: std::collections::HashMap::new(),
        }
    }

    pub fn threads(&self) -> usize {
        self.workspaces.len()
    }

    /// Shape of the per-thread workspaces.
    pub fn shape(&self) -> (usize, usize, usize, usize) {
        self.workspaces[0].shape()
    }

    /// The primary workspace (sequential fallback target; holds the
    /// pool's merged core-gradient accumulator).
    pub fn primary_mut(&mut self) -> &mut BatchWorkspace {
        &mut self.workspaces[0]
    }

    /// Planning scratch paired with this pool (lives on the primary
    /// workspace, same as the unpooled engines).
    pub fn plan_scratch_mut(&mut self) -> &mut PlanScratch {
        self.workspaces[0].plan_scratch_mut()
    }

    /// Coloring scratch paired with this pool.
    pub fn color_scratch_mut(&mut self) -> &mut ColorScratch {
        &mut self.color_scratch
    }

    /// Memoized coloring verdict for `(plan fingerprint, tensor
    /// revision)`, if one was recorded: `Some(Some(c))` = reuse coloring
    /// `c`, `Some(None)` = the pays-off gate already rejected this plan
    /// (dispatch sequentially), `None` = not seen yet — color it and
    /// record the verdict with [`Self::record_coloring`]. Sound because
    /// the fingerprint pins the exact group structure and the revision
    /// pins the coordinates the conflict graph is built from
    /// ([`BatchPlan::fingerprint`]).
    pub fn cached_coloring(&self, key: (u64, u64)) -> Option<Option<&SubGroupColoring>> {
        self.color_cache.get(&key).map(|v| v.as_ref())
    }

    /// Record a coloring verdict (see [`Self::cached_coloring`]). The
    /// cache is bounded: past [`COLOR_CACHE_CAP`] distinct keys it is
    /// cleared outright — correct (it is a pure memo) and cheap, since
    /// steady-state workers see a handful of plans, not thousands.
    pub fn record_coloring(&mut self, key: (u64, u64), verdict: Option<SubGroupColoring>) {
        if self.color_cache.len() >= COLOR_CACHE_CAP {
            self.color_cache.clear();
        }
        self.color_cache.insert(key, verdict);
    }

    /// Core-gradient accumulator and count of the pool. Invariant: after
    /// [`Self::execute`] (or a sequential pass on [`Self::primary_mut`])
    /// the pool's whole accumulated gradient lives on the primary
    /// workspace — the tape replay targets it directly and the thread
    /// workspaces never accumulate.
    pub fn core_grad_mut(&mut self) -> (&mut Vec<f32>, &mut usize) {
        self.workspaces[0].core_grad_mut()
    }

    /// Execute `plan` over the waves of `coloring`, fanning each wave's
    /// sub-groups across this pool's threads. `make_access` is invoked
    /// once per worker thread to mint that thread's [`FactorAccess`]
    /// handle; the caller is responsible for the handles being safe to
    /// use concurrently under the coloring's disjointness guarantee
    /// (exact waves) or the hogwild opt-in (relaxed single wave) — see
    /// [`SharedFactors`](crate::parallel::shared::SharedFactors) for the
    /// three-level contract.
    ///
    /// Exact-mode result contract: bitwise identical to
    /// [`batched::run_plan`] over the same plan — factors, residual log,
    /// SSE, and core gradients (accumulated onto the primary workspace).
    #[allow(clippy::too_many_arguments)]
    pub fn execute<A, M>(
        &mut self,
        tensor: &SparseTensor,
        plan: &BatchPlan,
        coloring: &SubGroupColoring,
        core: &KruskalCore,
        strided: &[Vec<f32>],
        layout: CoreLayout,
        make_access: M,
        lr_f: f32,
        lam_f: f32,
        update_core: bool,
        residual_log: Option<&mut Vec<f32>>,
    ) -> KernelStats
    where
        A: FactorAccess,
        M: Fn() -> A + Sync,
    {
        assert_eq!(
            coloring.n_groups(),
            plan.n_groups(),
            "coloring was built for a different plan"
        );
        let cap = self.shape().3;
        assert!(plan.max_batch() <= cap, "plan exceeds pool workspace capacity");
        let n_threads = self.workspaces.len();
        if n_threads == 1 || plan.n_groups() <= 1 {
            // Sequential fast path — same semantics, no tape overhead.
            let mut access = make_access();
            return batched::run_plan(
                &mut self.workspaces[0],
                tensor,
                plan,
                core,
                strided,
                layout,
                &mut access,
                lr_f,
                lam_f,
                update_core,
                residual_log,
            );
        }

        self.execute_with_tape_budget(
            tensor,
            plan,
            coloring,
            core,
            strided,
            layout,
            make_access,
            lr_f,
            lam_f,
            update_core,
            residual_log,
            TAPE_BUDGET_BYTES,
        )
    }

    /// [`Self::execute`] with an explicit tape budget (exposed for the
    /// chunking tests; `execute` passes [`TAPE_BUDGET_BYTES`]).
    ///
    /// The plan's groups are processed as consecutive **chunks** whose
    /// tape footprint fits the budget, each chunk fanned across the pool
    /// as its waves (the global coloring restricted to the chunk's group
    /// range, which stays sound: within a chunk conflicting sub-groups
    /// keep their wave separation, and across chunks the full join
    /// between chunks preserves plan order outright). This bounds the
    /// exact-mode tape memory at O(budget) instead of O(plan samples)
    /// without giving up bitwise identity — chunks replay in plan order.
    #[allow(clippy::too_many_arguments)]
    fn execute_with_tape_budget<A, M>(
        &mut self,
        tensor: &SparseTensor,
        plan: &BatchPlan,
        coloring: &SubGroupColoring,
        core: &KruskalCore,
        strided: &[Vec<f32>],
        layout: CoreLayout,
        make_access: M,
        lr_f: f32,
        lam_f: f32,
        update_core: bool,
        mut residual_log: Option<&mut Vec<f32>>,
        tape_budget: usize,
    ) -> KernelStats
    where
        A: FactorAccess,
        M: Fn() -> A + Sync,
    {
        let (order, r, j, _) = self.shape();
        let n_threads = self.workspaces.len();
        let ng = plan.n_groups();
        // Exact mode owes the caller bitwise identity with sequential
        // execution, so core-gradient accumulation must replay in plan
        // order from the w/a tapes. Relaxed mode has no bitwise contract
        // — its threads accumulate into their own workspaces (skipping
        // the w/a tapes and the serial replay entirely) and the partials
        // merge in thread order below.
        let bitwise = plan.params().exactness == Exactness::Exact;
        let tape_core = update_core && bitwise;
        let accumulate_inline = update_core && !bitwise;
        let bytes_per_sample =
            4 + if tape_core { order * (r + j) * 4 } else { 0 };
        // At least one full group per chunk, whatever the budget says.
        let budget_samples =
            (tape_budget / bytes_per_sample).max(plan.max_batch()).max(1);

        let lanes = plan.params().lanes.resolve(r);
        let simd = plan.params().simd.resolve();
        let beta = 1.0 - lr_f * lam_f;
        let mut sse = 0.0f64;
        let mut samples = 0usize;
        let mut g_lo = 0usize;
        while g_lo < ng {
            // Grow the chunk [g_lo, g_hi) of consecutive groups up to the
            // tape budget.
            let chunk_base = plan.group_offset(g_lo);
            let mut g_hi = g_lo;
            let mut chunk_samples = 0usize;
            while g_hi < ng {
                let b = plan.group(g_hi).len();
                if chunk_samples > 0 && chunk_samples + b > budget_samples {
                    break;
                }
                chunk_samples += b;
                g_hi += 1;
            }
            samples += chunk_samples;
            // resize (not clear+resize): only a newly-grown tail is
            // zeroed; stale prefixes are fine because the chunk's groups
            // partition its sample range, so every slot is overwritten
            // before it is read.
            self.tape_e.resize(chunk_samples, 0.0);
            if tape_core {
                self.tape_w.resize(chunk_samples * order * r, 0.0);
                self.tape_a.resize(chunk_samples * order * j, 0.0);
            }
            let tape = TapePtrs {
                e: self.tape_e.as_mut_ptr(),
                w: self.tape_w.as_mut_ptr(),
                a: self.tape_a.as_mut_ptr(),
            };
            // One claim cursor per wave; the barrier separates waves,
            // which both orders conflicting sub-groups (exact bitwise
            // contract) and publishes each wave's factor writes to the
            // next. Each wave is restricted to the chunk's ascending
            // group range by binary search.
            let cursors: Vec<AtomicUsize> =
                (0..coloring.n_waves()).map(|_| AtomicUsize::new(0)).collect();
            let barrier = WaveBarrier::new(n_threads);
            // Shadow-ledger provenance: pool threads inherit the worker
            // coordinates of the thread that owns this pool.
            #[cfg(feature = "shadow-ledger")]
            let parent_ctx = crate::analysis::shadow::current_ctx();
            std::thread::scope(|scope| {
                for (_t, ws) in self.workspaces.iter_mut().enumerate() {
                    let tape = &tape;
                    let cursors = &cursors;
                    let barrier = &barrier;
                    let make_access = &make_access;
                    scope.spawn(move || {
                        // Poison the barrier if this thread unwinds, so
                        // the others bail instead of deadlocking (the
                        // panic then propagates through the scope join).
                        let _poison = PoisonGuard(barrier);
                        #[cfg(feature = "shadow-ledger")]
                        crate::analysis::shadow::adopt(parent_ctx, _t);
                        let mut access = make_access();
                        for (w, cursor) in cursors.iter().enumerate() {
                            #[cfg(feature = "shadow-ledger")]
                            crate::analysis::shadow::set_wave(w);
                            let full = coloring.wave(w);
                            let lo = full.partition_point(|&g| (g as usize) < g_lo);
                            let hi = full.partition_point(|&g| (g as usize) < g_hi);
                            // Every thread computes the same restriction,
                            // so skipping an empty wave keeps the barrier
                            // aligned — no T-thread no-op syncs for waves
                            // outside this chunk's group range.
                            if lo == hi {
                                continue;
                            }
                            let wave = &full[lo..hi];
                            loop {
                                let i = cursor.fetch_add(1, Ordering::Relaxed);
                                let Some(&g) = wave.get(i) else { break };
                                let g = g as usize;
                                let ids = plan.group(g);
                                batched::run_group(
                                    ws, tensor, ids, core, strided, layout, lanes, simd,
                                    lr_f, beta, &mut access, accumulate_inline,
                                );
                                // SAFETY: this thread exclusively claimed
                                // group `g`; groups occupy disjoint
                                // chunk-relative ranges (TapePtrs
                                // contract).
                                unsafe {
                                    tape.record(
                                        plan.group_offset(g) - chunk_base,
                                        ids.len(),
                                        ws,
                                        tape_core,
                                        order,
                                        r,
                                        j,
                                    );
                                }
                            }
                            if !barrier.wait() {
                                return;
                            }
                        }
                    });
                }
            });

            // Serial epilogue in exact plan order (chunks run in plan
            // order, samples within a chunk replay in plan order): SSE,
            // residual log, and the Eq. 17 core-gradient replay — the
            // identical accumulation loops `run_plan` executes inline, so
            // exact pooled results are bitwise equal to sequential
            // execution.
            for &e in &self.tape_e[..chunk_samples] {
                sse += (e as f64) * (e as f64);
            }
            if let Some(log) = residual_log.as_mut() {
                log.extend_from_slice(&self.tape_e[..chunk_samples]);
            }
            if tape_core {
                let ws0 = &mut self.workspaces[0];
                for s in 0..chunk_samples {
                    batched::accumulate_sample_core_grad(
                        &mut ws0.core_grad,
                        self.tape_e[s],
                        order,
                        r,
                        j,
                        &self.tape_w[s * order * r..(s + 1) * order * r],
                        &self.tape_a[s * order * j..(s + 1) * order * j],
                    );
                    ws0.core_grad_count += 1;
                }
            }
            g_lo = g_hi;
        }
        if accumulate_inline {
            // Relaxed: merge the threads' core-grad partials onto the
            // primary workspace in thread-index order (deterministic
            // merge; the per-sample values are hogwild).
            let (first, rest) = self.workspaces.split_at_mut(1);
            let (grad0, count0) = first[0].core_grad_mut();
            for ws in rest.iter_mut() {
                let (grad, count) = ws.core_grad_mut();
                batched::merge_core_grad(grad0, count0, grad, count);
            }
        }
        KernelStats { samples, sse }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::plan::PlanParams;
    use crate::kernel::Workspace;
    use crate::model::{CoreRepr, TuckerModel};
    use crate::parallel::shared::{RelaxedRowAccess, SharedFactors, SharedRowAccess};
    use crate::util::Rng;

    #[test]
    fn thread_count_parses() {
        assert_eq!(ThreadCount::parse("auto"), Some(ThreadCount::Auto));
        assert_eq!(ThreadCount::parse("1"), Some(ThreadCount::Fixed(1)));
        assert_eq!(ThreadCount::parse("8"), Some(ThreadCount::Fixed(8)));
        assert_eq!(ThreadCount::parse("0"), None);
        assert_eq!(ThreadCount::parse("-2"), None);
        assert_eq!(ThreadCount::parse("many"), None);
    }

    /// The module-level pin of the tentpole: pooled exact execution over
    /// a colored split plan is bitwise identical to sequential
    /// `run_plan` — factors, SSE, residual stream, and core gradients —
    /// at T = 1, 2, and 3.
    #[test]
    fn pooled_exact_matches_sequential_bitwise() {
        let mut rng = Rng::new(11);
        let dims = vec![512usize, 60, 55];
        let tensor = synth::random_uniform(&mut rng, &dims, 2000, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 6, 5);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let params = PlanParams::tiled(64, 8).with_split(4);
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        assert!(plan.n_groups() > 8);
        let coloring = plan.color_subgroups(&tensor);
        let (lr, lam) = (0.01f32, 0.003f32);

        let mut f_seq = model.factors.clone();
        let mut seq_ws = BatchWorkspace::new(3, 5, 6, 64);
        let mut log_seq = Vec::new();
        let st_seq = batched::run_plan(
            &mut seq_ws, &tensor, &plan, &core, &[], CoreLayout::Packed, &mut f_seq, lr,
            lam, true, Some(&mut log_seq),
        );

        for threads in [1usize, 2, 3] {
            let mut f_pool = model.factors.clone();
            let mut pool = DispatchPool::new(threads, 3, 5, 6, 64);
            let mut log_pool = Vec::new();
            let st_pool = {
                let shared = SharedFactors::new(&mut f_pool);
                // SAFETY: exact coloring waves have disjoint row
                // footprints; only this test touches the factors.
                pool.execute(
                    &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                    || unsafe { SharedRowAccess::new(&shared) },
                    lr, lam, true, Some(&mut log_pool),
                )
            };
            assert_eq!(st_seq.samples, st_pool.samples);
            assert_eq!(
                st_seq.sse.to_bits(),
                st_pool.sse.to_bits(),
                "T={threads}: sse diverged"
            );
            assert_eq!(log_seq.len(), log_pool.len());
            for (a, b) in log_seq.iter().zip(log_pool.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "T={threads}: residuals diverged");
            }
            for n in 0..3 {
                for (a, b) in f_seq
                    .mat(n)
                    .data()
                    .iter()
                    .zip(f_pool.mat(n).data().iter())
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "T={threads}: mode {n} diverged");
                }
            }
            let (gs, cs) = seq_ws.core_grad_mut();
            let (gp, cp) = pool.core_grad_mut();
            assert_eq!(*cs, *cp);
            for (a, b) in gs.iter().zip(gp.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "T={threads}: core grads diverged");
            }
        }
    }

    /// Tape chunking: a budget far below the plan's footprint forces
    /// many consecutive group chunks, and the result must STILL be
    /// bitwise identical to sequential execution (chunks replay in plan
    /// order; the restricted waves keep conflicting pairs separated).
    #[test]
    fn chunked_tapes_stay_bitwise_identical() {
        let mut rng = Rng::new(14);
        let dims = vec![400usize, 50, 45];
        let tensor = synth::random_uniform(&mut rng, &dims, 1500, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 5, 4);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let plan =
            BatchPlan::build_params(&tensor, &ids, PlanParams::tiled(32, 4).with_split(2));
        let coloring = plan.color_subgroups(&tensor);
        let (lr, lam) = (0.01f32, 0.003f32);

        let mut f_seq = model.factors.clone();
        let mut seq_ws = BatchWorkspace::new(3, 4, 5, 32);
        let mut log_seq = Vec::new();
        let st_seq = batched::run_plan(
            &mut seq_ws, &tensor, &plan, &core, &[], CoreLayout::Packed, &mut f_seq, lr,
            lam, true, Some(&mut log_seq),
        );

        let mut f_pool = model.factors.clone();
        let mut pool = DispatchPool::new(3, 3, 4, 5, 32);
        let mut log_pool = Vec::new();
        // 1-byte budget: every chunk degenerates to a single group — the
        // maximal chunking stress.
        let st_pool = {
            let shared = SharedFactors::new(&mut f_pool);
            // SAFETY: exact coloring waves have disjoint row footprints.
            pool.execute_with_tape_budget(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { SharedRowAccess::new(&shared) },
                lr, lam, true, Some(&mut log_pool), 1,
            )
        };
        assert_eq!(st_seq.samples, st_pool.samples);
        assert_eq!(st_seq.sse.to_bits(), st_pool.sse.to_bits(), "sse diverged");
        assert_eq!(log_seq.len(), log_pool.len());
        for (a, b) in log_seq.iter().zip(log_pool.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "residuals diverged under chunking");
        }
        for n in 0..3 {
            for (a, b) in f_seq
                .mat(n)
                .data()
                .iter()
                .zip(f_pool.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under chunking");
            }
        }
        let (gs, cs) = seq_ws.core_grad_mut();
        let (gp, cp) = pool.core_grad_mut();
        assert_eq!(*cs, *cp);
        for (a, b) in gs.iter().zip(gp.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core grads diverged under chunking");
        }
    }

    /// Relaxed single-wave dispatch: every sample executed exactly once
    /// (plan-order residual tape filled), and the trained factors stay
    /// finite — the hogwild contract; quality is pinned end-to-end in
    /// `tests/integration.rs`.
    #[test]
    fn pooled_relaxed_executes_every_sample_once() {
        let mut rng = Rng::new(12);
        let dims = vec![256usize, 40, 40];
        let tensor = synth::random_uniform(&mut rng, &dims, 1500, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 4);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let params = PlanParams::relaxed(64, 16).with_split(8);
        let plan = BatchPlan::build_params(&tensor, &ids, params);
        let coloring = SubGroupColoring::single_wave(plan.n_groups());
        let mut factors = model.factors.clone();
        let mut pool = DispatchPool::new(3, 3, 4, 4, 64);
        let mut log = Vec::new();
        let st = {
            let shared = SharedFactors::new(&mut factors);
            // SAFETY: hogwild opt-in — concurrent row access goes through
            // the relaxed-atomic path (the paper's GPU write semantics
            // without UB races).
            pool.execute(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { RelaxedRowAccess::new(&shared) },
                0.005, 0.001, true, Some(&mut log),
            )
        };
        assert_eq!(st.samples, ids.len());
        assert_eq!(log.len(), ids.len());
        assert!(log.iter().all(|e| e.is_finite()));
        for n in 0..3 {
            assert!(factors.mat(n).data().iter().all(|v| v.is_finite()));
        }
        let (_, count) = pool.core_grad_mut();
        assert_eq!(*count, ids.len());
    }

    /// The scalar reference over plan order equals the pooled exact path
    /// end to end (transitively through run_plan, asserted directly here
    /// so the dispatcher has its own scalar anchor).
    #[test]
    fn pooled_exact_matches_scalar_over_plan_order() {
        let mut rng = Rng::new(13);
        let dims = vec![300usize, 50, 45];
        let tensor = synth::random_uniform(&mut rng, &dims, 1200, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 5, 7);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let plan =
            BatchPlan::build_params(&tensor, &ids, PlanParams::tiled(32, 4).with_split(2));
        let coloring = plan.color_subgroups(&tensor);

        let mut f_scalar = model.factors.clone();
        let mut ws = Workspace::new(3, 7, 5);
        let st_s = crate::kernel::scalar::run_ids(
            &mut ws, &tensor, plan.ids(), &core, &[], CoreLayout::Packed, &mut f_scalar,
            0.01, 0.001, false, None,
        );

        let mut f_pool = model.factors.clone();
        let mut pool = DispatchPool::new(2, 3, 7, 5, 32);
        let st_p = {
            let shared = SharedFactors::new(&mut f_pool);
            // SAFETY: exact coloring waves have disjoint row footprints.
            pool.execute(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { SharedRowAccess::new(&shared) },
                0.01, 0.001, false, None,
            )
        };
        assert_eq!(st_s.samples, st_p.samples);
        assert_eq!(st_s.sse.to_bits(), st_p.sse.to_bits());
        for n in 0..3 {
            for (a, b) in f_scalar
                .mat(n)
                .data()
                .iter()
                .zip(f_pool.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged");
            }
        }
    }

    /// Miri anchor (tiny on purpose — the interpreter is ~1000x slower
    /// than native): pooled exact dispatch over a colored split plan on a
    /// minimal geometry, bitwise against sequential `run_plan`. CI's Miri
    /// leg runs `cargo miri test --lib -- unsafe_access_`, i.e. exactly
    /// the `unsafe_access_*` tests here and in `parallel::shared`.
    #[test]
    fn unsafe_access_pooled_exact_smoke() {
        let mut rng = Rng::new(21);
        let dims = vec![24usize, 6, 5];
        let tensor = synth::random_uniform(&mut rng, &dims, 40, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 3, 3);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let plan =
            BatchPlan::build_params(&tensor, &ids, PlanParams::tiled(8, 2).with_split(2));
        let coloring = plan.color_subgroups(&tensor);

        let mut f_seq = model.factors.clone();
        let mut seq_ws = BatchWorkspace::new(3, 3, 3, 8);
        let st_seq = batched::run_plan(
            &mut seq_ws, &tensor, &plan, &core, &[], CoreLayout::Packed, &mut f_seq, 0.01,
            0.001, true, None,
        );

        let mut f_pool = model.factors.clone();
        let mut pool = DispatchPool::new(2, 3, 3, 3, 8);
        let st_pool = {
            let shared = SharedFactors::new(&mut f_pool);
            // SAFETY: exact coloring waves have disjoint row footprints.
            pool.execute(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { SharedRowAccess::new(&shared) },
                0.01, 0.001, true, None,
            )
        };
        assert_eq!(st_seq.samples, st_pool.samples);
        assert_eq!(st_seq.sse.to_bits(), st_pool.sse.to_bits());
        for n in 0..3 {
            for (a, b) in f_seq.mat(n).data().iter().zip(f_pool.mat(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged");
            }
        }
    }

    /// Miri anchor, relaxed leg: hogwild single-wave dispatch on the same
    /// tiny geometry — every sample executed once, results finite.
    #[test]
    fn unsafe_access_pooled_relaxed_smoke() {
        let mut rng = Rng::new(22);
        let dims = vec![24usize, 6, 5];
        let tensor = synth::random_uniform(&mut rng, &dims, 40, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 3, 3);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let plan = BatchPlan::build_params(
            &tensor, &ids, PlanParams::relaxed(8, 2).with_split(2),
        );
        let coloring = SubGroupColoring::single_wave(plan.n_groups());
        let mut factors = model.factors.clone();
        let mut pool = DispatchPool::new(2, 3, 3, 3, 8);
        let st = {
            let shared = SharedFactors::new(&mut factors);
            // SAFETY: hogwild opt-in — concurrent row access goes through
            // the relaxed-atomic path.
            pool.execute(
                &tensor, &plan, &coloring, &core, &[], CoreLayout::Packed,
                || unsafe { RelaxedRowAccess::new(&shared) },
                0.005, 0.001, true, None,
            )
        };
        assert_eq!(st.samples, ids.len());
        for n in 0..3 {
            assert!(factors.mat(n).data().iter().all(|v| v.is_finite()));
        }
    }
}
