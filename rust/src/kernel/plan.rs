//! Batch planning: group a stream of sampled nonzero ids by their mode-1
//! fiber (paper's 1-based mode 1 = our mode 0), CSF-style, so the batched
//! kernel can stage each shared factor row once per group.
//!
//! A group satisfies three invariants that together make the batched
//! execution **bitwise identical** to scalar execution over the plan's
//! sample order:
//!
//! 1. every sample in the group shares the same mode-0 coordinate (the
//!    fiber whose factor row is staged once and kept hot);
//! 2. within the group, the coordinates of every other mode are pairwise
//!    distinct — so deferred panel reads/writes of those rows cannot
//!    observe or clobber an intra-group update;
//! 3. the group is at most `max_batch` long (panel capacity).
//!
//! Relative sample order is preserved inside each fiber (the grouping sort
//! is a stable counting sort, the same pass
//! [`ModeSlices`](crate::tensor::ModeSlices) does over a whole tensor).

use crate::tensor::SparseTensor;

/// An execution plan: grouped nonzero ids plus group boundaries.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    ids: Vec<u32>,
    /// `offsets[g]..offsets[g+1]` delimit group `g` in `ids`.
    offsets: Vec<usize>,
    max_batch: usize,
}

/// Reusable scratch for [`BatchPlan::build_with_scratch`]: the per-mode
/// stamp arrays are O(Σ dims) and the sort keys O(ids), so hot callers
/// (one plan per Latin-schedule worker pass) keep one of these per worker
/// instead of reallocating per call. Stamps stay valid across builds via
/// a monotone group serial.
#[derive(Default)]
pub struct PlanScratch {
    /// `(coord0, original position)` sort keys.
    keys: Vec<(u32, u32)>,
    /// Last-group serial per coordinate, per mode ≥ 1.
    stamps: Vec<Vec<u32>>,
    /// Dims fingerprint the stamps were sized for.
    dims: Vec<usize>,
    /// Monotone group serial (stale stamps compare unequal).
    serial: u32,
}

impl PlanScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, dims: &[usize], upcoming_groups: usize) {
        let refresh = self.dims != dims
            || self.serial > u32::MAX - (upcoming_groups as u32).saturating_add(2);
        if refresh {
            self.stamps = dims[1..].iter().map(|&d| vec![u32::MAX; d]).collect();
            self.dims = dims.to_vec();
            self.serial = 0;
        }
    }
}

impl BatchPlan {
    /// Build a plan over `ids` (nonzero ids into `tensor`). Groups are
    /// capped at `max_batch` (≥ 1). Allocates fresh scratch — use
    /// [`Self::build_with_scratch`] on hot paths.
    pub fn build(tensor: &SparseTensor, ids: &[u32], max_batch: usize) -> BatchPlan {
        let mut scratch = PlanScratch::new();
        Self::build_with_scratch(tensor, ids, max_batch, &mut scratch)
    }

    /// [`Self::build`] with caller-owned [`PlanScratch`].
    pub fn build_with_scratch(
        tensor: &SparseTensor,
        ids: &[u32],
        max_batch: usize,
        scratch: &mut PlanScratch,
    ) -> BatchPlan {
        assert!(max_batch >= 1);
        let order = tensor.order();
        scratch.ensure(tensor.dims(), ids.len());

        // Stable sort by mode-0 coordinate: the composite key
        // `(coord0, stream position)` makes the in-place unstable sort
        // order-preserving within each fiber.
        scratch.keys.clear();
        scratch
            .keys
            .extend(ids.iter().enumerate().map(|(pos, &k)| {
                (tensor.index(k as usize)[0], pos as u32)
            }));
        scratch.keys.sort_unstable();
        let sorted: Vec<u32> = scratch.keys.iter().map(|&(_, pos)| ids[pos as usize]).collect();

        // Split fibers into groups: cap length and keep modes >= 1
        // coordinates distinct within a group. `stamps[n-1][coord]` holds
        // the serial of the last group that saw that coordinate.
        let mut offsets = vec![0usize];
        let mut serial: u32 = scratch.serial + 1;
        let mut group_len = 0usize;
        let mut group_coord0 = 0u32;
        for (pos, &k) in sorted.iter().enumerate() {
            let coords = tensor.index(k as usize);
            let must_split = group_len == 0
                || coords[0] != group_coord0
                || group_len == max_batch
                || (1..order).any(|n| scratch.stamps[n - 1][coords[n] as usize] == serial);
            if must_split && group_len > 0 {
                offsets.push(pos);
                serial += 1;
                group_len = 0;
            }
            group_coord0 = coords[0];
            for n in 1..order {
                scratch.stamps[n - 1][coords[n] as usize] = serial;
            }
            group_len += 1;
        }
        if group_len > 0 {
            offsets.push(sorted.len());
        }
        scratch.serial = serial;
        BatchPlan { ids: sorted, offsets, max_batch }
    }

    /// All ids in execution order (the scalar reference must iterate this
    /// order for bitwise comparison).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn n_groups(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Ids of group `g`.
    #[inline]
    pub fn group(&self, g: usize) -> &[u32] {
        &self.ids[self.offsets[g]..self.offsets[g + 1]]
    }

    /// The group-size cap the plan was built with.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Mean group size (batching effectiveness diagnostic).
    pub fn mean_group_len(&self) -> f64 {
        if self.n_groups() == 0 {
            return 0.0;
        }
        self.ids.len() as f64 / self.n_groups() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;

    #[test]
    fn prop_plan_invariants() {
        forall("batch plan: permutation + fiber + distinctness", 24, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(30)).collect();
            let nnz = 1 + rng.gen_range(400);
            let t = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
            let n_ids = 1 + rng.gen_range(nnz);
            let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
            let max_batch = 1 + rng.gen_range(16);
            let plan = BatchPlan::build(&t, &ids, max_batch);

            // Permutation of the input multiset.
            let mut a = ids.clone();
            let mut b = plan.ids().to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);

            // Group invariants.
            let mut total = 0usize;
            for g in 0..plan.n_groups() {
                let grp = plan.group(g);
                assert!(!grp.is_empty() && grp.len() <= max_batch);
                total += grp.len();
                let i0 = t.index(grp[0] as usize)[0];
                for n in 1..order {
                    let mut seen = std::collections::HashSet::new();
                    for &k in grp {
                        let coords = t.index(k as usize);
                        assert_eq!(coords[0], i0, "group shares mode-0 fiber");
                        assert!(
                            seen.insert(coords[n]),
                            "mode {n} coordinate repeated within a group"
                        );
                    }
                }
            }
            assert_eq!(total, plan.len());
        });
    }

    #[test]
    fn fiber_order_is_stable() {
        // Within one fiber, ids keep their stream order.
        let t = synth::random_uniform(&mut crate::util::Rng::new(1), &[4, 50, 50], 200, 1.0, 2.0);
        let ids: Vec<u32> = (0..200).collect();
        let plan = BatchPlan::build(&t, &ids, 64);
        let mut last_pos: Vec<Option<u32>> = vec![None; 4];
        for &k in plan.ids() {
            let f = t.index(k as usize)[0] as usize;
            if let Some(prev) = last_pos[f] {
                assert!(k > prev, "fiber {f}: {k} after {prev}");
            }
            last_pos[f] = Some(k);
        }
    }

    #[test]
    fn empty_ids_give_empty_plan() {
        let t = synth::random_uniform(&mut crate::util::Rng::new(2), &[3, 3], 10, 1.0, 2.0);
        let plan = BatchPlan::build(&t, &[], 8);
        assert_eq!(plan.n_groups(), 0);
        assert!(plan.is_empty());
    }
}
