//! Batch planning: group a stream of sampled nonzero ids by their mode-1
//! fiber (paper's 1-based mode 1 = our mode 0), CSF-style, so the batched
//! kernel can stage each shared factor row once per fiber and run the
//! contraction over flat `batch × R_core` panels.
//!
//! A group is a **tile of fibers** (cuFasterTucker packs several fibers
//! per thread block, arXiv:2210.06014): up to [`PlanParams::tile`]
//! distinct mode-0 fibers, each a contiguous sub-run inside the group
//! (the grouping sort keeps equal mode-0 coordinates adjacent), totalling
//! at most [`PlanParams::max_batch`] samples. Under
//! [`Exactness::Exact`] (the default) a group additionally satisfies the
//! distinctness invariant that makes batched execution **bitwise
//! identical** to scalar execution over the plan's sample order:
//!
//! 1. within the group, the coordinates of every mode ≥ 1 are pairwise
//!    distinct **across the whole tile** — so deferred panel reads/writes
//!    of those rows cannot observe or clobber an intra-group update;
//! 2. each fiber's shared mode-0 row is staged once at its sub-run and
//!    updated sequentially there; the sort guarantees a mode-0 coordinate
//!    appears in at most one sub-run per group, so per-fiber staging
//!    observes exactly the rows scalar execution would.
//!
//! [`Exactness::Relaxed`] drops invariant 1 (the paper's hogwild-style
//! GPU write semantics): groups are then just capped tiles of the sorted
//! stream, much longer on hollow tensors. Panel reads become mini-batch
//! (pre-group) reads for duplicated mode-≥1 rows and their deferred SGD
//! write-backs compose at group end, so results are no longer bitwise
//! scalar-equal — but the plan is still a permutation of the input
//! multiset, the mode-0 chain stays exact, and accuracy stays within
//! noise of the exact path (pinned by `tests/properties.rs`).
//!
//! Relative sample order is preserved inside each fiber (the grouping sort
//! is stable via composite `(coord0, position)` keys, the same pass
//! [`ModeSlices`](crate::tensor::ModeSlices) does over a whole tensor).
//!
//! **Split-group refinement** ([`PlanParams::split`] > 1): groups are
//! additionally cut once they reach `ceil(max_batch / split)` samples —
//! exact plans only at fiber **sub-run boundaries** (the mode-0 chain
//! stays whole per fiber, so execution over the refined plan is bitwise
//! identical to the unsplit plan over the same sample order; pinned by
//! `tests/properties.rs::prop_split_group_execution_bitwise_matches_unsplit`),
//! relaxed plans anywhere. Sub-groups are the independently dispatchable
//! units split-group execution hands to workers.
//!
//! **Sub-group coloring** ([`BatchPlan::color_subgroups`]): the
//! row-ownership partition beyond the Latin schedule that exact-mode
//! in-group threading needs. Two sub-groups *conflict* when their factor-
//! row footprints intersect in **any** mode (mode ≥ 1 rows can repeat
//! across groups of one exact plan, and a mode-0 fiber can span groups
//! when a cap or distinctness cut lands mid-fiber — so mode 0 is part of
//! the conflict graph too). The greedy ordered coloring assigns
//! `color(g) = 1 + max{color(g') : g' < g, g' conflicts with g}` (0 when
//! unconflicted), which yields two properties the threaded executor
//! ([`crate::kernel::dispatch`]) relies on:
//!
//! 1. **wave disjointness** — same color ⇒ no shared rows, so a wave's
//!    sub-groups can run on concurrent threads without synchronization;
//! 2. **order preservation** — along any one row's chain of touching
//!    sub-groups, colors strictly increase, so executing waves in color
//!    order replays every conflicting pair in its sequential plan order
//!    and exact execution stays **bitwise identical** to sequential
//!    sub-group order (pinned by `tests/properties.rs`).
//!
//! The pass is one O(footprint) sweep using per-mode last-color arrays
//! (reusable via [`ColorScratch`]), because along a row's chain the last
//! toucher always carries that chain's maximum color.

use crate::kernel::panel::{Lanes, SimdLevel};
use crate::metrics::PlanStats;
use crate::tensor::SparseTensor;
use crate::util::hash::{FNV_OFFSET, FNV_PRIME};

/// Collision semantics of a plan (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Exactness {
    /// Intra-group mode-≥1 rows pairwise distinct: batched execution is
    /// bitwise identical to scalar over plan order. The property-test
    /// oracle and the default.
    #[default]
    Exact,
    /// Ignore intra-group collisions (hogwild, the paper's GPU
    /// semantics): longer groups, stale panel reads under collision.
    Relaxed,
}

/// Shape of the groups a plan may form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanParams {
    /// Maximum samples per group (panel capacity, ≥ 1).
    pub max_batch: usize,
    /// Maximum distinct mode-0 fibers per group (≥ 1; 1 = the legacy
    /// one-fiber-per-group plans).
    pub tile: usize,
    pub exactness: Exactness,
    /// Lane width of the panel microkernels executing this plan (see
    /// [`crate::kernel::panel`]); carried on the plan so the executor and
    /// the planner agree per workload. Does not affect group formation.
    pub lanes: Lanes,
    /// Vector instruction level of the panel microkernels executing
    /// this plan (see [`crate::kernel::panel::SimdLevel`]); carried on
    /// the plan like `lanes` so the executor and the planner agree per
    /// workload. Does not affect group formation, and — because every
    /// level combines per-lane partial sums in the scalar association —
    /// does not affect exact-mode results either.
    pub simd: SimdLevel,
    /// Accumulate the per-sample contraction in f64 even though
    /// storage stays f32 (relaxed mode only — see
    /// [`crate::kernel::batched::run_plan`]). Does not affect group
    /// formation.
    pub wide_accum: bool,
    /// Split-group factor (≥ 1): groups are additionally cut once they
    /// reach `ceil(max_batch / split)` samples — in [`Exactness::Exact`]
    /// mode only at fiber **sub-run boundaries** (so the per-fiber mode-0
    /// chain stays whole and execution remains bitwise identical to the
    /// unsplit plan over the same sample order), in
    /// [`Exactness::Relaxed`] mode anywhere. The resulting sub-groups are
    /// the independently dispatchable work units split-group execution
    /// hands to workers ([`crate::parallel::worker`]).
    pub split: usize,
    /// Planner marker: the requested relaxed/split semantics could not
    /// engage on this workload (degenerate planner fallback — see
    /// [`crate::kernel::planner::choose_params`]). Does not affect group
    /// formation; carried into [`PlanStats`] so the silent-no-op case is
    /// observable.
    pub degraded: bool,
}

impl Default for PlanParams {
    fn default() -> Self {
        PlanParams {
            max_batch: 1,
            tile: 1,
            exactness: Exactness::Exact,
            lanes: Lanes::Auto,
            simd: SimdLevel::Auto,
            wide_accum: false,
            split: 1,
            degraded: false,
        }
    }
}

impl PlanParams {
    /// Legacy single-fiber exact plan with group cap `max_batch`.
    pub fn exact(max_batch: usize) -> PlanParams {
        PlanParams { max_batch, ..Default::default() }
    }

    /// Exact tiled plan: up to `tile` fibers per group.
    pub fn tiled(max_batch: usize, tile: usize) -> PlanParams {
        PlanParams { max_batch, tile, ..Default::default() }
    }

    /// Relaxed (hogwild) tiled plan.
    pub fn relaxed(max_batch: usize, tile: usize) -> PlanParams {
        PlanParams { max_batch, tile, exactness: Exactness::Relaxed, ..Default::default() }
    }

    /// Builder-style split-group factor.
    pub fn with_split(mut self, split: usize) -> PlanParams {
        self.split = split.max(1);
        self
    }

    /// Builder-style lane width.
    pub fn with_lanes(mut self, lanes: Lanes) -> PlanParams {
        self.lanes = lanes;
        self
    }

    /// Builder-style SIMD level.
    pub fn with_simd(mut self, simd: SimdLevel) -> PlanParams {
        self.simd = simd;
        self
    }

    /// Builder-style wide (f64) accumulation toggle.
    pub fn with_wide_accum(mut self, wide_accum: bool) -> PlanParams {
        self.wide_accum = wide_accum;
        self
    }

    /// Per-sub-group sample budget the split factor implies.
    pub fn split_budget(&self) -> usize {
        self.max_batch.div_ceil(self.split.max(1))
    }
}

/// An execution plan: grouped nonzero ids plus group boundaries.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    ids: Vec<u32>,
    /// `offsets[g]..offsets[g+1]` delimit group `g` in `ids`.
    offsets: Vec<usize>,
    params: PlanParams,
    /// Fiber sub-runs summed over groups (a fiber split across groups
    /// counts once per group it appears in) — the tile-occupancy
    /// numerator.
    fiber_slots: usize,
    /// Group boundaries introduced by the split-group rule (beyond the
    /// cap/tile/distinctness splits an unsplit plan would make).
    splits: usize,
    /// FNV-1a over the grouping-relevant params and the sorted id
    /// stream: two plans with equal fingerprints over the same tensor
    /// revision form identical groups, so per-plan derived artifacts
    /// (the sub-group coloring and its pays-off verdict —
    /// [`crate::kernel::dispatch`]) can be cached against it. `lanes`/
    /// `simd`/`wide_accum` are deliberately excluded: they never affect
    /// group formation.
    fingerprint: u64,
}

/// Fold `bytes` into an incremental FNV-1a state.
#[inline]
fn fnv_mix(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// Reusable scratch for [`BatchPlan::build_params_with_scratch`]: the
/// per-mode stamp arrays are O(Σ dims), the sort keys O(ids), and the
/// recycled id/offset buffers O(ids), so hot callers (one plan per
/// Latin-schedule worker pass) keep one of these per worker and planning
/// allocates nothing after warmup. Stamps stay valid across builds via a
/// monotone group serial; finished plans donate their buffers back
/// through [`PlanScratch::recycle`].
#[derive(Default)]
pub struct PlanScratch {
    /// `(coord0, original position)` sort keys.
    keys: Vec<(u32, u32)>,
    /// Last-group serial per coordinate, per mode ≥ 1 (exact plans only).
    stamps: Vec<Vec<u32>>,
    /// Dims fingerprint the stamps were sized for.
    dims: Vec<usize>,
    /// Monotone group serial (stale stamps compare unequal).
    serial: u32,
    /// Recycled plan buffers (donated by [`Self::recycle`]).
    ids_spare: Vec<u32>,
    offsets_spare: Vec<usize>,
}

impl PlanScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Donate a finished plan's buffers back for the next build — the
    /// counterpart of [`BatchPlan::build_params_with_scratch`] that makes
    /// per-pass planning allocation-free.
    pub fn recycle(&mut self, plan: BatchPlan) {
        // Keep the larger of old/new so capacity ratchets up once.
        if plan.ids.capacity() > self.ids_spare.capacity() {
            self.ids_spare = plan.ids;
        }
        if plan.offsets.capacity() > self.offsets_spare.capacity() {
            self.offsets_spare = plan.offsets;
        }
    }

    fn ensure(&mut self, dims: &[usize], upcoming_groups: usize, need_stamps: bool) {
        let stamps_missing = need_stamps && self.stamps.len() != dims.len().saturating_sub(1);
        let refresh = self.dims != dims
            || stamps_missing
            || self.serial > u32::MAX - (upcoming_groups as u32).saturating_add(2);
        if refresh {
            self.stamps = if need_stamps {
                dims[1..].iter().map(|&d| vec![u32::MAX; d]).collect()
            } else {
                Vec::new()
            };
            self.dims = dims.to_vec();
            self.serial = 0;
        }
    }
}

impl BatchPlan {
    /// Build a legacy single-fiber exact plan over `ids` (nonzero ids
    /// into `tensor`), groups capped at `max_batch` (≥ 1). Allocates
    /// fresh scratch — use the `_with_scratch` variants on hot paths.
    pub fn build(tensor: &SparseTensor, ids: &[u32], max_batch: usize) -> BatchPlan {
        Self::build_params(tensor, ids, PlanParams::exact(max_batch))
    }

    /// [`Self::build`] with explicit [`PlanParams`] (tile width and
    /// exactness).
    pub fn build_params(tensor: &SparseTensor, ids: &[u32], params: PlanParams) -> BatchPlan {
        let mut scratch = PlanScratch::new();
        Self::build_params_with_scratch(tensor, ids, params, &mut scratch)
    }

    /// [`Self::build`] with caller-owned [`PlanScratch`].
    pub fn build_with_scratch(
        tensor: &SparseTensor,
        ids: &[u32],
        max_batch: usize,
        scratch: &mut PlanScratch,
    ) -> BatchPlan {
        Self::build_params_with_scratch(tensor, ids, PlanParams::exact(max_batch), scratch)
    }

    /// The full builder: tile of fibers per group, exact or relaxed.
    /// Allocation-free when `scratch` has recycled buffers (see
    /// [`PlanScratch::recycle`]).
    pub fn build_params_with_scratch(
        tensor: &SparseTensor,
        ids: &[u32],
        params: PlanParams,
        scratch: &mut PlanScratch,
    ) -> BatchPlan {
        assert!(params.max_batch >= 1);
        assert!(params.tile >= 1);
        assert!(params.split >= 1);
        let order = tensor.order();
        let exact = params.exactness == Exactness::Exact;
        // Split-group budget: once a group holds this many samples it is
        // cut at the next legal boundary (sub-run start in exact mode,
        // anywhere in relaxed mode). `split == 1` disables the rule.
        let split_budget = params.split_budget();
        let split_active = split_budget < params.max_batch;
        scratch.ensure(tensor.dims(), ids.len(), exact);

        // Stable sort by mode-0 coordinate: the composite key
        // `(coord0, stream position)` makes the in-place unstable sort
        // order-preserving within each fiber.
        scratch.keys.clear();
        scratch
            .keys
            .extend(ids.iter().enumerate().map(|(pos, &k)| {
                (tensor.index(k as usize)[0], pos as u32)
            }));
        scratch.keys.sort_unstable();
        let mut sorted = std::mem::take(&mut scratch.ids_spare);
        sorted.clear();
        sorted.extend(scratch.keys.iter().map(|&(_, pos)| ids[pos as usize]));

        // Split the sorted stream into groups: cap total length, cap the
        // number of fiber sub-runs at the tile width, and (exact mode)
        // keep mode-≥1 coordinates distinct across the whole tile.
        // `stamps[n-1][coord]` holds the serial of the last group that
        // saw that coordinate.
        let mut offsets = std::mem::take(&mut scratch.offsets_spare);
        offsets.clear();
        offsets.push(0usize);
        let mut serial: u32 = scratch.serial + 1;
        let mut group_len = 0usize;
        let mut group_fibers = 0usize;
        let mut fiber_slots = 0usize;
        let mut splits = 0usize;
        let mut prev_coord0 = 0u32;
        for (pos, &k) in sorted.iter().enumerate() {
            let coords = tensor.index(k as usize);
            let mut new_fiber = group_len == 0 || coords[0] != prev_coord0;
            let base_split = group_len > 0
                && (group_len == params.max_batch
                    || (new_fiber && group_fibers == params.tile)
                    || (exact
                        && (1..order)
                            .any(|n| scratch.stamps[n - 1][coords[n] as usize] == serial)));
            // Split-group rule: exact plans only cut where a new fiber
            // sub-run starts (the mode-0 chain stays whole per fiber, so
            // execution over the refined groups is bitwise identical to
            // the unsplit plan); relaxed plans cut anywhere.
            let split_rule = split_active
                && group_len >= split_budget
                && (!exact || new_fiber);
            let must_split = base_split || split_rule;
            if must_split {
                if split_rule && !base_split {
                    splits += 1;
                }
                offsets.push(pos);
                serial += 1;
                group_len = 0;
                group_fibers = 0;
                new_fiber = true;
            }
            if exact {
                for n in 1..order {
                    scratch.stamps[n - 1][coords[n] as usize] = serial;
                }
            }
            if new_fiber {
                group_fibers += 1;
                fiber_slots += 1;
            }
            prev_coord0 = coords[0];
            group_len += 1;
        }
        if group_len > 0 {
            offsets.push(sorted.len());
        }
        scratch.serial = serial;
        // Fingerprint: the grouping inputs (cap/tile/exactness/split)
        // plus the sorted id stream. One O(nnz) byte sweep — small next
        // to the sort above.
        let mut fingerprint = FNV_OFFSET;
        fnv_mix(&mut fingerprint, &(params.max_batch as u64).to_le_bytes());
        fnv_mix(&mut fingerprint, &(params.tile as u64).to_le_bytes());
        fnv_mix(&mut fingerprint, &[exact as u8]);
        fnv_mix(&mut fingerprint, &(params.split as u64).to_le_bytes());
        for &k in &sorted {
            fnv_mix(&mut fingerprint, &k.to_le_bytes());
        }
        BatchPlan { ids: sorted, offsets, params, fiber_slots, splits, fingerprint }
    }

    /// All ids in execution order (the scalar reference must iterate this
    /// order for bitwise comparison).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn n_groups(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Ids of group `g`.
    #[inline]
    pub fn group(&self, g: usize) -> &[u32] {
        &self.ids[self.offsets[g]..self.offsets[g + 1]]
    }

    /// Offset of group `g`'s first sample in plan order (`ids()`): the
    /// slice `ids()[group_offset(g)..group_offset(g) + group(g).len()]`
    /// is exactly `group(g)`. Threaded execution uses this to land each
    /// sub-group's per-sample tape entries in their plan-order slots.
    #[inline]
    pub fn group_offset(&self, g: usize) -> usize {
        self.offsets[g]
    }

    /// The group-size cap the plan was built with.
    pub fn max_batch(&self) -> usize {
        self.params.max_batch
    }

    /// The fiber-tile width the plan was built with.
    pub fn tile(&self) -> usize {
        self.params.tile
    }

    pub fn exactness(&self) -> Exactness {
        self.params.exactness
    }

    pub fn params(&self) -> PlanParams {
        self.params
    }

    /// Fiber sub-runs summed over groups (see field docs).
    pub fn fiber_slots(&self) -> usize {
        self.fiber_slots
    }

    /// Grouping fingerprint (see the field docs): equal fingerprints on
    /// the same tensor revision ⇒ identical groups ⇒ identical coloring.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Group boundaries the split-group rule introduced (0 when
    /// `params.split == 1` or every cut coincided with a cap/tile/
    /// distinctness split).
    pub fn splits(&self) -> usize {
        self.splits
    }

    /// Mean group size (batching effectiveness diagnostic).
    pub fn mean_group_len(&self) -> f64 {
        if self.n_groups() == 0 {
            return 0.0;
        }
        self.ids.len() as f64 / self.n_groups() as f64
    }

    /// Observability snapshot for `metrics`/bench reporting. `threads`
    /// defaults to 1 and `waves` to 0 — the execution layer overwrites
    /// them when a pooled dispatch actually runs this plan.
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            samples: self.len(),
            n_groups: self.n_groups(),
            fiber_slots: self.fiber_slots,
            cap: self.params.max_batch,
            tile: self.params.tile,
            lanes: self.params.lanes.code(),
            split: self.params.split,
            splits: self.splits,
            threads: 1,
            waves: 0,
            device: 0,
            degraded: self.params.degraded,
        }
    }

    /// The sub-group coloring pass (see module docs): greedy ordered
    /// coloring of the conflict graph over this plan's groups, where two
    /// groups conflict iff their factor-row footprints intersect in any
    /// mode. Allocates fresh scratch — hot callers should hold a
    /// [`ColorScratch`] and use [`Self::color_subgroups_with_scratch`].
    pub fn color_subgroups(&self, tensor: &SparseTensor) -> SubGroupColoring {
        self.color_subgroups_with_scratch(tensor, &mut ColorScratch::new())
    }

    /// [`Self::color_subgroups`] with caller-owned scratch: the O(Σ dims)
    /// last-color arrays are reused (the dominant cost on big tensors);
    /// the returned coloring itself still allocates a few O(n_groups)
    /// buffers per call.
    pub fn color_subgroups_with_scratch(
        &self,
        tensor: &SparseTensor,
        scratch: &mut ColorScratch,
    ) -> SubGroupColoring {
        let ng = self.n_groups();
        assert!(
            ng < u32::MAX as usize,
            "plan has too many groups to color"
        );
        scratch.ensure(tensor.dims());
        let mut colors = vec![0u32; ng];
        let mut n_waves = 0usize;
        for g in 0..ng {
            // color(g) = 1 + max color over every row the group touches.
            // Along one row's chain of touching groups colors strictly
            // increase, so the last toucher carries the chain maximum and
            // a single last-color array per mode suffices.
            let mut color = 0u32;
            for &k in self.group(g) {
                let coords = tensor.index(k as usize);
                for (n, &c) in coords.iter().enumerate() {
                    let last = scratch.last[n][c as usize];
                    if last != ColorScratch::UNTOUCHED {
                        color = color.max(last + 1);
                    }
                }
            }
            for &k in self.group(g) {
                let coords = tensor.index(k as usize);
                for (n, &c) in coords.iter().enumerate() {
                    scratch.last[n][c as usize] = color;
                }
            }
            colors[g] = color;
            n_waves = n_waves.max(color as usize + 1);
        }
        SubGroupColoring::from_colors(&colors, n_waves)
    }
}

/// Reusable scratch for [`BatchPlan::color_subgroups_with_scratch`]: one
/// last-color array per mode, O(Σ dims), refilled (not reallocated) per
/// coloring pass.
#[derive(Default)]
pub struct ColorScratch {
    last: Vec<Vec<u32>>,
    dims: Vec<usize>,
}

impl ColorScratch {
    const UNTOUCHED: u32 = u32::MAX;

    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, dims: &[usize]) {
        if self.dims != dims {
            self.last = dims.iter().map(|&d| vec![Self::UNTOUCHED; d]).collect();
            self.dims = dims.to_vec();
        } else {
            for mode in self.last.iter_mut() {
                mode.fill(Self::UNTOUCHED);
            }
        }
    }
}

/// The wave schedule a coloring pass produces: group indices bucketed by
/// color, ascending group index within each wave. Invariants (pinned by
/// `tests/properties.rs::prop_subgroup_coloring_is_disjoint_ordered_partition`):
/// the waves partition `0..n_groups`, same-wave groups have pairwise-
/// disjoint row footprints in every mode, and any two conflicting groups
/// appear in waves that preserve their plan order.
#[derive(Clone, Debug)]
pub struct SubGroupColoring {
    /// Group indices sorted by `(color, group index)`.
    order: Vec<u32>,
    /// `order[wave_offsets[w]..wave_offsets[w + 1]]` is wave `w`.
    wave_offsets: Vec<usize>,
}

impl SubGroupColoring {
    fn from_colors(colors: &[u32], n_waves: usize) -> SubGroupColoring {
        let mut wave_offsets = vec![0usize; n_waves + 1];
        for &c in colors {
            wave_offsets[c as usize + 1] += 1;
        }
        for w in 1..wave_offsets.len() {
            wave_offsets[w] += wave_offsets[w - 1];
        }
        let mut cursor = wave_offsets.clone();
        let mut order = vec![0u32; colors.len()];
        for (g, &c) in colors.iter().enumerate() {
            order[cursor[c as usize]] = g as u32;
            cursor[c as usize] += 1;
        }
        SubGroupColoring { order, wave_offsets }
    }

    /// The trivial one-wave schedule (relaxed dispatch: every sub-group
    /// freely concurrent, the paper's hogwild GPU write semantics).
    pub fn single_wave(n_groups: usize) -> SubGroupColoring {
        SubGroupColoring {
            order: (0..n_groups as u32).collect(),
            wave_offsets: if n_groups == 0 { vec![0] } else { vec![0, n_groups] },
        }
    }

    pub fn n_groups(&self) -> usize {
        self.order.len()
    }

    pub fn n_waves(&self) -> usize {
        self.wave_offsets.len() - 1
    }

    /// Group indices of wave `w`, ascending.
    pub fn wave(&self, w: usize) -> &[u32] {
        &self.order[self.wave_offsets[w]..self.wave_offsets[w + 1]]
    }

    /// Conflict-density summary the planner's pays-off gate reads.
    pub fn stats(&self) -> ColorStats {
        let max_wave = (0..self.n_waves()).map(|w| self.wave(w).len()).max().unwrap_or(0);
        ColorStats { n_groups: self.n_groups(), n_waves: self.n_waves(), max_wave }
    }
}

/// Summary of one coloring pass: how much intra-plan parallelism the
/// conflict structure exposes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ColorStats {
    pub n_groups: usize,
    /// Colors used (barrier-separated execution waves).
    pub n_waves: usize,
    /// Largest wave (peak concurrent sub-groups).
    pub max_wave: usize,
}

impl ColorStats {
    /// Mean sub-groups per wave — the parallel width threading can
    /// exploit; 1.0 means the conflict graph is a chain and threading
    /// degenerates to sequential execution with barrier overhead.
    pub fn parallelism(&self) -> f64 {
        if self.n_waves == 0 {
            0.0
        } else {
            self.n_groups as f64 / self.n_waves as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;

    fn check_tile_invariants(t: &SparseTensor, ids: &[u32], plan: &BatchPlan) {
        let order = t.order();
        let params = plan.params();

        // Permutation of the input multiset (holds for exact AND relaxed).
        let mut a = ids.to_vec();
        let mut b = plan.ids().to_vec();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "plan is not a permutation of the sample multiset");

        let mut total = 0usize;
        let mut fiber_slots = 0usize;
        for g in 0..plan.n_groups() {
            let grp = plan.group(g);
            assert!(!grp.is_empty() && grp.len() <= params.max_batch);
            total += grp.len();

            // Fibers form contiguous sub-runs; count them and check the
            // tile cap and per-fiber slot integrity (a coord0 value never
            // appears in two separate sub-runs of one group).
            let mut fibers_seen: Vec<u32> = Vec::new();
            let mut prev = None;
            for &k in grp {
                let c0 = t.index(k as usize)[0];
                if prev != Some(c0) {
                    assert!(
                        !fibers_seen.contains(&c0),
                        "fiber {c0} split into two sub-runs within a group"
                    );
                    fibers_seen.push(c0);
                    prev = Some(c0);
                }
            }
            assert!(fibers_seen.len() <= params.tile, "tile width exceeded");
            fiber_slots += fibers_seen.len();

            // Exact mode: modes >= 1 distinct across the whole tile.
            if params.exactness == Exactness::Exact {
                for n in 1..order {
                    let mut seen = std::collections::HashSet::new();
                    for &k in grp {
                        let coords = t.index(k as usize);
                        assert!(
                            seen.insert(coords[n]),
                            "mode {n} coordinate repeated within an exact group"
                        );
                    }
                }
            }
        }
        assert_eq!(total, plan.len());
        assert_eq!(fiber_slots, plan.fiber_slots(), "fiber_slots miscounted");
    }

    #[test]
    fn prop_plan_invariants() {
        forall("batch plan: permutation + fiber + distinctness", 24, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(30)).collect();
            let nnz = 1 + rng.gen_range(400);
            let t = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
            let n_ids = 1 + rng.gen_range(nnz);
            let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
            let max_batch = 1 + rng.gen_range(16);
            let plan = BatchPlan::build(&t, &ids, max_batch);
            assert_eq!(plan.tile(), 1);
            check_tile_invariants(&t, &ids, &plan);
        });
    }

    #[test]
    fn prop_tiled_plan_invariants() {
        // Tiled and relaxed plans over random shapes: permutation, caps,
        // per-fiber slot integrity, and (exact) tile-wide distinctness.
        forall("tiled/relaxed plan invariants", 24, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(30)).collect();
            let nnz = 1 + rng.gen_range(400);
            let t = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
            let n_ids = 1 + rng.gen_range(nnz);
            let ids: Vec<u32> = (0..n_ids).map(|_| rng.gen_range(nnz) as u32).collect();
            let params = PlanParams {
                max_batch: 1 + rng.gen_range(48),
                tile: 1 + rng.gen_range(8),
                exactness: if rng.gen_range(2) == 0 {
                    Exactness::Exact
                } else {
                    Exactness::Relaxed
                },
                split: 1 + rng.gen_range(4),
                ..Default::default()
            };
            let plan = BatchPlan::build_params(&t, &ids, params);
            check_tile_invariants(&t, &ids, &plan);
        });
    }

    #[test]
    fn prop_relaxed_is_permutation_and_not_shorter() {
        // Relaxed plans: always a permutation of the multiset, and never
        // more groups than the exact plan with identical caps (dropping a
        // split condition can only merge groups).
        forall("relaxed plan: permutation + fewer groups", 16, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(12)).collect();
            let nnz = 50 + rng.gen_range(400);
            let t = synth::random_uniform(rng, &dims, nnz, 1.0, 5.0);
            let ids: Vec<u32> = (0..nnz as u32).collect();
            let (cap, tile) = (2 + rng.gen_range(48), 1 + rng.gen_range(8));
            let exact = BatchPlan::build_params(&t, &ids, PlanParams::tiled(cap, tile));
            let relaxed = BatchPlan::build_params(&t, &ids, PlanParams::relaxed(cap, tile));
            check_tile_invariants(&t, &ids, &relaxed);
            assert!(
                relaxed.n_groups() <= exact.n_groups(),
                "relaxed formed more groups ({}) than exact ({})",
                relaxed.n_groups(),
                exact.n_groups()
            );
            assert_eq!(relaxed.ids().len(), ids.len());
        });
    }

    #[test]
    fn tiled_plans_lift_group_len_on_hollow_tensors() {
        // The acceptance-criterion shape: hollow tensor (mean mode-0
        // fiber length < 4); tiling must raise mean group length >= 4x
        // over single-fiber plans. Trailing modes are wide enough (512)
        // that exact-mode collision splits (~sqrt of the trailing dim)
        // don't cap groups below the 4x bar.
        let mut rng = crate::util::Rng::new(11);
        let dims = vec![4096usize, 512, 512];
        let t = synth::random_uniform(&mut rng, &dims, 8192, 1.0, 5.0);
        let ids: Vec<u32> = (0..t.nnz() as u32).collect();
        let single = BatchPlan::build_params(&t, &ids, PlanParams::exact(64));
        assert!(
            single.mean_group_len() < 4.0,
            "workload not hollow: mean group {}",
            single.mean_group_len()
        );
        let tiled = BatchPlan::build_params(&t, &ids, PlanParams::tiled(64, 32));
        assert!(
            tiled.mean_group_len() >= 4.0 * single.mean_group_len(),
            "tiling lifted mean group only {}x ({} -> {})",
            tiled.mean_group_len() / single.mean_group_len(),
            single.mean_group_len(),
            tiled.mean_group_len()
        );
        let relaxed = BatchPlan::build_params(&t, &ids, PlanParams::relaxed(64, 64));
        assert!(relaxed.mean_group_len() >= tiled.mean_group_len());
    }

    #[test]
    fn split_refines_groups_and_preserves_order_and_invariants() {
        // Split-group plans over a hollow tensor with long tiled groups:
        // the sample order is untouched (the sort is grouping-invariant),
        // groups only get more numerous, relaxed sub-groups respect the
        // split budget, and all tile invariants keep holding.
        let mut rng = crate::util::Rng::new(21);
        let dims = vec![2048usize, 400, 400];
        let t = synth::random_uniform(&mut rng, &dims, 6000, 1.0, 5.0);
        let ids: Vec<u32> = (0..t.nnz() as u32).collect();
        for exactness in [Exactness::Exact, Exactness::Relaxed] {
            let base = PlanParams { max_batch: 64, tile: 32, exactness, ..Default::default() };
            let unsplit = BatchPlan::build_params(&t, &ids, base);
            assert_eq!(unsplit.splits(), 0);
            for split in [2usize, 4, 64] {
                let params = base.with_split(split);
                let plan = BatchPlan::build_params(&t, &ids, params);
                check_tile_invariants(&t, &ids, &plan);
                assert_eq!(
                    plan.ids(),
                    unsplit.ids(),
                    "split changed the sample order ({exactness:?}, split {split})"
                );
                if exactness == Exactness::Relaxed {
                    let budget = params.split_budget();
                    for g in 0..plan.n_groups() {
                        assert!(
                            plan.group(g).len() <= budget,
                            "relaxed sub-group exceeds split budget {budget}"
                        );
                    }
                }
            }
            // At the finest split (budget 1) the rule must fire: every
            // multi-fiber (exact) / multi-sample (relaxed) group gets cut.
            let finest = BatchPlan::build_params(&t, &ids, base.with_split(64));
            assert!(
                finest.splits() > 0,
                "split rule never fired at budget 1 ({exactness:?})"
            );
            assert!(finest.n_groups() > unsplit.n_groups());
        }
    }

    #[test]
    fn exact_split_cuts_only_at_subrun_boundaries() {
        // Collision-free tensor (every mode-1/2 coordinate globally
        // unique) with 63 fibers of 32 samples: the only cuts an exact
        // split plan can make besides cap/tile are split-rule cuts, and
        // those must all land where a new fiber starts.
        let n = 63 * 32usize;
        let mut indices = Vec::with_capacity(3 * n);
        for i in 0..n {
            indices.extend_from_slice(&[(i / 32) as u32, i as u32, i as u32]);
        }
        let t = SparseTensor::new_unchecked(
            vec![63, n, n],
            indices,
            vec![1.0f32; n],
        );
        let ids: Vec<u32> = (0..n as u32).collect();
        let params = PlanParams { max_batch: 512, tile: 64, ..Default::default() }.with_split(8);
        assert_eq!(params.split_budget(), 64);
        let plan = BatchPlan::build_params(&t, &ids, params);
        assert!(plan.splits() > 0, "split rule never fired");
        for g in 1..plan.n_groups() {
            let prev_last = *plan.group(g - 1).last().unwrap();
            let first = plan.group(g)[0];
            assert_ne!(
                t.index(prev_last as usize)[0],
                t.index(first as usize)[0],
                "exact split-rule cut landed mid-fiber (group {g})"
            );
        }
        // Budget 64 = two 32-sample fibers per sub-group.
        for g in 0..plan.n_groups() {
            assert!(plan.group(g).len() <= 64);
        }
    }

    #[test]
    fn fiber_order_is_stable() {
        // Within one fiber, ids keep their stream order (tile > 1 too).
        let t = synth::random_uniform(&mut crate::util::Rng::new(1), &[4, 50, 50], 200, 1.0, 2.0);
        let ids: Vec<u32> = (0..200).collect();
        for params in [PlanParams::exact(64), PlanParams::tiled(64, 4), PlanParams::relaxed(64, 4)]
        {
            let plan = BatchPlan::build_params(&t, &ids, params);
            let mut last_pos: Vec<Option<u32>> = vec![None; 4];
            for &k in plan.ids() {
                let f = t.index(k as usize)[0] as usize;
                if let Some(prev) = last_pos[f] {
                    assert!(k > prev, "fiber {f}: {k} after {prev}");
                }
                last_pos[f] = Some(k);
            }
        }
    }

    #[test]
    fn empty_ids_give_empty_plan() {
        let t = synth::random_uniform(&mut crate::util::Rng::new(2), &[3, 3], 10, 1.0, 2.0);
        let plan = BatchPlan::build(&t, &[], 8);
        assert_eq!(plan.n_groups(), 0);
        assert!(plan.is_empty());
        assert_eq!(plan.fiber_slots(), 0);
    }

    #[test]
    fn recycled_scratch_builds_identical_plans() {
        // recycle() must not change planning results, and repeated builds
        // through one scratch reuse the donated buffers.
        let mut rng = crate::util::Rng::new(3);
        let t = synth::random_uniform(&mut rng, &[32, 40, 40], 600, 1.0, 5.0);
        let ids: Vec<u32> = (0..600).collect();
        let params = PlanParams::tiled(32, 4);
        let fresh = BatchPlan::build_params(&t, &ids, params);
        let mut scratch = PlanScratch::new();
        for _ in 0..3 {
            let plan = BatchPlan::build_params_with_scratch(&t, &ids, params, &mut scratch);
            assert_eq!(plan.ids(), fresh.ids());
            assert_eq!(plan.n_groups(), fresh.n_groups());
            assert_eq!(plan.fiber_slots(), fresh.fiber_slots());
            scratch.recycle(plan);
        }
    }

    #[test]
    fn scratch_alternates_exact_and_relaxed() {
        // A shared scratch must keep its stamps coherent when relaxed
        // builds (which skip stamping) interleave with exact builds.
        let mut rng = crate::util::Rng::new(4);
        let t = synth::random_uniform(&mut rng, &[16, 20, 20], 300, 1.0, 5.0);
        let ids: Vec<u32> = (0..300).collect();
        let mut scratch = PlanScratch::new();
        let e1 = BatchPlan::build_params_with_scratch(
            &t, &ids, PlanParams::tiled(32, 4), &mut scratch,
        );
        let r = BatchPlan::build_params_with_scratch(
            &t, &ids, PlanParams::relaxed(32, 4), &mut scratch,
        );
        let e2 = BatchPlan::build_params_with_scratch(
            &t, &ids, PlanParams::tiled(32, 4), &mut scratch,
        );
        assert_eq!(e1.ids(), e2.ids());
        assert_eq!(e1.n_groups(), e2.n_groups());
        check_tile_invariants(&t, &ids, &e2);
        check_tile_invariants(&t, &ids, &r);
    }

    // The full coloring invariant oracle (partition, per-wave all-mode
    // disjointness, conflict-order preservation over random shapes)
    // lives in `tests/properties.rs::
    // prop_subgroup_coloring_is_disjoint_ordered_partition` — the
    // module-local tests below cover only what it does not: scratch
    // reuse and the degenerate/constructed edges.

    #[test]
    fn coloring_scratch_reuse_matches_fresh() {
        let mut rng = crate::util::Rng::new(7);
        let t = synth::random_uniform(&mut rng, &[64, 30, 30], 500, 1.0, 5.0);
        let ids: Vec<u32> = (0..500).collect();
        let plan = BatchPlan::build_params(&t, &ids, PlanParams::tiled(32, 4).with_split(4));
        let fresh = plan.color_subgroups(&t);
        let mut scratch = ColorScratch::new();
        for _ in 0..3 {
            let c = plan.color_subgroups_with_scratch(&t, &mut scratch);
            assert_eq!(c.n_waves(), fresh.n_waves());
            for w in 0..c.n_waves() {
                assert_eq!(c.wave(w), fresh.wave(w));
            }
        }
    }

    #[test]
    fn coloring_degenerate_and_single_wave() {
        // Empty plan: zero waves. Disjoint-by-construction plan: one wave.
        let t = synth::random_uniform(&mut crate::util::Rng::new(8), &[4, 4, 4], 10, 1.0, 2.0);
        let empty = BatchPlan::build(&t, &[], 8);
        let c = empty.color_subgroups(&t);
        assert_eq!(c.n_waves(), 0);
        assert_eq!(c.n_groups(), 0);
        assert_eq!(c.stats().parallelism(), 0.0);

        // A collision-free tensor at split budget 1: every group is one
        // fiber with globally-unique rows, so all groups land in wave 0.
        let n = 12usize;
        let mut indices = Vec::new();
        for i in 0..n {
            indices.extend_from_slice(&[i as u32, i as u32, i as u32]);
        }
        let free = SparseTensor::new_unchecked(vec![n, n, n], indices, vec![1.0f32; n]);
        let ids: Vec<u32> = (0..n as u32).collect();
        let plan =
            BatchPlan::build_params(&free, &ids, PlanParams::tiled(8, 8).with_split(8));
        assert!(plan.n_groups() > 1);
        let c = plan.color_subgroups(&free);
        assert_eq!(c.n_waves(), 1, "disjoint groups must share one wave");
        assert_eq!(c.stats().max_wave, plan.n_groups());

        let single = SubGroupColoring::single_wave(5);
        assert_eq!(single.n_waves(), 1);
        assert_eq!(single.wave(0), &[0, 1, 2, 3, 4]);
        assert_eq!(SubGroupColoring::single_wave(0).n_waves(), 0);
    }

    #[test]
    fn group_offsets_index_plan_order() {
        let mut rng = crate::util::Rng::new(9);
        let t = synth::random_uniform(&mut rng, &[32, 20, 20], 300, 1.0, 5.0);
        let ids: Vec<u32> = (0..300).collect();
        let plan = BatchPlan::build_params(&t, &ids, PlanParams::tiled(16, 4));
        let mut off = 0usize;
        for g in 0..plan.n_groups() {
            assert_eq!(plan.group_offset(g), off);
            assert_eq!(
                &plan.ids()[off..off + plan.group(g).len()],
                plan.group(g)
            );
            off += plan.group(g).len();
        }
        assert_eq!(off, plan.len());
    }
}
