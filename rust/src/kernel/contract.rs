//! The Theorem-1/2 contraction primitives: staged-row workspace, the
//! per-sample contraction, and the Eq. 17 core-gradient accumulate/apply
//! pair. Moved here from `algo::fasttucker` so the serial, multi-device,
//! and PJRT engines share one implementation (re-exported there for
//! compatibility).
//!
//! Per sampled nonzero `(i_1..i_N, x)` the update costs `O(N·R_core·J)`:
//!
//! 1. `c[n][r] = b_r^(n) · a_{i_n}^(n)` — N·R dot products of length J
//!    (the warp-shuffle step of the CUDA kernel).
//! 2. `w[n][r] = Π_{m≠n} c[m][r]` via prefix/suffix products — O(N·R)
//!    total, an improvement over Algorithm 1's per-mode recomputation
//!    (O(N²·R)); numerically identical — see
//!    `tests::prefix_suffix_identity`.
//! 3. `GS^(n) = Σ_r w[n][r] · b_r^(n)` — the factor-update coefficient
//!    (paper Fig. 1 left).
//! 4. `x̂ = a^(1) · GS^(1)`, `e = x̂ - x`; factor row SGD (Eq. 13).
//! 5. Core gradients `∂/∂b_r^(n) = e · w[n][r] · a^(n)` (Eq. 17, where
//!    `w·a` is the paper's `Q^(n),r` vector, Fig. 1 right), accumulated
//!    over the epoch and applied with `M = |Ψ|` (Algorithm 1).
//!
//! The [`CoreLayout`] switch reproduces the paper's shared-vs-global-memory
//! ablation (Tables 8–12): `Packed` walks `b_r^(n)` as contiguous rows
//! (shared-memory analogue), `Strided` reads a column-major copy with
//! stride `R_core` (global-memory analogue).

use crate::kruskal::KruskalCore;
use crate::util::linalg::{axpy, dot};

/// Memory layout of the hot Kruskal factors (Tables 8–12 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreLayout {
    /// Contiguous `b_r^(n)` rows (paper: core factors in shared memory).
    Packed,
    /// Column-major copy, stride `R_core` between elements of one `b_r^(n)`
    /// (paper: core factors in global memory, uncoalesced).
    Strided,
}

/// Reusable scratch for the per-sample update — everything the CUDA kernel
/// would keep in registers/shared memory, preallocated so the hot loop
/// never allocates.
pub struct Workspace {
    pub(crate) order: usize,
    pub(crate) r_core: usize,
    pub(crate) j: usize,
    /// Staged factor rows for the current sample, `[n][j]`.
    pub(crate) a_stage: Vec<f32>,
    /// `c[n*R + r]`.
    c: Vec<f32>,
    /// Prefix products `pre[n*R + r] = Π_{m<n} c[m][r]`.
    pre: Vec<f32>,
    /// Suffix products.
    suf: Vec<f32>,
    /// `w[n*R + r] = Π_{m≠n} c[m][r]`.
    pub(crate) w: Vec<f32>,
    /// `gs[n*J .. (n+1)*J]`.
    pub(crate) gs: Vec<f32>,
    /// Core gradient accumulator, `[n][r][j]` flattened.
    pub(crate) core_grad: Vec<f32>,
    /// Number of samples accumulated into `core_grad`.
    pub(crate) core_grad_count: usize,
}

impl Workspace {
    pub fn new(order: usize, r_core: usize, j: usize) -> Self {
        Workspace {
            order,
            r_core,
            j,
            a_stage: vec![0.0; order * j],
            c: vec![0.0; order * r_core],
            pre: vec![0.0; (order + 1) * r_core],
            suf: vec![0.0; (order + 1) * r_core],
            w: vec![0.0; order * r_core],
            gs: vec![0.0; order * j],
            core_grad: vec![0.0; order * r_core * j],
            core_grad_count: 0,
        }
    }

    /// `GS^(n)` of the last contraction.
    #[inline]
    pub fn gs_row(&self, n: usize) -> &[f32] {
        &self.gs[n * self.j..(n + 1) * self.j]
    }

    /// Staged row for mode `n`.
    #[inline]
    pub fn staged_row(&self, n: usize) -> &[f32] {
        &self.a_stage[n * self.j..(n + 1) * self.j]
    }

    /// Stage one mode's factor row.
    #[inline]
    pub fn stage_row(&mut self, n: usize, row: &[f32]) {
        self.a_stage[n * self.j..(n + 1) * self.j].copy_from_slice(row);
    }

    /// Core-gradient accumulator (`[n][r][j]` flattened) and sample count —
    /// exposed so engines can all-reduce worker-local gradients.
    pub fn core_grad_mut(&mut self) -> (&mut Vec<f32>, &mut usize) {
        (&mut self.core_grad, &mut self.core_grad_count)
    }
}

/// The Thm-1/2 contraction for one staged sample. Reads `ws.a_stage`,
/// fills `ws.{c, w, gs}`, returns the residual `e = x̂ - x`.
///
/// `strided` is only consulted under [`CoreLayout::Strided`] and must hold
/// the column-major mirror of `core` (see [`build_strided`]).
pub fn contract_staged(
    ws: &mut Workspace,
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    x: f32,
) -> f32 {
    let order = ws.order;
    let r_core = ws.r_core;
    let j = ws.j;

    // Step 1: c[n][r] = b_r^(n) · a_{i_n} — a register-blocked matvec
    // against the contiguous B^(n) under the Packed layout.
    for n in 0..order {
        let a_row = &ws.a_stage[n * j..(n + 1) * j];
        match layout {
            CoreLayout::Packed => {
                crate::util::linalg::matvec_rowmajor(
                    core.factor(n).data(),
                    r_core,
                    j,
                    a_row,
                    &mut ws.c[n * r_core..(n + 1) * r_core],
                );
            }
            CoreLayout::Strided => {
                strided_matvec(
                    &strided[n],
                    r_core,
                    a_row,
                    &mut ws.c[n * r_core..(n + 1) * r_core],
                );
            }
        }
    }

    // Step 2: prefix/suffix products -> w[n][r].
    prefix_suffix_w(&ws.c, order, r_core, &mut ws.pre, &mut ws.suf, &mut ws.w);

    // Step 3: GS^(n) = Σ_r w[n][r] b_r^(n) — 4-row blocked weighted sum
    // under the Packed layout.
    ws.gs.fill(0.0);
    for n in 0..order {
        match layout {
            CoreLayout::Packed => {
                crate::util::linalg::weighted_rowsum(
                    core.factor(n).data(),
                    r_core,
                    j,
                    &ws.w[n * r_core..(n + 1) * r_core],
                    &mut ws.gs[n * j..(n + 1) * j],
                );
            }
            CoreLayout::Strided => {
                strided_weighted_sum(
                    &strided[n],
                    r_core,
                    j,
                    &ws.w[n * r_core..(n + 1) * r_core],
                    &mut ws.gs[n * j..(n + 1) * j],
                );
            }
        }
    }

    // Step 4: prediction and residual (mode-invariant; use mode 0).
    let xhat = dot(&ws.a_stage[0..j], &ws.gs[0..j]);
    xhat - x
}

/// Strided-layout (column-major mirror) step 1: `out[r] = Σ_j col[j][r]·a[j]`.
/// The single definition of this reduction — the scalar and batched paths
/// both call it, which is what keeps their float-op association (and hence
/// the bitwise-equivalence property) pinned in one place.
#[inline]
pub(crate) fn strided_matvec(col: &[f32], r_core: usize, a_row: &[f32], out: &mut [f32]) {
    for r in 0..r_core {
        let mut acc = 0.0f32;
        for (jj, &av) in a_row.iter().enumerate() {
            acc += col[jj * r_core + r] * av;
        }
        out[r] = acc;
    }
}

/// Strided-layout step 3: `out[j] = Σ_r w[r]·col[j][r]` (see
/// [`strided_matvec`] for why this lives here).
#[inline]
pub(crate) fn strided_weighted_sum(
    col: &[f32],
    r_core: usize,
    j: usize,
    w: &[f32],
    out: &mut [f32],
) {
    for jj in 0..j {
        let mut acc = 0.0f32;
        for r in 0..r_core {
            acc += w[r] * col[jj * r_core + r];
        }
        out[jj] = acc;
    }
}

/// Step 2 shared by the scalar and batched paths: prefix/suffix products
/// of `c` over modes, yielding `w[n][r] = Π_{m≠n} c[m][r]`. `pre`/`suf`
/// are `(order+1)*r_core` scratch; `c` and `w` are `order*r_core`.
#[inline]
pub(crate) fn prefix_suffix_w(
    c: &[f32],
    order: usize,
    r_core: usize,
    pre: &mut [f32],
    suf: &mut [f32],
    w: &mut [f32],
) {
    for r in 0..r_core {
        pre[r] = 1.0;
    }
    for n in 0..order {
        for r in 0..r_core {
            pre[(n + 1) * r_core + r] = pre[n * r_core + r] * c[n * r_core + r];
        }
    }
    for r in 0..r_core {
        suf[order * r_core + r] = 1.0;
    }
    for n in (0..order).rev() {
        for r in 0..r_core {
            suf[n * r_core + r] = suf[(n + 1) * r_core + r] * c[n * r_core + r];
        }
    }
    for n in 0..order {
        for r in 0..r_core {
            w[n * r_core + r] = pre[n * r_core + r] * suf[(n + 1) * r_core + r];
        }
    }
}

/// Wide-accumulation [`prefix_suffix_w`]: identical recurrence over f64
/// (ISSUE 10 `wide_accum` step 2). Kept next to the f32 definition so
/// the two associations can be compared side by side — the wide path
/// has no bitwise contract, but it must compute the *same* leave-one-out
/// products.
#[inline]
pub(crate) fn prefix_suffix_w_wide(
    c: &[f64],
    order: usize,
    r_core: usize,
    pre: &mut [f64],
    suf: &mut [f64],
    w: &mut [f64],
) {
    for r in 0..r_core {
        pre[r] = 1.0;
    }
    for n in 0..order {
        for r in 0..r_core {
            pre[(n + 1) * r_core + r] = pre[n * r_core + r] * c[n * r_core + r];
        }
    }
    for r in 0..r_core {
        suf[order * r_core + r] = 1.0;
    }
    for n in (0..order).rev() {
        for r in 0..r_core {
            suf[n * r_core + r] = suf[(n + 1) * r_core + r] * c[n * r_core + r];
        }
    }
    for n in 0..order {
        for r in 0..r_core {
            w[n * r_core + r] = pre[n * r_core + r] * suf[(n + 1) * r_core + r];
        }
    }
}

/// Wide-accumulation [`strided_matvec`] (ISSUE 10 `wide_accum` under the
/// Strided layout).
#[inline]
pub(crate) fn strided_matvec_wide(col: &[f32], r_core: usize, a_row: &[f32], out: &mut [f64]) {
    for r in 0..r_core {
        let mut acc = 0.0f64;
        for (jj, &av) in a_row.iter().enumerate() {
            acc += (col[jj * r_core + r] as f64) * (av as f64);
        }
        out[r] = acc;
    }
}

/// Wide-accumulation [`strided_weighted_sum`] (ISSUE 10 `wide_accum`
/// under the Strided layout).
#[inline]
pub(crate) fn strided_weighted_sum_wide(
    col: &[f32],
    r_core: usize,
    j: usize,
    w: &[f64],
    out: &mut [f64],
) {
    for jj in 0..j {
        let mut acc = 0.0f64;
        for r in 0..r_core {
            acc += w[r] * (col[jj * r_core + r] as f64);
        }
        out[jj] = acc;
    }
}

/// Accumulate the Eq. 17 core gradient for the last contraction into
/// `ws.core_grad` (uses the staged *pre-update* rows).
#[inline]
pub fn accumulate_core_grad(ws: &mut Workspace, e: f32) {
    let (order, r_core, j) = (ws.order, ws.r_core, ws.j);
    for n in 0..order {
        let a_row = &ws.a_stage[n * j..(n + 1) * j];
        for r in 0..r_core {
            let coef = e * ws.w[n * r_core + r];
            let base = (n * r_core + r) * j;
            axpy(coef, a_row, &mut ws.core_grad[base..base + j]);
        }
    }
    ws.core_grad_count += 1;
}

/// Apply an accumulated core gradient (Algorithm 1's batched core update
/// with `M = |Ψ|`): `b <- (1-lr·λ)b - lr·Σe·w·a / M`. Clears the
/// accumulator. Shared by every engine (serial workspace, batched
/// workspace, worker all-reduce).
pub fn apply_core_grad_raw(
    grad: &mut [f32],
    count: &mut usize,
    core: &mut KruskalCore,
    lr_c: f32,
    lam_c: f32,
) {
    if *count == 0 {
        return;
    }
    let m = *count as f32;
    let (order, r_core) = (core.order(), core.rank());
    for n in 0..order {
        let j = core.j(n);
        for r in 0..r_core {
            let g = &grad[(n * r_core + r) * j..(n * r_core + r + 1) * j];
            let row = core.row_mut(n, r);
            for (bi, &gi) in row.iter_mut().zip(g.iter()) {
                *bi = (1.0 - lr_c * lam_c) * *bi - lr_c * gi / m;
            }
        }
    }
    grad.fill(0.0);
    *count = 0;
}

/// [`apply_core_grad_raw`] over a [`Workspace`].
pub fn apply_core_grad(ws: &mut Workspace, core: &mut KruskalCore, lr_c: f32, lam_c: f32) {
    apply_core_grad_raw(&mut ws.core_grad, &mut ws.core_grad_count, core, lr_c, lam_c);
}

/// Build the column-major mirror used by [`CoreLayout::Strided`]:
/// `out[n][j*R + r] = b^(n)[r][j]`.
pub fn build_strided(core: &KruskalCore) -> Vec<Vec<f32>> {
    let order = core.order();
    let r_core = core.rank();
    (0..order)
        .map(|n| {
            let j = core.j(n);
            let mut buf = vec![0.0f32; j * r_core];
            for r in 0..r_core {
                for (jj, &v) in core.row(n, r).iter().enumerate() {
                    buf[jj * r_core + r] = v;
                }
            }
            buf
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CoreRepr, TuckerModel};
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    #[test]
    fn prefix_suffix_identity() {
        // w[n][r] computed by prefix/suffix equals the direct product
        // over m != n (what Algorithm 1 recomputes per mode).
        forall("prefix/suffix == direct leave-one-out product", 64, |rng| {
            let order = 2 + rng.gen_range(5);
            let r_core = 1 + rng.gen_range(6);
            let c: Vec<f32> = (0..order * r_core).map(|_| 0.2 + rng.uniform()).collect();
            let mut direct = vec![0.0f32; order * r_core];
            for n in 0..order {
                for r in 0..r_core {
                    let mut prod = 1.0f32;
                    for m in 0..order {
                        if m != n {
                            prod *= c[m * r_core + r];
                        }
                    }
                    direct[n * r_core + r] = prod;
                }
            }
            let mut pre = vec![1.0f32; (order + 1) * r_core];
            let mut suf = vec![1.0f32; (order + 1) * r_core];
            let mut w = vec![0.0f32; order * r_core];
            prefix_suffix_w(&c, order, r_core, &mut pre, &mut suf, &mut w);
            for n in 0..order {
                for r in 0..r_core {
                    let rel = (w[n * r_core + r] - direct[n * r_core + r]).abs()
                        / direct[n * r_core + r].abs().max(1e-6);
                    assert!(rel < 1e-4, "n={n} r={r}");
                }
            }
        });
    }

    #[test]
    fn contract_staged_prediction_matches_dense_core() {
        // Thm 1/2 identity at the Rust layer: linear-path x̂ equals the
        // exponential dense-core prediction.
        let mut rng = Rng::new(20);
        let model = TuckerModel::init_kruskal(&mut rng, &[10, 11, 12], 4, 3);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dense = core.to_dense();
        let mut ws = Workspace::new(3, 3, 4);
        for coords in [[0u32, 0, 0], [9, 10, 11], [5, 6, 7]] {
            for n in 0..3 {
                ws.stage_row(n, model.factors.row(n, coords[n] as usize));
            }
            let e = contract_staged(&mut ws, &core, &[], CoreLayout::Packed, 0.0);
            let want = dense.predict(&model.factors, &coords);
            assert!((e - want).abs() < 1e-4, "{e} vs {want}");
        }
    }

    #[test]
    fn apply_core_grad_raw_clears_accumulator() {
        let mut rng = Rng::new(21);
        let mut core = KruskalCore::random(&mut rng, 3, 4, 2, 1.0);
        let before = core.factor(0).data().to_vec();
        let mut grad = vec![1.0f32; 3 * 2 * 4];
        let mut count = 4usize;
        apply_core_grad_raw(&mut grad, &mut count, &mut core, 0.1, 0.0);
        assert_eq!(count, 0);
        assert!(grad.iter().all(|&g| g == 0.0));
        // b' = b - 0.1 * 1.0 / 4.
        for (a, b) in before.iter().zip(core.factor(0).data().iter()) {
            assert!((b - (a - 0.025)).abs() < 1e-6);
        }
    }
}
