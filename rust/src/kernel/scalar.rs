//! The scalar kernel: one nonzero at a time in stream order — the
//! reference semantics every other execution strategy must reproduce
//! bit-for-bit. This is the per-sample update extracted from the old
//! `FastTucker::train_epoch` inline loop (stage → contract → core-grad
//! accumulate → factor SGD write-back).
//!
//! This kernel stays pure f32 at every [`SimdLevel`](crate::kernel::SimdLevel)
//! and ignores `wide_accum` on purpose: it *is* the bitwise oracle the
//! SIMD panel microkernels and the f64 wide-accumulation path (ISSUE 10,
//! `kernel/batched.rs`) are differential-tested against, so it must
//! never move.

use crate::kernel::contract::{
    accumulate_core_grad, contract_staged, CoreLayout, Workspace,
};
use crate::kernel::{FactorAccess, KernelStats};
use crate::kruskal::KruskalCore;
use crate::tensor::SparseTensor;

/// Run the per-sample update over `ids` in order.
///
/// `strided` must hold the column-major core mirror when `layout` is
/// [`CoreLayout::Strided`] (see [`crate::kernel::build_strided`]); pass
/// `&[]` under `Packed`. When `residual_log` is given, each sample's
/// residual `e` is appended (the loss trajectory the equivalence property
/// tests compare bitwise).
#[allow(clippy::too_many_arguments)]
pub fn run_ids<F: FactorAccess>(
    ws: &mut Workspace,
    tensor: &SparseTensor,
    ids: &[u32],
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    factors: &mut F,
    lr_f: f32,
    lam_f: f32,
    update_core: bool,
    mut residual_log: Option<&mut Vec<f32>>,
) -> KernelStats {
    let order = ws.order;
    let j = ws.j;
    let beta = 1.0 - lr_f * lam_f;
    let mut sse = 0.0f64;
    for &k in ids {
        let k = k as usize;
        let coords = tensor.index(k);
        for n in 0..order {
            factors.stage(n, coords[n] as usize, &mut ws.a_stage[n * j..(n + 1) * j]);
        }
        let e = contract_staged(ws, core, strided, layout, tensor.value(k));
        if update_core {
            accumulate_core_grad(ws, e);
        }
        for n in 0..order {
            let gs_n = &ws.gs[n * j..(n + 1) * j];
            factors.update(n, coords[n] as usize, beta, -lr_f * e, gs_n);
        }
        sse += (e as f64) * (e as f64);
        if let Some(log) = residual_log.as_mut() {
            log.push(e);
        }
    }
    KernelStats { samples: ids.len(), sse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::model::{CoreRepr, TuckerModel};
    use crate::util::Rng;

    #[test]
    fn scalar_kernel_descends_sse() {
        let spec = PlantedSpec {
            dims: vec![20, 25, 30],
            nnz: 2000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, 4, 4);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..p.tensor.nnz() as u32).collect();
        let mut ws = Workspace::new(3, 4, 4);
        let first = run_ids(
            &mut ws, &p.tensor, &ids, &core, &[], CoreLayout::Packed,
            &mut model.factors, 0.02, 0.0, false, None,
        );
        let mut last = first;
        for _ in 0..5 {
            last = run_ids(
                &mut ws, &p.tensor, &ids, &core, &[], CoreLayout::Packed,
                &mut model.factors, 0.02, 0.0, false, None,
            );
        }
        assert_eq!(first.samples, p.tensor.nnz());
        assert!(last.sse < first.sse, "{} -> {}", first.sse, last.sse);
    }
}
