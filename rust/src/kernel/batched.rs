//! The batched kernel: cuFasterTucker-style fiber batching
//! (arXiv:2210.06014) on top of the Theorem-1/2 contraction.
//!
//! [`run_plan`] executes a [`BatchPlan`] group by group, where a group is
//! a **tile of mode-0 fibers** (each a contiguous sub-run):
//!
//! * each fiber's shared **mode-0 factor row is staged once per sub-run**
//!   and kept hot in a local buffer, its SGD updates applied there sample
//!   by sample and written back at sub-run end;
//! * the rows of every other mode are gathered into contiguous
//!   `batch × J` panels up front (exact plans guarantee they are pairwise
//!   distinct across the whole tile, so deferred reads/writes are exact;
//!   relaxed plans let duplicates through — those samples read the
//!   pre-group row and their deferred updates compose at group end,
//!   hogwild-style);
//! * step 1 of the contraction (`c = B^(n) a`) for modes ≥ 1 runs over the
//!   panels through the **lane-blocked panel microkernels**
//!   ([`crate::kernel::panel`]: 4- or 8-row register blocks over
//!   `R_core`, scalar tails pinned to the scalar primitives' float
//!   association, Kruskal rows reused across all samples of the group) —
//!   and step 3 (`GS = Σ_r w_r b_r`) is deferred and batched the same
//!   way; the lane width comes from
//!   [`PlanParams::lanes`](crate::kernel::plan::PlanParams), planner-chosen
//!   by default;
//! * only the short mode-0 chain (`c^(0)`, prefix/suffix, `GS^(0)`, the
//!   residual, and the hot-row update) remains sequential, because each
//!   sample must observe the previous sample's update to the shared row.
//!
//! Every floating-point reduction keeps the exact association of the
//! scalar path's primitives (`matvec_rowmajor` / `dot` /
//! `weighted_rowsum`), so under an [`Exactness::Exact`] plan the result
//! is **bitwise identical** to
//! [`scalar::run_ids`](crate::kernel::scalar::run_ids) over the same plan
//! order — pinned by `tests/properties.rs` (single-fiber and tiled) and
//! enforced as this module's contract. Relaxed plans trade that for
//! longer groups; the mode-0 chain stays exact either way.
//!
//! [`Exactness::Exact`]: crate::kernel::plan::Exactness
//!
//! [`minibatch_train_step`] / [`minibatch_predict`] are the deferred-read
//! panel variants with *mini-batch* semantics (every sample reads the
//! pre-batch state, duplicate-row deltas sum): the semantics of the AOT
//! JAX `train_step` graph, used by the PJRT runtime's native executor.

use crate::kernel::contract::{
    prefix_suffix_w, prefix_suffix_w_wide, strided_matvec, strided_matvec_wide,
    strided_weighted_sum, strided_weighted_sum_wide, CoreLayout,
};
use crate::kernel::panel;
use crate::kernel::plan::{Exactness, PlanScratch};
use crate::kernel::{BatchPlan, FactorAccess, KernelStats};
use crate::kruskal::KruskalCore;
use crate::tensor::SparseTensor;
use crate::util::linalg::{
    axpy, dot, matvec_rowmajor, matvec_rowmajor_wide, scale_axpy, weighted_rowsum,
    weighted_rowsum_wide,
};

/// Preallocated panels for batched execution (the GPU kernel's shared
/// memory, sized once for a maximum group length `cap`).
pub struct BatchWorkspace {
    pub(crate) order: usize,
    pub(crate) r_core: usize,
    pub(crate) j: usize,
    pub(crate) cap: usize,
    /// Hot copy of the group's shared mode-0 row.
    a0: Vec<f32>,
    /// Staged rows, `[s][n][j]`; slot `[s][0]` holds the per-sample
    /// snapshot of the hot row (the Eq. 17 linearization point). Read by
    /// the threaded dispatcher's core tape ([`crate::kernel::dispatch`]).
    pub(crate) a_panel: Vec<f32>,
    /// `c[s][n][r]`.
    c_panel: Vec<f32>,
    /// Per-sample prefix/suffix scratch, `(order+1)*r`.
    pre: Vec<f32>,
    suf: Vec<f32>,
    /// `w[s][n][r]` (tape-read by the threaded dispatcher).
    pub(crate) w_panel: Vec<f32>,
    /// `GS[s][n][j]`.
    gs_panel: Vec<f32>,
    /// Residuals of the current group (tape-read by the threaded
    /// dispatcher).
    pub(crate) e: Vec<f32>,
    /// Core gradient accumulator, `[n][r][j]` flattened (same layout as
    /// [`Workspace::core_grad`](crate::kernel::contract::Workspace)).
    pub(crate) core_grad: Vec<f32>,
    pub(crate) core_grad_count: usize,
    /// Reusable planning scratch (per-worker; see [`PlanScratch`]).
    pub(crate) plan_scratch: PlanScratch,
    /// Lazily-allocated f64 scratch for the relaxed wide-accumulation
    /// path ([`run_group_wide`]); `None` until the first wide group.
    wide: Option<WideScratch>,
}

/// Per-sample f64 scratch of the wide-accumulation path (ISSUE 10):
/// c/pre/suf/w for one sample plus one `gs` row — the wide path is
/// sequential per sample, so nothing is panel-sized.
struct WideScratch {
    c: Vec<f64>,
    pre: Vec<f64>,
    suf: Vec<f64>,
    w: Vec<f64>,
    gs: Vec<f64>,
}

impl WideScratch {
    fn new(order: usize, r_core: usize, j: usize) -> Self {
        WideScratch {
            c: vec![0.0; order * r_core],
            pre: vec![0.0; (order + 1) * r_core],
            suf: vec![0.0; (order + 1) * r_core],
            w: vec![0.0; order * r_core],
            gs: vec![0.0; j],
        }
    }
}

impl BatchWorkspace {
    pub fn new(order: usize, r_core: usize, j: usize, cap: usize) -> Self {
        assert!(cap >= 1);
        BatchWorkspace {
            order,
            r_core,
            j,
            cap,
            a0: vec![0.0; j],
            a_panel: vec![0.0; cap * order * j],
            c_panel: vec![0.0; cap * order * r_core],
            pre: vec![0.0; (order + 1) * r_core],
            suf: vec![0.0; (order + 1) * r_core],
            w_panel: vec![0.0; cap * order * r_core],
            gs_panel: vec![0.0; cap * order * j],
            e: vec![0.0; cap],
            core_grad: vec![0.0; order * r_core * j],
            core_grad_count: 0,
            plan_scratch: PlanScratch::new(),
            wide: None,
        }
    }

    /// The reusable plan scratch paired with this workspace.
    pub fn plan_scratch_mut(&mut self) -> &mut PlanScratch {
        &mut self.plan_scratch
    }

    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.order, self.r_core, self.j, self.cap)
    }

    /// Core-gradient accumulator and sample count — exposed so the
    /// multi-device engine can all-reduce worker-local gradients.
    pub fn core_grad_mut(&mut self) -> (&mut Vec<f32>, &mut usize) {
        (&mut self.core_grad, &mut self.core_grad_count)
    }
}

/// Execute `plan` with batched group semantics. Bitwise identical to the
/// scalar kernel over `plan.ids()` (see module docs). `strided` as in
/// [`crate::kernel::scalar::run_ids`].
#[allow(clippy::too_many_arguments)]
pub fn run_plan<F: FactorAccess>(
    ws: &mut BatchWorkspace,
    tensor: &SparseTensor,
    plan: &BatchPlan,
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    factors: &mut F,
    lr_f: f32,
    lam_f: f32,
    update_core: bool,
    mut residual_log: Option<&mut Vec<f32>>,
) -> KernelStats {
    assert!(plan.max_batch() <= ws.cap, "plan exceeds workspace capacity");
    let beta = 1.0 - lr_f * lam_f;
    // Panel-microkernel lane width and SIMD level for this plan (see
    // `kernel::panel`) — resolved once per run, never handed to the
    // kernels as `Auto`.
    let lanes = plan.params().lanes.resolve(ws.r_core);
    let simd = plan.params().simd.resolve();
    // ISSUE 10 mixed precision: wide f64 accumulation is relaxed-only
    // (config validation rejects wide + exact — it would break the
    // bitwise oracle by design); an exact plan that slips through in
    // release ignores the flag rather than silently changing bits.
    let wide = plan.params().wide_accum && plan.params().exactness == Exactness::Relaxed;
    debug_assert!(
        !(plan.params().wide_accum && plan.params().exactness == Exactness::Exact),
        "wide_accum is relaxed-only (rejected by TrainConfig::validate)"
    );
    let mut sse = 0.0f64;
    let mut samples = 0usize;

    for g in 0..plan.n_groups() {
        let ids = plan.group(g);
        let b = ids.len();
        samples += b;
        if wide {
            run_group_wide(
                ws, tensor, ids, core, strided, layout, lr_f, beta, factors, update_core,
            );
        } else {
            run_group(
                ws, tensor, ids, core, strided, layout, lanes, simd, lr_f, beta, factors,
                update_core,
            );
        }
        // Residual bookkeeping in plan order — the same per-sample f64
        // accumulation sequence as the historical inline loop, so the
        // refactor stays bitwise-neutral.
        for &e in &ws.e[..b] {
            sse += (e as f64) * (e as f64);
        }
        if let Some(log) = residual_log.as_mut() {
            log.extend_from_slice(&ws.e[..b]);
        }
    }

    KernelStats { samples, sse }
}

/// Execute ONE group of a plan: stage → panel contraction → sequential
/// mode-0 chain → deferred GS/SGD — the per-group body of [`run_plan`],
/// extracted so the threaded dispatcher ([`crate::kernel::dispatch`]) can
/// run independent sub-groups on separate workspaces/threads. Residuals
/// land in `ws.e[..ids.len()]`; the group's staged `a`/`w` panels stay
/// valid in `ws` afterwards (the dispatcher's core tape reads them).
/// `accumulate_core` performs the Eq. 17 accumulation into `ws.core_grad`
/// inline (the sequential semantics); the dispatcher passes `false` and
/// replays the accumulation in plan order from its tape instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_group<F: FactorAccess>(
    ws: &mut BatchWorkspace,
    tensor: &SparseTensor,
    ids: &[u32],
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    lanes: usize,
    simd: panel::SimdLevel,
    lr_f: f32,
    beta: f32,
    factors: &mut F,
    accumulate_core: bool,
) {
    let order = ws.order;
    let r = ws.r_core;
    let j = ws.j;
    let b = ids.len();
    // Gather modes >= 1 into the panel (rows distinct by plan in
    // exact mode; pre-group mini-batch snapshots in relaxed mode).
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        for n in 1..order {
            let base = (s * order + n) * j;
            factors.stage(n, coords[n] as usize, &mut ws.a_panel[base..base + j]);
        }
    }

    // Batched step 1 for modes >= 1: c[s][n] = B^(n) a[s][n], through
    // the lane-blocked panel microkernels.
    for n in 1..order {
        match layout {
            CoreLayout::Packed => panel::c_panel_packed(
                core.factor(n).data(),
                r,
                j,
                order,
                n,
                b,
                &ws.a_panel,
                &mut ws.c_panel,
                lanes,
                simd,
            ),
            CoreLayout::Strided => panel::c_panel_strided(
                &strided[n],
                r,
                j,
                order,
                n,
                b,
                &ws.a_panel,
                &mut ws.c_panel,
            ),
        }
    }

    // Sequential mode-0 chain over the tile's fiber sub-runs: each
    // sample observes the previous sample's update to its fiber's
    // shared row. The row is staged at each sub-run start and written
    // back at sub-run end — the sort guarantees a mode-0 coordinate
    // appears in at most one sub-run per group, so this observes
    // exactly the rows scalar execution would (even in relaxed mode).
    let mut cur_i0 = usize::MAX;
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        let i0 = coords[0] as usize;
        if i0 != cur_i0 {
            if cur_i0 != usize::MAX {
                factors.store(0, cur_i0, &ws.a0);
            }
            factors.stage(0, i0, &mut ws.a0);
            cur_i0 = i0;
        }
        let x = tensor.value(k as usize);
        let abase = s * order * j;
        let cbase = s * order * r;
        // Snapshot the hot row (pre-update linearization point).
        ws.a_panel[abase..abase + j].copy_from_slice(&ws.a0);
        match layout {
            CoreLayout::Packed => {
                matvec_rowmajor(
                    core.factor(0).data(),
                    r,
                    j,
                    &ws.a_panel[abase..abase + j],
                    &mut ws.c_panel[cbase..cbase + r],
                );
            }
            CoreLayout::Strided => {
                strided_matvec(
                    &strided[0],
                    r,
                    &ws.a_panel[abase..abase + j],
                    &mut ws.c_panel[cbase..cbase + r],
                );
            }
        }
        prefix_suffix_w(
            &ws.c_panel[cbase..cbase + order * r],
            order,
            r,
            &mut ws.pre,
            &mut ws.suf,
            &mut ws.w_panel[s * order * r..(s + 1) * order * r],
        );
        let gbase = s * order * j;
        match layout {
            CoreLayout::Packed => {
                weighted_rowsum(
                    core.factor(0).data(),
                    r,
                    j,
                    &ws.w_panel[cbase..cbase + r],
                    &mut ws.gs_panel[gbase..gbase + j],
                );
            }
            CoreLayout::Strided => {
                strided_weighted_sum(
                    &strided[0],
                    r,
                    j,
                    &ws.w_panel[cbase..cbase + r],
                    &mut ws.gs_panel[gbase..gbase + j],
                );
            }
        }
        let xhat = dot(&ws.a_panel[abase..abase + j], &ws.gs_panel[gbase..gbase + j]);
        let e = xhat - x;
        ws.e[s] = e;
        // Update the hot shared row (Eq. 13 on the current fiber).
        scale_axpy(beta, -lr_f * e, &ws.gs_panel[gbase..gbase + j], &mut ws.a0);
    }

    // Write the last fiber's shared row back.
    if cur_i0 != usize::MAX {
        factors.store(0, cur_i0, &ws.a0);
    }

    // Deferred batched step 3 for modes >= 1: GS[s][n] = Σ_r w b_r,
    // through the lane-blocked panel microkernels.
    for n in 1..order {
        match layout {
            CoreLayout::Packed => panel::gs_panel_packed(
                core.factor(n).data(),
                r,
                j,
                order,
                n,
                b,
                &ws.w_panel,
                &mut ws.gs_panel,
                lanes,
                simd,
            ),
            CoreLayout::Strided => panel::gs_panel_strided(
                &strided[n],
                r,
                j,
                order,
                n,
                b,
                &ws.w_panel,
                &mut ws.gs_panel,
            ),
        }
    }

    // Deferred factor SGD for modes >= 1. Exact plans: rows distinct
    // in the group, so the write order cannot change any operand.
    // Relaxed plans: duplicated rows were all staged pre-group
    // (stale/mini-batch reads) and their updates compose here in
    // sample order — the hogwild semantics the plan opted into.
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        let e = ws.e[s];
        for n in 1..order {
            let gbase = (s * order + n) * j;
            factors.update(
                n,
                coords[n] as usize,
                beta,
                -lr_f * e,
                &ws.gs_panel[gbase..gbase + j],
            );
        }
    }

    // Eq. 17 core-gradient accumulation from the staged (pre-update)
    // rows, in sample order — the same element-wise accumulation
    // sequence as the scalar path.
    if accumulate_core {
        for s in 0..b {
            accumulate_sample_core_grad(
                &mut ws.core_grad,
                ws.e[s],
                order,
                r,
                j,
                &ws.w_panel[s * order * r..(s + 1) * order * r],
                &ws.a_panel[s * order * j..(s + 1) * order * j],
            );
            ws.core_grad_count += 1;
        }
    }
}

/// The wide-accumulation group executor (ISSUE 10 mixed precision):
/// same group semantics as [`run_group`] under a relaxed plan — modes
/// ≥ 1 staged pre-group with deferred hogwild-composed updates, the
/// mode-0 chain sequential over fiber sub-runs — but every contraction
/// reduction (step 1 matvecs, step 2 prefix/suffix products, step 3
/// weighted sums, the x̂ dot) runs in **f64**, narrowing to the f32
/// storage exactly once per quantity: `w`/`gs` into the tape panels
/// (read by the deferred SGD and Eq. 17 accumulation) and the hot
/// mode-0 row at its SGD write-back. No panel microkernels — the wide
/// path is sequential per sample by design (`dispatch_plan` never
/// engages the pool for wide plans), trading throughput for
/// accumulation headroom on long fibers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_group_wide<F: FactorAccess>(
    ws: &mut BatchWorkspace,
    tensor: &SparseTensor,
    ids: &[u32],
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    lr_f: f32,
    beta: f32,
    factors: &mut F,
    accumulate_core: bool,
) {
    let order = ws.order;
    let r = ws.r_core;
    let j = ws.j;
    let b = ids.len();
    let mut wide = ws
        .wide
        .take()
        .unwrap_or_else(|| WideScratch::new(order, r, j));

    // Gather modes >= 1 into the panel (pre-group mini-batch snapshots —
    // the relaxed staging semantics of `run_group`).
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        for n in 1..order {
            let base = (s * order + n) * j;
            factors.stage(n, coords[n] as usize, &mut ws.a_panel[base..base + j]);
        }
    }

    // Sequential per-sample chain, all reductions in f64.
    let (beta_w, lr_w) = (beta as f64, lr_f as f64);
    let mut cur_i0 = usize::MAX;
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        let i0 = coords[0] as usize;
        if i0 != cur_i0 {
            if cur_i0 != usize::MAX {
                factors.store(0, cur_i0, &ws.a0);
            }
            factors.stage(0, i0, &mut ws.a0);
            cur_i0 = i0;
        }
        let x = tensor.value(k as usize);
        let abase = s * order * j;
        // Snapshot the hot row (pre-update linearization point for the
        // Eq. 17 tape, exactly as in `run_group`).
        ws.a_panel[abase..abase + j].copy_from_slice(&ws.a0);

        // Step 1, every mode: c[n][r] = b_r^(n) · a^(n), f64 accumulators.
        for n in 0..order {
            let a_row = &ws.a_panel[(s * order + n) * j..(s * order + n + 1) * j];
            let c_out = &mut wide.c[n * r..(n + 1) * r];
            match layout {
                CoreLayout::Packed => {
                    matvec_rowmajor_wide(core.factor(n).data(), r, j, a_row, c_out)
                }
                CoreLayout::Strided => strided_matvec_wide(&strided[n], r, a_row, c_out),
            }
        }

        // Step 2: leave-one-out products in f64; narrow into the w tape
        // (the Eq. 17 accumulation and the dispatcher-free replay read
        // f32 — one narrowing per w element).
        prefix_suffix_w_wide(&wide.c, order, r, &mut wide.pre, &mut wide.suf, &mut wide.w);
        for (dst, &src) in ws.w_panel[s * order * r..(s + 1) * order * r]
            .iter_mut()
            .zip(wide.w.iter())
        {
            *dst = src as f32;
        }

        // Step 3 for mode 0 + residual, f64 end to end.
        match layout {
            CoreLayout::Packed => {
                weighted_rowsum_wide(core.factor(0).data(), r, j, &wide.w[0..r], &mut wide.gs)
            }
            CoreLayout::Strided => {
                strided_weighted_sum_wide(&strided[0], r, j, &wide.w[0..r], &mut wide.gs)
            }
        }
        let mut xhat = 0.0f64;
        for (&a, &g) in ws.a_panel[abase..abase + j].iter().zip(wide.gs.iter()) {
            xhat += (a as f64) * g;
        }
        let e = xhat - x as f64;
        ws.e[s] = e as f32;
        // Eq. 13 on the hot mode-0 row: f64 arithmetic, one narrowing at
        // the store.
        for (a, &g) in ws.a0.iter_mut().zip(wide.gs.iter()) {
            *a = (beta_w * (*a as f64) - lr_w * e * g) as f32;
        }

        // Step 3 for modes >= 1: f64 weighted sums narrowed into the gs
        // panel; the deferred SGD below composes them hogwild-style.
        for n in 1..order {
            match layout {
                CoreLayout::Packed => weighted_rowsum_wide(
                    core.factor(n).data(),
                    r,
                    j,
                    &wide.w[n * r..(n + 1) * r],
                    &mut wide.gs,
                ),
                CoreLayout::Strided => strided_weighted_sum_wide(
                    &strided[n],
                    r,
                    j,
                    &wide.w[n * r..(n + 1) * r],
                    &mut wide.gs,
                ),
            }
            let gbase = (s * order + n) * j;
            for (dst, &src) in ws.gs_panel[gbase..gbase + j].iter_mut().zip(wide.gs.iter()) {
                *dst = src as f32;
            }
        }
    }

    // Write the last fiber's shared row back.
    if cur_i0 != usize::MAX {
        factors.store(0, cur_i0, &ws.a0);
    }

    // Deferred factor SGD for modes >= 1 (relaxed hogwild composition,
    // identical to `run_group`).
    for (s, &k) in ids.iter().enumerate() {
        let coords = tensor.index(k as usize);
        let e = ws.e[s];
        for n in 1..order {
            let gbase = (s * order + n) * j;
            factors.update(
                n,
                coords[n] as usize,
                beta,
                -lr_f * e,
                &ws.gs_panel[gbase..gbase + j],
            );
        }
    }

    // Eq. 17 core-gradient accumulation from the staged rows and the
    // narrowed w tape (same association as `run_group`).
    if accumulate_core {
        for s in 0..b {
            accumulate_sample_core_grad(
                &mut ws.core_grad,
                ws.e[s],
                order,
                r,
                j,
                &ws.w_panel[s * order * r..(s + 1) * order * r],
                &ws.a_panel[s * order * j..(s + 1) * order * j],
            );
            ws.core_grad_count += 1;
        }
    }

    ws.wide = Some(wide);
}

/// One sample's Eq. 17 core-gradient accumulation from its staged
/// (pre-update) panel slices (`w`: `order × r`, `a`: `order × j`).
/// The single definition of the accumulation association — the
/// sequential executor above AND the threaded dispatcher's plan-order
/// tape replay ([`crate::kernel::dispatch`]) both call it, which is what
/// makes the exact-mode pooled-vs-sequential bitwise contract structural
/// rather than two hand-kept copies.
pub(crate) fn accumulate_sample_core_grad(
    core_grad: &mut [f32],
    e: f32,
    order: usize,
    r: usize,
    j: usize,
    w: &[f32],
    a: &[f32],
) {
    for n in 0..order {
        let a_row = &a[n * j..(n + 1) * j];
        for rr in 0..r {
            let coef = e * w[n * r + rr];
            let base = (n * r + rr) * j;
            axpy(coef, a_row, &mut core_grad[base..base + j]);
        }
    }
}

/// Drain `(grad, count)` into `(grad0, count0)` — the worker-local /
/// thread-local core-gradient merge used by the multi-device all-reduce
/// ([`crate::parallel::worker`]) and the relaxed pooled epilogue
/// ([`crate::kernel::dispatch`]). Element-wise adds in slot order;
/// the source is zeroed.
pub fn merge_core_grad(
    grad0: &mut [f32],
    count0: &mut usize,
    grad: &mut [f32],
    count: &mut usize,
) {
    for (a, b) in grad0.iter_mut().zip(grad.iter()) {
        *a += *b;
    }
    *count0 += *count;
    grad.fill(0.0);
    *count = 0;
}

/// Pure mini-batch panel train step (deferred reads, duplicate deltas sum
/// at scatter): the semantics of the AOT JAX `train_step` graph, executed
/// natively by the PJRT runtime. `a_panels[n]` is `b × j` sample-major,
/// `b_mats[n]` is the `r × j` Kruskal factor. Writes updated rows,
/// accumulates `core_grads[n]` (`r × j`, zeroed here), and fills
/// `residuals`.
#[allow(clippy::too_many_arguments)]
pub fn minibatch_train_step(
    order: usize,
    b: usize,
    r_core: usize,
    j: usize,
    a_panels: &[&[f32]],
    b_mats: &[&[f32]],
    vals: &[f32],
    lr: f32,
    lam: f32,
    new_rows: &mut [Vec<f32>],
    core_grads: &mut [Vec<f32>],
    residuals: &mut [f32],
) {
    debug_assert_eq!(a_panels.len(), order);
    debug_assert_eq!(b_mats.len(), order);
    let beta = 1.0 - lr * lam;
    let mut c = vec![0.0f32; order * r_core];
    let mut pre = vec![0.0f32; (order + 1) * r_core];
    let mut suf = vec![0.0f32; (order + 1) * r_core];
    let mut w = vec![0.0f32; order * r_core];
    let mut gs = vec![0.0f32; j];
    for g in core_grads.iter_mut() {
        g.fill(0.0);
    }
    for s in 0..b {
        for n in 0..order {
            matvec_rowmajor(
                b_mats[n],
                r_core,
                j,
                &a_panels[n][s * j..(s + 1) * j],
                &mut c[n * r_core..(n + 1) * r_core],
            );
        }
        prefix_suffix_w(&c, order, r_core, &mut pre, &mut suf, &mut w);
        let mut e = -vals[s];
        // x̂ via mode 0 (mode-invariant).
        weighted_rowsum(b_mats[0], r_core, j, &w[0..r_core], &mut gs);
        e += dot(&a_panels[0][s * j..(s + 1) * j], &gs);
        residuals[s] = e;
        for n in 0..order {
            if n > 0 {
                weighted_rowsum(
                    b_mats[n],
                    r_core,
                    j,
                    &w[n * r_core..(n + 1) * r_core],
                    &mut gs,
                );
            }
            let a = &a_panels[n][s * j..(s + 1) * j];
            let out = &mut new_rows[n][s * j..(s + 1) * j];
            for jj in 0..j {
                out[jj] = beta * a[jj] - lr * e * gs[jj];
            }
            for rr in 0..r_core {
                let coef = e * w[n * r_core + rr];
                axpy(coef, a, &mut core_grads[n][rr * j..(rr + 1) * j]);
            }
        }
    }
}

/// Mini-batch panel prediction: `x̂[s] = Σ_r Π_n (b_r^(n) · a^(n)[s])`.
pub fn minibatch_predict(
    order: usize,
    b: usize,
    r_core: usize,
    j: usize,
    a_panels: &[&[f32]],
    b_mats: &[&[f32]],
    out: &mut [f32],
) {
    let mut c = vec![0.0f32; order * r_core];
    for s in 0..b {
        for n in 0..order {
            matvec_rowmajor(
                b_mats[n],
                r_core,
                j,
                &a_panels[n][s * j..(s + 1) * j],
                &mut c[n * r_core..(n + 1) * r_core],
            );
        }
        let mut acc = 0.0f32;
        for rr in 0..r_core {
            let mut prod = 1.0f32;
            for n in 0..order {
                prod *= c[n * r_core + rr];
            }
            acc += prod;
        }
        out[s] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kernel::scalar;
    use crate::kernel::Workspace;
    use crate::model::{CoreRepr, TuckerModel};
    use crate::util::Rng;

    fn setup(seed: u64) -> (crate::data::synth::Planted, TuckerModel, KruskalCore) {
        let spec = PlantedSpec {
            dims: vec![15, 40, 35],
            nnz: 3000,
            j: 6, // deliberately not a multiple of 4: exercises dot tails
            r_core: 5,
            noise: 0.05,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        let p = planted_tucker(&mut rng, &spec);
        let model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        (p, model, core)
    }

    #[test]
    fn batched_matches_scalar_bitwise_packed() {
        let (p, model, core) = setup(1);
        let ids: Vec<u32> = (0..p.tensor.nnz() as u32).collect();
        let plan = BatchPlan::build(&p.tensor, &ids, 64);

        let mut f_scalar = model.factors.clone();
        let mut ws = Workspace::new(3, 5, 6);
        let mut log_s = Vec::new();
        let st_s = scalar::run_ids(
            &mut ws, &p.tensor, plan.ids(), &core, &[], CoreLayout::Packed,
            &mut f_scalar, 0.01, 0.001, true, Some(&mut log_s),
        );

        let mut f_batch = model.factors.clone();
        let mut bws = BatchWorkspace::new(3, 5, 6, 64);
        let mut log_b = Vec::new();
        let st_b = run_plan(
            &mut bws, &p.tensor, &plan, &core, &[], CoreLayout::Packed,
            &mut f_batch, 0.01, 0.001, true, Some(&mut log_b),
        );

        assert_eq!(st_s.samples, st_b.samples);
        assert_eq!(st_s.sse.to_bits(), st_b.sse.to_bits());
        assert_eq!(log_s.len(), log_b.len());
        for (a, b) in log_s.iter().zip(log_b.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for n in 0..3 {
            for (a, b) in f_scalar
                .mat(n)
                .data()
                .iter()
                .zip(f_batch.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
            }
        }
        let (gs, cs) = ws.core_grad_mut();
        let (gb, cb) = bws.core_grad_mut();
        assert_eq!(*cs, *cb);
        for (a, b) in gs.iter().zip(gb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "core grads diverged");
        }
    }

    #[test]
    fn tiled_plan_matches_scalar_bitwise() {
        // The tentpole invariant at module level: a multi-fiber tile over
        // a hollow tensor (short fibers, so tiling actually engages) is
        // still bitwise-identical to scalar over plan order.
        let mut rng = Rng::new(5);
        let dims = vec![512usize, 60, 55];
        let tensor = crate::data::synth::random_uniform(&mut rng, &dims, 2000, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 6, 5);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        let plan = BatchPlan::build_params(
            &tensor,
            &ids,
            crate::kernel::plan::PlanParams::tiled(64, 8),
        );

        let mut f_scalar = model.factors.clone();
        let mut ws = Workspace::new(3, 5, 6);
        let st_s = scalar::run_ids(
            &mut ws, &tensor, plan.ids(), &core, &[], CoreLayout::Packed,
            &mut f_scalar, 0.01, 0.001, true, None,
        );

        let mut f_batch = model.factors.clone();
        let mut bws = BatchWorkspace::new(3, 5, 6, 64);
        let st_b = run_plan(
            &mut bws, &tensor, &plan, &core, &[], CoreLayout::Packed,
            &mut f_batch, 0.01, 0.001, true, None,
        );

        assert!(
            plan.stats().mean_fibers_per_group() > 1.0,
            "tile degenerate: {:?}",
            plan.stats()
        );
        assert_eq!(st_s.samples, st_b.samples);
        assert_eq!(st_s.sse.to_bits(), st_b.sse.to_bits());
        for n in 0..3 {
            for (a, b) in f_scalar
                .mat(n)
                .data()
                .iter()
                .zip(f_batch.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} factors diverged");
            }
        }
    }

    #[test]
    fn lane_widths_and_split_plans_match_scalar_bitwise() {
        // Module-level pin of the PR-3 tentpole, extended by ISSUE 10:
        // forcing either lane width at any host-supported SIMD level,
        // and refining groups with the split-group rule, keeps exact
        // batched execution bitwise identical to scalar over plan
        // order. R=5 exercises the quad+tail boundary at both widths.
        use crate::kernel::panel::{Lanes, SimdLevel};
        let mut rng = Rng::new(8);
        let dims = vec![512usize, 60, 55];
        let tensor = crate::data::synth::random_uniform(&mut rng, &dims, 2000, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 6, 5);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        for lanes in [Lanes::Auto, Lanes::W4, Lanes::W8] {
            // split 64 = budget 1, the finest refinement (every fiber
            // sub-run its own group) — guaranteed to engage on a tiled
            // hollow plan.
            for split in [1usize, 64] {
                // Scalar pins the oracle association; Auto resolves to
                // the host's best vector level (or back to Scalar) and
                // must not change a single bit.
                for simd in [SimdLevel::Scalar, SimdLevel::Auto] {
                    let params = crate::kernel::plan::PlanParams::tiled(64, 8)
                        .with_lanes(lanes)
                        .with_split(split)
                        .with_simd(simd);
                    let plan = BatchPlan::build_params(&tensor, &ids, params);
                    if split > 1 {
                        assert!(plan.splits() > 0, "split rule never engaged");
                    }

                    let mut f_scalar = model.factors.clone();
                    let mut ws = Workspace::new(3, 5, 6);
                    let st_s = scalar::run_ids(
                        &mut ws, &tensor, plan.ids(), &core, &[], CoreLayout::Packed,
                        &mut f_scalar, 0.01, 0.001, true, None,
                    );

                    let mut f_batch = model.factors.clone();
                    let mut bws = BatchWorkspace::new(3, 5, 6, 64);
                    let st_b = run_plan(
                        &mut bws, &tensor, &plan, &core, &[], CoreLayout::Packed,
                        &mut f_batch, 0.01, 0.001, true, None,
                    );

                    assert_eq!(st_s.samples, st_b.samples);
                    assert_eq!(
                        st_s.sse.to_bits(),
                        st_b.sse.to_bits(),
                        "{lanes:?} split {split} {simd:?}: sse diverged"
                    );
                    for n in 0..3 {
                        for (a, b) in f_scalar
                            .mat(n)
                            .data()
                            .iter()
                            .zip(f_batch.mat(n).data().iter())
                        {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{lanes:?} split {split} {simd:?}: mode {n} factors diverged"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn wide_accum_relaxed_tracks_f32_path_closely() {
        // ISSUE 10 mixed precision: on the same relaxed plan (same sample
        // order, same staging semantics) the wide f64-accumulation path
        // must track the f32 path within rounding noise — it changes
        // accumulation precision, not the algorithm. Both layouts.
        use crate::kernel::contract::build_strided;
        use crate::kernel::plan::{Exactness, PlanParams};
        let mut rng = Rng::new(9);
        let dims = vec![512usize, 60, 55];
        let tensor = crate::data::synth::random_uniform(&mut rng, &dims, 2000, 1.0, 5.0);
        let model = TuckerModel::init_kruskal(&mut rng, &dims, 6, 5);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let strided = build_strided(&core);
        let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
        for layout in [CoreLayout::Packed, CoreLayout::Strided] {
            let run = |wide: bool| {
                let params = PlanParams {
                    exactness: Exactness::Relaxed,
                    wide_accum: wide,
                    ..PlanParams::tiled(64, 8)
                };
                let plan = BatchPlan::build_params(&tensor, &ids, params);
                let mut f = model.factors.clone();
                let mut bws = BatchWorkspace::new(3, 5, 6, 64);
                let st = run_plan(
                    &mut bws, &tensor, &plan, &core, &strided, layout, &mut f, 0.01,
                    0.001, true, None,
                );
                (st, f)
            };
            let (st_f32, f_f32) = run(false);
            let (st_wide, f_wide) = run(true);
            assert_eq!(st_f32.samples, st_wide.samples);
            assert!(
                (st_f32.sse - st_wide.sse).abs() <= 1e-3 * st_f32.sse.max(1.0),
                "{layout:?}: sse {} vs wide {}",
                st_f32.sse,
                st_wide.sse
            );
            for n in 0..3 {
                for (a, b) in f_f32
                    .mat(n)
                    .data()
                    .iter()
                    .zip(f_wide.mat(n).data().iter())
                {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "{layout:?} mode {n}: {a} vs wide {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn minibatch_train_step_matches_per_sample_math() {
        // On a batch with all-distinct rows and frozen inputs, the
        // mini-batch panel step equals the staged scalar contraction.
        let (_p, _model, core) = setup(3);
        let (order, r, j, b) = (3usize, 5usize, 6usize, 8usize);
        let mut rng = Rng::new(4);
        let mut a_data: Vec<Vec<f32>> = Vec::new();
        for _ in 0..order {
            a_data.push((0..b * j).map(|_| rng.normal()).collect());
        }
        let a_panels: Vec<&[f32]> = a_data.iter().map(|v| v.as_slice()).collect();
        let b_data: Vec<&[f32]> = (0..order).map(|n| core.factor(n).data()).collect();
        let vals: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let mut new_rows: Vec<Vec<f32>> = (0..order).map(|_| vec![0.0; b * j]).collect();
        let mut grads: Vec<Vec<f32>> = (0..order).map(|_| vec![0.0; r * j]).collect();
        let mut resid = vec![0.0f32; b];
        let (lr, lam) = (0.02f32, 0.01f32);
        minibatch_train_step(
            order, b, r, j, &a_panels, &b_data, &vals, lr, lam,
            &mut new_rows, &mut grads, &mut resid,
        );

        let mut ws = Workspace::new(order, r, j);
        for s in 0..b {
            for n in 0..order {
                ws.stage_row(n, &a_data[n][s * j..(s + 1) * j]);
            }
            let e = crate::kernel::contract_staged(
                &mut ws, &core, &[], CoreLayout::Packed, vals[s],
            );
            assert!((e - resid[s]).abs() < 1e-5, "sample {s}: {e} vs {}", resid[s]);
            for n in 0..order {
                let gs = ws.gs_row(n);
                for jj in 0..j {
                    let want =
                        (1.0 - lr * lam) * a_data[n][s * j + jj] - lr * e * gs[jj];
                    let got = new_rows[n][s * j + jj];
                    assert!((want - got).abs() < 1e-5, "mode {n} s {s} j {jj}");
                }
            }
        }
    }
}
