//! The batch planner: a small cost model that picks [`PlanParams`] (group
//! cap and fiber-tile width) per dataset from mode-0 fiber-length
//! statistics, replacing the fixed `batch: 64`-style constants the
//! engines used to hard-code.
//!
//! The model has two inputs:
//!
//! * **Workspace footprint** — the batched kernel's panels cost
//!   `order · 2·(J + R_core) · 4` bytes per sample slot
//!   ([`BatchWorkspace`](crate::kernel::BatchWorkspace): `a`/`gs` panels
//!   of J floats and `c`/`w` panels of R floats, per mode). The cap is
//!   the largest power of two whose panels fit [`PANEL_BUDGET_BYTES`]
//!   (an L2-resident working set, the CPU analogue of the paper's
//!   shared-memory sizing), clamped to `[`[`MIN_CAP`]`, `[`MAX_CAP`]`]`
//!   and to the workload size.
//! * **Fiber-length statistics** ([`FiberStats`]) — on hollow HOHDST
//!   tensors (short fibers, the common recommender shape) single-fiber
//!   groups collapse toward scalar execution; the tile width is chosen
//!   so the *expected* group length reaches the cap:
//!   `tile ≈ cap / mean_fiber_len`, clamped to `[1, `[`MAX_TILE`]`]`.
//!   Tall tensors (fibers longer than the cap) get `tile = 1` — extra
//!   slots could never be filled.
//!
//! [`BatchSizing`] is the user-facing switch the engine configs carry:
//! `Auto` routes through this planner, `Fixed(n)` pins the legacy
//! single-fiber cap (0/1 = scalar execution).

use crate::kernel::dispatch::ThreadCount;
use crate::kernel::panel::{Lanes, SimdLevel};
use crate::kernel::plan::{ColorStats, Exactness, PlanParams};
use crate::log_warn;
use crate::tensor::SparseTensor;

/// Panel working-set budget the cap is sized against (≈ L2-resident).
pub const PANEL_BUDGET_BYTES: usize = 256 * 1024;
/// Cap bounds (power of two inside these).
pub const MIN_CAP: usize = 8;
pub const MAX_CAP: usize = 512;
/// Tile-width bound: staging cost per fiber is tiny (J floats), but very
/// wide tiles stop paying once groups reach the cap.
pub const MAX_TILE: usize = 64;

/// How an engine sizes its batch groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchSizing {
    /// Let the planner pick cap and tile from the dataset's fiber stats.
    Auto,
    /// Pin the legacy single-fiber group cap; `0`/`1` select the scalar
    /// kernel.
    Fixed(usize),
}

impl BatchSizing {
    /// Resolve to concrete [`PlanParams`] for a workload, or `None` when
    /// this sizing selects the scalar kernel. `lanes`/`simd`/`split` are
    /// the user's microkernel tuning ([`Lanes::Auto`] lets the planner
    /// pick the lane width from `R_core`, [`SimdLevel::Auto`] the vector
    /// level from the host via [`SimdLevel::resolve`]; `split` ≥ 1 is
    /// honored as given, with 0 treated as 1).
    #[allow(clippy::too_many_arguments)]
    pub fn resolve(
        self,
        tensor: &SparseTensor,
        ids_hint: usize,
        order: usize,
        r_core: usize,
        j: usize,
        exactness: Exactness,
        lanes: Lanes,
        simd: SimdLevel,
        split: usize,
    ) -> Option<PlanParams> {
        match self {
            BatchSizing::Fixed(b) if b < 2 => None,
            BatchSizing::Fixed(b) => Some(PlanParams {
                max_batch: b,
                tile: 1,
                exactness,
                lanes: resolve_lanes(lanes, r_core),
                simd: simd.resolve(),
                split: split.max(1),
                ..Default::default()
            }),
            BatchSizing::Auto => {
                let stats = FiberStats::compute_full(tensor, ids_hint);
                Some(choose_params(&stats, order, r_core, j, exactness, lanes, simd, split))
            }
        }
    }
}

/// Planner lane-width policy: honor an explicit width; materialize
/// [`Lanes::Auto`] through [`Lanes::resolve`] — the executor's runtime
/// policy is the single source of truth, so a planner-built plan always
/// reports the width the kernels actually run at.
pub fn resolve_lanes(lanes: Lanes, r_core: usize) -> Lanes {
    match lanes {
        Lanes::Auto => match Lanes::Auto.resolve(r_core) {
            8 => Lanes::W8,
            _ => Lanes::W4,
        },
        explicit => explicit,
    }
}

/// Mode-0 fiber-length statistics of a workload (an id multiset over a
/// tensor).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FiberStats {
    /// Samples the stats cover.
    pub n_ids: usize,
    /// Distinct mode-0 fibers among them.
    pub n_fibers: usize,
    pub mean_len: f64,
    /// 90th-percentile fiber length.
    pub p90_len: usize,
    pub max_len: usize,
}

impl FiberStats {
    /// Count fiber lengths of an explicit id multiset. O(ids + dims[0]).
    pub fn compute(tensor: &SparseTensor, ids: &[u32]) -> FiberStats {
        let mut counts = vec![0u32; tensor.dims()[0]];
        for &k in ids {
            counts[tensor.index(k as usize)[0] as usize] += 1;
        }
        Self::from_counts(ids.len(), &mut counts)
    }

    /// Per-mode-0-row nonzero counts of the whole tensor — the shared
    /// counting pass behind [`Self::compute_full`] and the device-shard
    /// layer's per-device decisions (which slice this by shard range).
    pub fn mode0_counts(tensor: &SparseTensor) -> Vec<u32> {
        let mut counts = vec![0u32; tensor.dims()[0]];
        for k in 0..tensor.nnz() {
            counts[tensor.index(k)[0] as usize] += 1;
        }
        counts
    }

    /// Stats over the whole tensor, scaled down to a workload of
    /// `ids_hint` samples (see [`Self::scaled_to`]).
    pub fn compute_full(tensor: &SparseTensor, ids_hint: usize) -> FiberStats {
        let mut counts = Self::mode0_counts(tensor);
        Self::from_mode0_counts(&mut counts).scaled_to(ids_hint)
    }

    /// Stats of a workload given its per-mode-0-row nonzero counts
    /// (`counts` is scratch: sorted in place). The device-shard layer
    /// uses this to derive **per-device** planner decisions from one
    /// global counting pass — a device's shard is a contiguous mode-0 row
    /// range, so its stats are the stats of that slice of the counts.
    pub fn from_mode0_counts(counts: &mut [u32]) -> FiberStats {
        let n_ids = counts.iter().map(|&c| c as usize).sum();
        Self::from_counts(n_ids, counts)
    }

    /// Scale these stats down to a workload of `ids_hint` samples — what
    /// a uniform sample of that size would see: lengths shrink
    /// proportionally, the fiber support does not grow. A hint at or
    /// above the population size is a no-op.
    pub fn scaled_to(mut self, ids_hint: usize) -> FiberStats {
        if ids_hint < self.n_ids && self.n_ids > 0 {
            let frac = ids_hint as f64 / self.n_ids as f64;
            self.mean_len = (self.mean_len * frac).max(1.0);
            self.p90_len = ((self.p90_len as f64 * frac).round() as usize).max(1);
            self.max_len = ((self.max_len as f64 * frac).round() as usize).max(1);
            self.n_ids = ids_hint;
        }
        self
    }

    fn from_counts(n_ids: usize, counts: &mut [u32]) -> FiberStats {
        // Sort the nonzero counts in place (counts buffer is scratch).
        counts.sort_unstable();
        let first_nonzero = counts.iter().position(|&c| c > 0).unwrap_or(counts.len());
        let lens = &counts[first_nonzero..];
        let n_fibers = lens.len();
        if n_fibers == 0 {
            return FiberStats::default();
        }
        let p90 = lens[((n_fibers * 9).div_ceil(10)).saturating_sub(1).min(n_fibers - 1)];
        FiberStats {
            n_ids,
            n_fibers,
            mean_len: n_ids as f64 / n_fibers as f64,
            p90_len: p90 as usize,
            max_len: lens[n_fibers - 1] as usize,
        }
    }
}

/// The cost model (see module docs): group cap from the panel footprint,
/// tile width from the fiber-length statistics, lane width from `R_core`
/// (via [`resolve_lanes`] when `lanes` is `Auto`), SIMD level from the
/// host (via [`SimdLevel::resolve`] when `simd` is `Auto`), split factor
/// honored as configured.
///
/// Degenerate workloads (empty tensor / empty id set: zero means in
/// `stats`) resolve to the minimum cap with a single-fiber tile — never a
/// zero cap, zero tile, or a division by zero.
#[allow(clippy::too_many_arguments)]
pub fn choose_params(
    stats: &FiberStats,
    order: usize,
    r_core: usize,
    j: usize,
    exactness: Exactness,
    lanes: Lanes,
    simd: SimdLevel,
    split: usize,
) -> PlanParams {
    let lanes = resolve_lanes(lanes, r_core);
    let simd = simd.resolve();
    let split = split.max(1);
    if stats.n_ids == 0 || stats.n_fibers == 0 {
        // Empty/degenerate workload: nothing to batch — minimum cap,
        // single-fiber tile (regression: ISSUE 3 satellite). When the
        // caller asked for relaxed or split-group semantics, those become
        // silent no-ops here — degrade LOUDLY instead (ISSUE 4
        // satellite): warn once per resolution and mark the params so
        // `PlanStats::degraded` records it.
        let degraded = exactness == Exactness::Relaxed || split > 1;
        if degraded {
            log_warn!(
                "degenerate workload (n_ids={}, n_fibers={}): requested \
                 exactness={exactness:?}/split={split} cannot engage — falling back to \
                 minimum-cap single-fiber groups (recorded in PlanStats::degraded)",
                stats.n_ids,
                stats.n_fibers
            );
        }
        return PlanParams {
            max_batch: MIN_CAP,
            tile: 1,
            exactness,
            lanes,
            simd,
            split,
            degraded,
            ..Default::default()
        };
    }
    let bytes_per_sample = order.max(1) * 2 * (j + r_core) * 4;
    let mut cap = PANEL_BUDGET_BYTES / bytes_per_sample.max(1);
    cap = cap.clamp(MIN_CAP, MAX_CAP);
    // Never size workspaces far beyond the workload itself.
    cap = cap.min(stats.n_ids.next_power_of_two().max(MIN_CAP));
    cap = prev_power_of_two(cap);
    // Zero/NaN-proof mean (a hand-built FiberStats can carry zeros even
    // with n_ids > 0).
    let mean = if stats.mean_len.is_finite() && stats.mean_len >= 1.0 {
        stats.mean_len
    } else {
        1.0
    };
    let tile = if mean >= cap as f64 {
        1
    } else {
        ((cap as f64 / mean).ceil() as usize).clamp(1, MAX_TILE.min(cap))
    };
    PlanParams { max_batch: cap, tile, exactness, lanes, simd, split, ..Default::default() }
}

/// Widest pool `Auto` will open on its own: wave parallelism on the
/// exact workloads the pool serves saturates quickly, and anything wider
/// is the user's explicit call (`Fixed(n)` or the env knob).
pub const AUTO_MAX_THREADS: usize = 4;

/// Resolve a [`ThreadCount`] to a concrete in-group pool width.
/// `Fixed(n)` is honored (clamped to ≥ 1). `Auto` reads
/// `FASTTUCKER_POOL_THREADS` (the CI differential knob) first; without
/// it, **exact** mode engages the measured cores-aware policy — pooled
/// exact execution is bitwise-neutral and has soaked through the
/// `FASTTUCKER_POOL_THREADS=2` CI leg since PR 4, so `Auto` now opens
/// `min(available cores, `[`AUTO_MAX_THREADS`]`)` — while **relaxed**
/// (hogwild) mode stays at 1: its pooling is racy by design and its
/// RMSE-envelope pins assume a single-threaded run, so it still engages
/// only on explicit opt-in.
pub fn resolve_threads(threads: ThreadCount, exactness: Exactness) -> usize {
    match threads {
        ThreadCount::Fixed(n) => n.max(1),
        ThreadCount::Auto => match std::env::var("FASTTUCKER_POOL_THREADS") {
            Err(_) => match exactness {
                Exactness::Exact => std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .min(AUTO_MAX_THREADS),
                Exactness::Relaxed => 1,
            },
            Ok(raw) => match raw.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => {
                    log_warn!(
                        "FASTTUCKER_POOL_THREADS={raw:?} is not a positive integer; \
                         running single-threaded"
                    );
                    1
                }
            },
        },
    }
}

/// Minimum mean sub-groups per coloring wave for in-group threading to
/// beat sequential dispatch: below this, waves are near-chains and the
/// barrier overhead outweighs the parallel width.
pub const MIN_WAVE_PARALLELISM: f64 = 2.0;

/// The planner's conflict-density gate: `true` when a coloring exposes
/// enough parallel width ([`ColorStats::parallelism`]) for a wave-
/// dispatched pool to pay off; `false` sends the pass down the
/// sequential (bitwise-identical) path instead.
pub fn coloring_pays_off(stats: &ColorStats) -> bool {
    stats.parallelism() >= MIN_WAVE_PARALLELISM
}

/// Mini-batch cap for the PJRT (AOT artifact) path: its `train_step`
/// applies a *sum-reduced* mini-batch gradient, so batches much larger
/// than the workload average away per-epoch progress on small tensors.
/// Aim for ≥ ~64 optimizer steps per epoch; the runtime picks the
/// largest compiled artifact batch under this cap.
pub fn pjrt_batch_cap(nnz: usize) -> usize {
    (nnz / 64).max(1).next_power_of_two().clamp(64, 65_536)
}

fn prev_power_of_two(x: usize) -> usize {
    debug_assert!(x >= 1);
    1usize << (usize::BITS - 1 - x.leading_zeros())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::tensor::SparseTensor;
    use crate::util::Rng;

    /// Order-3 tensor with one nonzero per given mode-0 coordinate.
    fn tensor_with_fibers(fiber_of_nnz: &[u32], dim0: usize) -> SparseTensor {
        let mut indices = Vec::new();
        let values = vec![1.0f32; fiber_of_nnz.len()];
        for (i, &f) in fiber_of_nnz.iter().enumerate() {
            indices.extend_from_slice(&[f, (i % 7) as u32, (i % 5) as u32]);
        }
        SparseTensor::new_unchecked(vec![dim0, 7, 5], indices, values)
    }

    #[test]
    fn fiber_stats_on_degenerate_shapes() {
        // All-singleton fibers: every nonzero its own fiber.
        let t = tensor_with_fibers(&(0..100u32).collect::<Vec<_>>(), 100);
        let ids: Vec<u32> = (0..100).collect();
        let s = FiberStats::compute(&t, &ids);
        assert_eq!(s.n_fibers, 100);
        assert!((s.mean_len - 1.0).abs() < 1e-12);
        assert_eq!(s.p90_len, 1);
        assert_eq!(s.max_len, 1);

        // One giant fiber.
        let t = tensor_with_fibers(&vec![3u32; 100], 10);
        let s = FiberStats::compute(&t, &ids);
        assert_eq!(s.n_fibers, 1);
        assert!((s.mean_len - 100.0).abs() < 1e-12);
        assert_eq!(s.max_len, 100);
        assert_eq!(s.p90_len, 100);
    }

    #[test]
    fn mode0_count_slices_give_per_shard_stats() {
        // The device-shard path: stats of a contiguous mode-0 row range
        // computed from a slice of the global counts must equal stats
        // computed from that shard's explicit id set.
        let fibers: Vec<u32> =
            (0..60u32).flat_map(|f| std::iter::repeat(f).take((f as usize % 5) + 1)).collect();
        let t = tensor_with_fibers(&fibers, 60);
        let mut counts = vec![0u32; 60];
        for k in 0..t.nnz() {
            counts[t.index(k)[0] as usize] += 1;
        }
        for (lo, hi) in [(0usize, 30usize), (30, 60), (0, 60), (10, 11)] {
            let mut slice = counts[lo..hi].to_vec();
            let from_counts = FiberStats::from_mode0_counts(&mut slice);
            let ids: Vec<u32> = (0..t.nnz() as u32)
                .filter(|&k| {
                    let f = t.index(k as usize)[0] as usize;
                    (lo..hi).contains(&f)
                })
                .collect();
            let from_ids = FiberStats::compute(&t, &ids);
            assert_eq!(from_counts, from_ids, "shard [{lo}, {hi})");
        }
        // scaled_to matches the historical compute_full scaling and is a
        // no-op at or above the population size.
        let full = FiberStats::compute_full(&t, t.nnz());
        assert_eq!(full.scaled_to(t.nnz() * 2), full);
        let half = FiberStats::compute_full(&t, t.nnz() / 2);
        assert_eq!(full.scaled_to(t.nnz() / 2), half);
        assert_eq!(half.n_ids, t.nnz() / 2);
    }

    #[test]
    fn planner_tiles_hollow_and_not_tall() {
        // All-singleton fibers => widest useful tile.
        let singleton = FiberStats { n_ids: 100_000, n_fibers: 100_000, mean_len: 1.0, p90_len: 1, max_len: 1 };
        let p = choose_params(&singleton, 3, 16, 16, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1);
        assert!(p.max_batch.is_power_of_two());
        assert!((MIN_CAP..=MAX_CAP).contains(&p.max_batch));
        assert_eq!(p.tile, MAX_TILE.min(p.max_batch), "singleton fibers want the max tile");

        // One giant fiber => single-fiber groups suffice.
        let giant = FiberStats { n_ids: 100_000, n_fibers: 1, mean_len: 100_000.0, p90_len: 100_000, max_len: 100_000 };
        let p = choose_params(&giant, 3, 16, 16, Exactness::Relaxed, Lanes::Auto, SimdLevel::Scalar, 1);
        assert_eq!(p.tile, 1);
        assert_eq!(p.exactness, Exactness::Relaxed);
    }

    #[test]
    fn planner_cap_respects_budget_and_workload() {
        // Budget shrinks the cap as panels grow.
        let s = FiberStats { n_ids: 1 << 20, n_fibers: 1 << 12, mean_len: 256.0, p90_len: 400, max_len: 800 };
        let small = choose_params(&s, 3, 8, 8, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1).max_batch;
        let big = choose_params(&s, 3, 64, 64, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1).max_batch;
        assert!(big <= small, "bigger panels must not get a bigger cap");
        assert!(big >= MIN_CAP);

        // Tiny workloads don't get giant workspaces.
        let tiny = FiberStats { n_ids: 20, n_fibers: 10, mean_len: 2.0, p90_len: 3, max_len: 4 };
        let p = choose_params(&tiny, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1);
        assert!(p.max_batch <= 32, "cap {} for a 20-sample workload", p.max_batch);
    }

    #[test]
    fn degenerate_relaxed_or_split_requests_are_marked_degraded() {
        // ISSUE 4 satellite: a degenerate workload silently neutering
        // relaxed/split semantics must be recorded, not swallowed.
        let empty = FiberStats::default();
        let p = choose_params(&empty, 3, 4, 4, Exactness::Relaxed, Lanes::Auto, SimdLevel::Scalar, 1);
        assert!(p.degraded, "relaxed on an empty workload must degrade loudly");
        let p = choose_params(&empty, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 4);
        assert!(p.degraded, "split > 1 on an empty workload must degrade loudly");
        assert_eq!(p.split, 4, "the requested split is still carried for observability");
        // Plain exact/unsplit degenerate resolution is NOT degraded.
        let p = choose_params(&empty, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1);
        assert!(!p.degraded);
        // Healthy workloads are never degraded.
        let s = FiberStats { n_ids: 1000, n_fibers: 100, mean_len: 10.0, p90_len: 15, max_len: 30 };
        let p = choose_params(&s, 3, 4, 4, Exactness::Relaxed, Lanes::Auto, SimdLevel::Scalar, 4);
        assert!(!p.degraded);

        // Through the Auto path end to end, and into PlanStats.
        let t = SparseTensor::new_unchecked(vec![4, 4, 4], Vec::new(), Vec::new());
        let p = BatchSizing::Auto
            .resolve(&t, 0, 3, 4, 4, Exactness::Relaxed, Lanes::Auto, SimdLevel::Scalar, 2)
            .unwrap();
        assert!(p.degraded);
        let plan = crate::kernel::BatchPlan::build_params(&t, &[], p);
        assert!(plan.stats().degraded, "degrade marker must reach PlanStats");
    }

    #[test]
    fn thread_resolution_and_pays_off_gate() {
        use crate::kernel::dispatch::ThreadCount;
        assert_eq!(resolve_threads(ThreadCount::Fixed(3), Exactness::Exact), 3);
        assert_eq!(
            resolve_threads(ThreadCount::Fixed(0), Exactness::Relaxed),
            1,
            "Fixed(0) clamps to 1"
        );
        // Auto without the env override: exact mode engages the
        // cores-aware policy (≥ 1, capped), relaxed mode stays
        // sequential — its nondeterminism needs an explicit opt-in.
        // (The env-set case is exercised by CI's
        // FASTTUCKER_POOL_THREADS=2 pass; not asserted here to keep the
        // test env-independent.)
        if std::env::var("FASTTUCKER_POOL_THREADS").is_err() {
            let auto = resolve_threads(ThreadCount::Auto, Exactness::Exact);
            assert!(
                (1..=AUTO_MAX_THREADS).contains(&auto),
                "cores-aware Auto resolved to {auto}"
            );
            assert_eq!(resolve_threads(ThreadCount::Auto, Exactness::Relaxed), 1);
        }

        // Conflict-density gate: chains don't pay, wide waves do.
        let chain = ColorStats { n_groups: 8, n_waves: 8, max_wave: 1 };
        assert!(!coloring_pays_off(&chain));
        let wide = ColorStats { n_groups: 64, n_waves: 4, max_wave: 20 };
        assert!(coloring_pays_off(&wide));
        let empty = ColorStats::default();
        assert!(!coloring_pays_off(&empty));
    }

    #[test]
    fn planner_degenerate_inputs_return_minimum_params() {
        // ISSUE 3 satellite: zero FiberStats means (empty workload) must
        // not divide by zero or emit a zero cap/tile.
        let empty = FiberStats::default();
        assert_eq!(empty.n_ids, 0);
        let p = choose_params(&empty, 3, 16, 16, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1);
        assert_eq!(p.max_batch, MIN_CAP);
        assert_eq!(p.tile, 1);
        assert!(p.split >= 1);

        // Hand-built stats with n_ids > 0 but zeroed means must also be
        // safe (tile ≥ 1, cap ≥ MIN_CAP).
        let weird = FiberStats { n_ids: 5, n_fibers: 5, mean_len: 0.0, p90_len: 0, max_len: 0 };
        let p = choose_params(&weird, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1);
        assert!(p.max_batch >= MIN_CAP && p.tile >= 1);

        // split = 0 is normalized to 1, not propagated.
        let p = choose_params(&empty, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 0);
        assert_eq!(p.split, 1);

        // Empty tensor through the Auto path end to end.
        let t = SparseTensor::new_unchecked(vec![4, 4, 4], Vec::new(), Vec::new());
        let p = BatchSizing::Auto
            .resolve(&t, 0, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1)
            .unwrap();
        assert_eq!(p.max_batch, MIN_CAP);
        assert_eq!(p.tile, 1);

        // One-nnz tensor: minimum cap, nonzero tile.
        let one = SparseTensor::new_unchecked(vec![4, 4, 4], vec![1, 2, 3], vec![1.0]);
        let p = BatchSizing::Auto
            .resolve(&one, 1, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1)
            .unwrap();
        assert!(p.max_batch >= MIN_CAP && p.tile >= 1);
    }

    #[test]
    fn planner_selects_lane_width_from_r_core() {
        let s = FiberStats { n_ids: 1000, n_fibers: 100, mean_len: 10.0, p90_len: 15, max_len: 30 };
        assert_eq!(
            choose_params(&s, 3, 16, 16, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1).lanes,
            Lanes::W8
        );
        assert_eq!(
            choose_params(&s, 3, 8, 8, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1).lanes,
            Lanes::W8
        );
        assert_eq!(
            choose_params(&s, 3, 7, 8, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1).lanes,
            Lanes::W4
        );
        // Explicit widths are honored.
        assert_eq!(
            choose_params(&s, 3, 16, 16, Exactness::Exact, Lanes::W4, SimdLevel::Scalar, 1).lanes,
            Lanes::W4
        );
        // Split passes through.
        assert_eq!(
            choose_params(&s, 3, 16, 16, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 4).split,
            4
        );
    }

    #[test]
    fn batch_sizing_resolves() {
        let mut rng = Rng::new(9);
        let t = synth::random_uniform(&mut rng, &[128, 32, 32], 1000, 1.0, 5.0);
        assert_eq!(
            BatchSizing::Fixed(0).resolve(&t, 1000, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1),
            None
        );
        assert_eq!(
            BatchSizing::Fixed(1).resolve(&t, 1000, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1),
            None
        );
        let fixed = BatchSizing::Fixed(48)
            .resolve(&t, 1000, 3, 4, 4, Exactness::Relaxed, Lanes::Auto, SimdLevel::Scalar, 2)
            .unwrap();
        assert_eq!(fixed.max_batch, 48);
        assert_eq!(fixed.tile, 1);
        assert_eq!(fixed.exactness, Exactness::Relaxed);
        assert_eq!(fixed.lanes, Lanes::W4, "r_core 4 resolves to 4-lane blocks");
        assert_eq!(fixed.split, 2);
        let auto = BatchSizing::Auto
            .resolve(&t, 1000, 3, 4, 4, Exactness::Exact, Lanes::Auto, SimdLevel::Scalar, 1)
            .unwrap();
        assert!(auto.max_batch >= MIN_CAP);
        // mean fiber len ~ 1000/128 ≈ 7.8 — hollow, so the tile engages.
        assert!(auto.tile > 1, "hollow tensor must tile: {auto:?}");
    }

    #[test]
    fn pjrt_cap_scales_with_nnz() {
        assert_eq!(pjrt_batch_cap(0), 64);
        assert_eq!(pjrt_batch_cap(4_000), 64);
        assert_eq!(pjrt_batch_cap(100_000), 2048);
        assert_eq!(pjrt_batch_cap(usize::MAX / 2), 65_536);
    }

    #[test]
    fn prev_power_of_two_bounds() {
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(2), 2);
        assert_eq!(prev_power_of_two(3), 2);
        assert_eq!(prev_power_of_two(511), 256);
        assert_eq!(prev_power_of_two(512), 512);
    }
}
