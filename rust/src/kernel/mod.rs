//! The shared FastTucker kernel layer: one implementation of the
//! per-sample Theorem-1/2 update, consumed by every engine (serial,
//! multi-device, PJRT).
//!
//! Mapping to the paper (Fig. 1 / Algorithm 1), per sampled nonzero
//! `(i_1..i_N, x)`:
//!
//! | Stage                | Paper step                                | Here |
//! |----------------------|-------------------------------------------|------|
//! | **stage**            | gather `a_{i_n}^(n)` into shared memory   | [`FactorAccess::stage`] into `a` panels |
//! | **contract (c)**     | `c_r^(n) = b_r^(n) · a_{i_n}^(n)` (warp-shuffle dots) | [`contract::contract_staged`] step 1 / [`batched`] c-panels |
//! | **contract (w)**     | `w_r^(n) = Π_{m≠n} c_r^(m)` (Thm 1/2 reduction) | prefix/suffix products |
//! | **factor SGD**       | Eq. 13: `a ← a - γ(e·GS + λa)` with `GS^(n) = Σ_r w_r b_r^(n)` | [`FactorAccess::update`] |
//! | **core-grad accumulate** | Eq. 17: `∂/∂b_r^(n) = e·w_r^(n)·a^(n)`, applied with `M = |Ψ|` | `core_grad` accumulators + [`contract::apply_core_grad_raw`] |
//!
//! Module map:
//!
//! | Module       | Role |
//! |--------------|------|
//! | [`contract`] | Thm-1/2 contraction primitives + core-grad accumulate/apply (the per-sample math) |
//! | [`plan`]     | [`BatchPlan`]: tiles of mode-0 fibers per group, [`Exactness::Exact`] (bitwise) or [`Exactness::Relaxed`] (hogwild), split-group refinement ([`PlanParams::split`]), sub-group coloring ([`BatchPlan::color_subgroups`]: the row-ownership waves in-group threading executes) |
//! | [`planner`]  | Cost model choosing [`PlanParams`] (cap, tile, lane width) from fiber-length stats and `R_core`; [`BatchSizing`] `Auto`/`Fixed`; thread resolution + the coloring pays-off gate |
//! | [`scalar`]   | Reference executor: one nonzero at a time in stream order |
//! | [`batched`]  | Fiber-batched executor over a plan: per-fiber hot rows, flat `batch × R_core` panels |
//! | [`panel`]    | SIMD panel microkernels: [`Lanes`] 4/8 row blocks over `R_core` executed with real arch intrinsics (SSE2/AVX2/NEON) behind runtime detection ([`SimdLevel`] `Auto`/`Scalar`/`V128`/`V256`, `FASTTUCKER_SIMD`), scalar tails — bitwise-identical to the scalar association at every level |
//! | [`dispatch`] | In-group thread pool ([`DispatchPool`]): fans a plan's split sub-groups across T threads as barrier-separated coloring waves (exact: bitwise-identical to sequential via the plan-order tape; relaxed: one hogwild wave) |
//! | [`crate::analysis`] | Concurrency-safety layer over everything above: first-principles disjointness auditor (`strict-audit` re-checks every coloring/grid), shadow race detector (`shadow-ledger` records every `SharedFactors` row access), and the unsafe-discipline source lint |
//! | [`crate::parallel::transport`] | Fault-tolerant exchange behind the device grid: boundary-row and core-gradient panels as framed, checksummed messages over a `Transport` trait (in-proc bitwise oracle + seeded fault injector), with retry/dedup/backoff recovery, typed `TransportError`s, and a protocol event log audited by `analysis::audit_exchange` |
//!
//! Above this layer sits the parallel engine's **three-level
//! disjointness** stack — device grid × Latin schedule × color waves
//! ([`crate::parallel::DeviceGrid`] shards workers/nonzeros/rows across
//! devices; see [`crate::parallel::shared`] for the full contract): each
//! level only refines the one below, so exact-mode execution stays
//! bitwise-identical from a single scalar pass all the way to a
//! multi-device, multi-worker, multi-thread run.
//!
//! Two execution strategies share that math bit-for-bit:
//!
//! * [`scalar`] — one nonzero at a time, in stream order. This is the
//!   reference semantics (what `FastTucker::train_epoch` historically did
//!   inline).
//! * [`batched`] — the cuFasterTucker-style batching (arXiv:2210.06014):
//!   nonzeros are grouped into **tiles of mode-1 fibers**
//!   ([`plan::BatchPlan`]), each fiber's shared factor row is staged
//!   **once per sub-run**, and the contraction runs over contiguous
//!   `batch × R_core` panels so the inner loops are flat,
//!   allocation-free, and auto-vectorizable. Under
//!   [`Exactness::Exact`] plans the group construction guarantees the
//!   batched path is **bitwise identical** to [`scalar`] run over the
//!   same (grouped) sample order — see
//!   `tests/properties.rs::prop_batched_kernel_bitwise_matches_scalar`
//!   and `prop_tiled_batched_bitwise_matches_scalar`.
//!   [`Exactness::Relaxed`] plans drop the intra-tile distinctness
//!   constraint (the paper's hogwild GPU write semantics) for much longer
//!   groups on hollow tensors.
//!
//! The [`contract::CoreLayout`] parameter (Packed vs Strided walk of the
//! Kruskal factors) threads through both strategies, keeping the paper's
//! Tables 8–12 shared-vs-global-memory ablation runnable on either path.

pub mod contract;
pub mod dispatch;
pub mod panel;
pub mod plan;
pub mod planner;
pub mod scalar;
pub mod batched;

pub use batched::BatchWorkspace;
pub use contract::{
    accumulate_core_grad, apply_core_grad, apply_core_grad_raw, build_strided,
    contract_staged, CoreLayout, Workspace,
};
pub use dispatch::{DispatchPool, ThreadCount};
pub use panel::{Lanes, SimdLevel};
pub use plan::{BatchPlan, ColorScratch, ColorStats, Exactness, PlanParams, PlanScratch, SubGroupColoring};
pub use planner::{BatchSizing, FiberStats};

use crate::model::factors::FactorMatrices;
use crate::util::linalg::scale_axpy;

/// Aggregate result of one kernel invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelStats {
    /// Nonzeros processed.
    pub samples: usize,
    /// Sum of squared residuals over the processed samples, accumulated in
    /// sample order (an f64 so the scalar/batched paths agree bitwise when
    /// their residual streams do).
    pub sse: f64,
}

/// Row-level access to the factor matrices — the seam that lets the same
/// kernel run against plain [`FactorMatrices`] (serial/PJRT engines) and
/// the multi-device [`SharedFactors`](crate::parallel::shared::SharedFactors)
/// view (Latin-schedule workers).
pub trait FactorAccess {
    /// Copy row `(n, i)` into `out` (`out.len()` = J).
    fn stage(&self, n: usize, i: usize, out: &mut [f32]);

    /// `row ← beta·row + alpha·x` — the Eq. 13 SGD write-back.
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]);

    /// Overwrite row `(n, i)` with `src` (group write-back of the staged
    /// shared row).
    fn store(&mut self, n: usize, i: usize, src: &[f32]);
}

impl FactorAccess for FactorMatrices {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(n, i));
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        scale_axpy(beta, alpha, x, self.row_mut(n, i));
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        self.row_mut(n, i).copy_from_slice(src);
    }
}
