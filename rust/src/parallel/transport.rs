//! Fault-tolerant message transport for the device grid's parameter
//! exchange (ROADMAP item 2, transport half).
//!
//! Historically the grid's round-boundary "exchange" was bookkeeping: the
//! factor rows live in shared memory, so handing a chunk to its next
//! owner was free and infallible. This module makes the exchange a real
//! data path — boundary-row panels and core-gradient panels travel as
//! **serialized, framed, checksummed messages** between devices — so the
//! failure modes a multi-process/multi-node backend will have (lost,
//! duplicated, reordered, corrupted, delayed messages; dead peers) exist
//! here first, behind a deterministic in-process oracle, and every
//! detection/recovery path is testable bitwise.
//!
//! # Layers
//!
//! * [`Frame`] — the wire format: a fixed header (epoch, round,
//!   source/destination device, panel kind, mode, chunk, row range,
//!   sequence number, payload length) plus an opaque little-endian f32
//!   payload, trailed by an FNV-1a-64 checksum over everything before it.
//! * [`Transport`] — moves opaque frame bytes between device mailboxes.
//!   Deliberately **non-blocking and virtual-timed**: `recv` returns
//!   `None` when a mailbox is empty (the receiver's timeout signal) and
//!   [`Transport::tick`] advances virtual time, releasing delayed
//!   frames. Timeout/backoff are therefore attempt-counted, fully
//!   deterministic, and fast under test — no wall clocks.
//! * [`InProcTransport`] — per-device FIFO mailboxes; the bitwise
//!   oracle. Exact-mode training over it is bitwise-identical to the
//!   direct in-memory exchange at every device count (pinned by
//!   `tests/properties.rs::prop_channel_transport_exact_bitwise_matches_direct`).
//! * [`FaultyTransport`] — wraps the oracle and injects faults per a
//!   seeded [`FaultPlan`]: drops, duplicates, reorders, corruption
//!   (payload bit-flips the checksum must catch), delays (released on
//!   `tick`), and a permanent device kill.
//! * [`Exchanger`] — the protocol: a two-phase exchange per round
//!   barrier (send every inter-device panel, then drain/validate with
//!   sequence-number dedup, reorder buffering, and bounded
//!   resend-with-backoff), surfacing unrecoverable failures as typed
//!   [`TransportError`]s and counting every recovery in
//!   [`TransportStats`]. It can also record a plain-data
//!   [`ExchangeEvent`] stream for the in-flight-exchange auditor
//!   ([`crate::analysis::audit_exchange`]).
//!
//! # What recovers, what degrades, what fails
//!
//! * **Drops** recover by bounded resend with exponential virtual-time
//!   backoff (`TransportStats::retries` counts them).
//! * **Duplicates** are idempotently dropped by sequence-number dedup —
//!   a satisfied sequence number is never applied twice.
//! * **Reorders/delays** recover by buffering: panels are matched by
//!   (destination, kind, mode, chunk), not arrival order, and ticks
//!   release held frames before each retry round.
//! * **Corruption** is caught by the frame checksum; the frame is
//!   discarded and recovered like a drop. A corrupt frame is *never*
//!   applied — the factors cannot silently diverge.
//! * **Unrecoverable** conditions — retry budget exhausted, a killed
//!   device, protocol violations — surface as named [`TransportError`]
//!   variants from `train_epoch` (wrapped in
//!   [`AlgoError::Transport`](crate::algo::AlgoError)).
//!
//! All recovery activity is loud: per-epoch counters land in
//! [`PlanAccum`](crate::metrics::PlanAccum)'s transport block and a
//! warning is logged whenever an epoch saw faults.
//!
//! # Async prefetch (ROADMAP item 1)
//!
//! The exchange can be split around the round barrier: [`Exchanger::begin_round`]
//! opens a round and pre-assigns sequence numbers (in spec order, so the
//! numbering is deterministic no matter which worker thread reaches the
//! transport first), [`Exchanger::issue`] hands individual panel payloads
//! to the transport *while the previous round still computes*, and
//! [`Exchanger::collect`] drains at the barrier with the same
//! retry/dedup/backoff machinery the synchronous [`Exchanger::exchange`]
//! uses (and `exchange` is now literally `begin_round` + issue-all +
//! `collect`). In exact mode the **apply** still lands at the barrier, so
//! prefetch moves only the transfer earlier and results stay bitwise.
//! Relaxed mode may instead [`Exchanger::poll`] + [`Exchanger::take_ready`]
//! to apply whatever has arrived and defer stragglers up to a bounded
//! number of rounds ([`PrefetchMode`], `ParallelOptions::staleness`).

use std::collections::HashMap;
use std::collections::HashSet;
use std::collections::VecDeque;

use crate::log_warn;
use crate::util::fnv1a64;
use crate::util::Rng;

/// Which exchange path the parallel engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Harness-controlled: the `FASTTUCKER_TRANSPORT` environment
    /// variable (`direct`/`channel`), else `Direct`.
    Auto,
    /// The historical shared-memory handover: no serialization, no
    /// failure modes. Fault injection cannot engage (configuring a
    /// [`FaultPlan`] under `Direct` is surfaced as a degraded run).
    Direct,
    /// Route every inter-device panel through a framed [`Transport`]
    /// channel ([`InProcTransport`], optionally wrapped in
    /// [`FaultyTransport`]). Exact mode stays bitwise-identical to
    /// `Direct` at every device count.
    Channel,
}

impl TransportKind {
    /// Parse `"auto"`, `"direct"`, or `"channel"` (case-insensitive).
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(TransportKind::Auto),
            "direct" => Some(TransportKind::Direct),
            "channel" => Some(TransportKind::Channel),
            _ => None,
        }
    }

    /// Resolve `Auto` against `FASTTUCKER_TRANSPORT` (same loud-fallback
    /// policy as [`resolve_devices`](super::device::resolve_devices)):
    /// unknown values warn and fall back to `Direct`. Never returns
    /// `Auto`.
    pub fn resolve(self) -> TransportKind {
        match self {
            TransportKind::Direct | TransportKind::Channel => self,
            TransportKind::Auto => match std::env::var("FASTTUCKER_TRANSPORT") {
                Ok(v) => match TransportKind::parse(&v) {
                    Some(TransportKind::Channel) => TransportKind::Channel,
                    Some(_) => TransportKind::Direct,
                    None => {
                        log_warn!(
                            "FASTTUCKER_TRANSPORT={v:?} is not \"direct\"/\"channel\" — \
                             falling back to direct"
                        );
                        TransportKind::Direct
                    }
                },
                Err(_) => TransportKind::Direct,
            },
        }
    }
}

/// Environment variable consulted by [`PrefetchMode::resolve`].
pub const PREFETCH_VAR: &str = "FASTTUCKER_PREFETCH";

/// When boundary panels are handed to the transport relative to the
/// round barrier they are applied at (ROADMAP item 1).
///
/// In exact mode the **apply** always lands at the panel's own round
/// barrier — prefetch moves only the *transfer* earlier (issued during
/// the previous round's compute), so exact results stay bitwise-identical
/// to the synchronous path. Relaxed mode may additionally defer applies
/// up to a bounded number of rounds (`ParallelOptions::staleness`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchMode {
    /// Harness-controlled: the `FASTTUCKER_PREFETCH` environment
    /// variable (`off`/`async`), else `Off`.
    Auto,
    /// Send and apply at the barrier (the PR 7 synchronous exchange).
    Off,
    /// Double-buffered: issue round r+1's outgoing panels while round r
    /// computes; drain and apply at round r+1's barrier. Requires the
    /// channel transport — under `Direct` there is no transfer to
    /// overlap, so the engine warns and degrades to `Off`.
    Async,
}

impl PrefetchMode {
    /// Parse `"auto"`, `"off"`, or `"async"` (case-insensitive).
    pub fn parse(s: &str) -> Option<PrefetchMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PrefetchMode::Auto),
            "off" => Some(PrefetchMode::Off),
            "async" => Some(PrefetchMode::Async),
            _ => None,
        }
    }

    /// Resolve `Auto` against `FASTTUCKER_PREFETCH` (same loud-fallback
    /// policy as [`TransportKind::resolve`]): unknown values warn and
    /// fall back to `Off`. Never returns `Auto`.
    pub fn resolve(self) -> PrefetchMode {
        match self {
            PrefetchMode::Off | PrefetchMode::Async => self,
            PrefetchMode::Auto => match std::env::var(PREFETCH_VAR) {
                Ok(v) => match PrefetchMode::parse(&v) {
                    Some(PrefetchMode::Async) => PrefetchMode::Async,
                    Some(_) => PrefetchMode::Off,
                    None => {
                        log_warn!(
                            "FASTTUCKER_PREFETCH={v:?} is not \"off\"/\"async\" — \
                             falling back to off"
                        );
                        PrefetchMode::Off
                    }
                },
                Err(_) => PrefetchMode::Off,
            },
        }
    }
}

/// Typed transport failures. Every fault class the receive path can
/// detect has a named variant; `Clone + PartialEq + Eq` so the variants
/// can ride inside [`crate::algo::AlgoError`] and be `matches!`-asserted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// A frame that cannot be parsed (bad magic, impossible lengths,
    /// unknown panel kind) or whose header disagrees with the expected
    /// panel geometry.
    Malformed { detail: String },
    /// Frame checksum verification failed (payload or header corrupted
    /// in flight). Best-effort header fields are included for the log.
    ChecksumMismatch { src: usize, dst: usize, seq: u64 },
    /// A frame for a different round barrier than the one in progress
    /// whose sequence number was never satisfied — a protocol violation,
    /// not a stale duplicate (those are deduped silently).
    EpochRoundMismatch {
        expected_epoch: usize,
        expected_round: usize,
        epoch: usize,
        round: usize,
        seq: u64,
    },
    /// A structurally valid frame that matches no panel this barrier
    /// expects.
    UnexpectedPanel { dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// The retry budget was exhausted with panels still missing.
    Timeout { missing: usize, attempts: usize },
    /// A device stopped sending and acknowledging permanently (the
    /// elastic-recovery trigger: reload the checkpoint, re-shard, resume).
    DeviceDead { device: usize },
    /// A `FASTTUCKER_FAULT_*` environment variable failed validation.
    InvalidFaultEnv { var: String, value: String, reason: String },
    /// A panel header field too large for the 32-bit wire format —
    /// caught at encode time, before a silently wrapped value could
    /// corrupt routing (ISSUE 8 bugfix; previously a bare `as u32`).
    FrameOverflow { field: &'static str, value: usize },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Malformed { detail } => {
                write!(f, "malformed transport frame: {detail}")
            }
            TransportError::ChecksumMismatch { src, dst, seq } => write!(
                f,
                "transport frame checksum mismatch (src device {src}, dst device {dst}, \
                 seq {seq}): frame discarded"
            ),
            TransportError::EpochRoundMismatch {
                expected_epoch,
                expected_round,
                epoch,
                round,
                seq,
            } => write!(
                f,
                "transport frame for epoch {epoch} round {round} (seq {seq}) arrived at \
                 the epoch {expected_epoch} round {expected_round} barrier and was never \
                 satisfied — protocol violation"
            ),
            TransportError::UnexpectedPanel { dst, mode, chunk, seq } => write!(
                f,
                "transport frame (dst device {dst}, mode {mode}, chunk {chunk}, seq {seq}) \
                 matches no panel expected at this barrier"
            ),
            TransportError::Timeout { missing, attempts } => write!(
                f,
                "transport exchange timed out: {missing} panel(s) still missing after \
                 {attempts} attempts"
            ),
            TransportError::DeviceDead { device } => write!(
                f,
                "device {device} is unreachable (no frames after retry budget) — \
                 reload the last checkpoint into a re-sharded engine to resume"
            ),
            TransportError::InvalidFaultEnv { var, value, reason } => {
                write!(f, "{var}={value:?} is invalid: {reason}")
            }
            TransportError::FrameOverflow { field, value } => write!(
                f,
                "transport frame field {field}={value} exceeds the u32 wire format — \
                 refusing to encode a silently wrapped header"
            ),
        }
    }
}

impl std::error::Error for TransportError {}

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelKind {
    /// A contiguous factor-row panel (`n_rows` rows of mode `mode`,
    /// starting at `row_start`) changing device ownership at a round
    /// boundary.
    Rows,
    /// One worker's per-epoch Eq. 17 core-gradient panel (`chunk` holds
    /// the worker id), shipped to the root device for the merge.
    CoreGrad,
}

/// Frame magic: "FTXM" (FastTucker eXchange Message).
pub const FRAME_MAGIC: [u8; 4] = *b"FTXM";
/// Fixed header length in bytes (before the payload).
pub const FRAME_HEADER_LEN: usize = 53;

/// One exchange message: header + opaque payload + trailing checksum.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub epoch: u32,
    pub round: u32,
    pub src: u32,
    pub dst: u32,
    pub kind: PanelKind,
    pub mode: u32,
    pub chunk: u32,
    pub row_start: u32,
    pub n_rows: u32,
    pub seq: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Serialize: `magic | header fields | payload | fnv1a64 checksum`
    /// (checksum over every preceding byte, little-endian throughout —
    /// the same hand-rolled idiom as [`crate::model::checkpoint`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(FRAME_HEADER_LEN + self.payload.len() + 8);
        out.extend_from_slice(&FRAME_MAGIC);
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.dst.to_le_bytes());
        out.push(match self.kind {
            PanelKind::Rows => 0,
            PanelKind::CoreGrad => 1,
        });
        out.extend_from_slice(&self.mode.to_le_bytes());
        out.extend_from_slice(&self.chunk.to_le_bytes());
        out.extend_from_slice(&self.row_start.to_le_bytes());
        out.extend_from_slice(&self.n_rows.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u64).to_le_bytes());
        debug_assert_eq!(out.len(), FRAME_HEADER_LEN);
        out.extend_from_slice(&self.payload);
        let checksum = fnv1a64(&out);
        out.extend_from_slice(&checksum.to_le_bytes());
        out
    }

    /// Parse and validate a frame. Checksum failure and structural
    /// damage come back as named errors; the caller decides whether to
    /// recover (discard + retry) or abort.
    pub fn decode(bytes: &[u8]) -> Result<Frame, TransportError> {
        let malformed = |detail: String| TransportError::Malformed { detail };
        if bytes.len() < FRAME_HEADER_LEN + 8 {
            return Err(malformed(format!(
                "{} bytes, need at least {}",
                bytes.len(),
                FRAME_HEADER_LEN + 8
            )));
        }
        if bytes[0..4] != FRAME_MAGIC {
            return Err(malformed(format!("bad magic {:?}", &bytes[0..4])));
        }
        let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        let src = u32_at(12) as usize;
        let dst = u32_at(16) as usize;
        let seq = u64_at(37);
        let payload_len = u64_at(45) as usize;
        if bytes.len() != FRAME_HEADER_LEN + payload_len + 8 {
            return Err(malformed(format!(
                "payload length {} disagrees with frame size {}",
                payload_len,
                bytes.len()
            )));
        }
        let stored = u64_at(bytes.len() - 8);
        if fnv1a64(&bytes[..bytes.len() - 8]) != stored {
            return Err(TransportError::ChecksumMismatch { src, dst, seq });
        }
        let kind = match bytes[20] {
            0 => PanelKind::Rows,
            1 => PanelKind::CoreGrad,
            k => return Err(malformed(format!("unknown panel kind {k}"))),
        };
        Ok(Frame {
            epoch: u32_at(4),
            round: u32_at(8),
            src: src as u32,
            dst: dst as u32,
            kind,
            mode: u32_at(21),
            chunk: u32_at(25),
            row_start: u32_at(29),
            n_rows: u32_at(33),
            seq,
            payload: bytes[FRAME_HEADER_LEN..bytes.len() - 8].to_vec(),
        })
    }

    /// Best-effort source-device peek on raw frame bytes (used by the
    /// fault injector's kill filter without a full decode).
    pub fn peek_src(bytes: &[u8]) -> Option<usize> {
        if bytes.len() < FRAME_HEADER_LEN || bytes[0..4] != FRAME_MAGIC {
            return None;
        }
        Some(u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize)
    }
}

/// Moves opaque frame bytes between device mailboxes.
///
/// Deterministic, non-blocking semantics: `send` enqueues (or loses —
/// the caller cannot tell), `recv` dequeues or reports an empty mailbox,
/// and `tick` advances *virtual* time, releasing any frames an
/// implementation is holding (delays, reorders). There are no wall-clock
/// timeouts anywhere — the [`Exchanger`] counts attempts instead, which
/// keeps every fault scenario fast and bit-reproducible.
pub trait Transport {
    /// Number of device mailboxes.
    fn devices(&self) -> usize;
    /// Enqueue `bytes` for device `dst`. An `Err` is an immediate local
    /// failure (bad destination); silent loss is allowed and is what
    /// retries exist for.
    fn send(&mut self, dst: usize, bytes: Vec<u8>) -> Result<(), TransportError>;
    /// Dequeue the next frame for device `dst`, if any.
    fn recv(&mut self, dst: usize) -> Option<Vec<u8>>;
    /// Advance virtual time one step, releasing held frames.
    fn tick(&mut self);
    /// A device known to have failed permanently, if any — lets the
    /// exchanger distinguish [`TransportError::DeviceDead`] from a plain
    /// [`TransportError::Timeout`] when the retry budget runs out.
    fn failed_device(&self) -> Option<usize> {
        None
    }
}

/// The bitwise oracle: per-device FIFO mailboxes, no loss, no delay.
pub struct InProcTransport {
    boxes: Vec<VecDeque<Vec<u8>>>,
}

impl InProcTransport {
    pub fn new(devices: usize) -> InProcTransport {
        assert!(devices >= 1);
        InProcTransport { boxes: (0..devices).map(|_| VecDeque::new()).collect() }
    }
}

impl Transport for InProcTransport {
    fn devices(&self) -> usize {
        self.boxes.len()
    }

    fn send(&mut self, dst: usize, bytes: Vec<u8>) -> Result<(), TransportError> {
        match self.boxes.get_mut(dst) {
            Some(q) => {
                q.push_back(bytes);
                Ok(())
            }
            None => Err(TransportError::Malformed {
                detail: format!("send to device {dst} of {}", self.boxes.len()),
            }),
        }
    }

    fn recv(&mut self, dst: usize) -> Option<Vec<u8>> {
        self.boxes.get_mut(dst)?.pop_front()
    }

    fn tick(&mut self) {}
}

/// One injectable fault class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Frame silently lost.
    Drop,
    /// Frame delivered twice.
    Duplicate,
    /// Frame held back and delivered after a later frame to the same
    /// destination (a true inversion), or on the next tick.
    Reorder,
    /// One payload bit flipped; the stale checksum makes it detectable.
    Corrupt,
    /// Frame held until the next tick.
    Delay,
}

const ALL_FAULT_KINDS: [FaultKind; 5] = [
    FaultKind::Drop,
    FaultKind::Duplicate,
    FaultKind::Reorder,
    FaultKind::Corrupt,
    FaultKind::Delay,
];

impl FaultKind {
    pub fn parse(s: &str) -> Option<FaultKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "drop" => Some(FaultKind::Drop),
            "duplicate" | "dup" => Some(FaultKind::Duplicate),
            "reorder" => Some(FaultKind::Reorder),
            "corrupt" => Some(FaultKind::Corrupt),
            "delay" => Some(FaultKind::Delay),
            _ => None,
        }
    }
}

/// A `Copy` set of fault classes (bitmask), so a [`FaultPlan`] can live
/// inside the `Copy` engine options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultKinds(u8);

impl FaultKinds {
    pub const NONE: FaultKinds = FaultKinds(0);
    pub const ALL: FaultKinds = FaultKinds(0b1_1111);

    fn bit(kind: FaultKind) -> u8 {
        1 << (kind as usize)
    }

    pub fn single(kind: FaultKind) -> FaultKinds {
        FaultKinds(Self::bit(kind))
    }

    pub fn of(kinds: &[FaultKind]) -> FaultKinds {
        FaultKinds(kinds.iter().fold(0, |acc, &k| acc | Self::bit(k)))
    }

    pub fn contains(self, kind: FaultKind) -> bool {
        self.0 & Self::bit(kind) != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The contained kinds in declaration order (deterministic).
    pub fn list(self) -> Vec<FaultKind> {
        ALL_FAULT_KINDS.iter().copied().filter(|&k| self.contains(k)).collect()
    }

    /// Parse a comma-separated kind list, e.g. `"drop,duplicate"`.
    pub fn parse(s: &str) -> Option<FaultKinds> {
        let mut kinds = FaultKinds::NONE;
        for part in s.split(',') {
            if part.trim().is_empty() {
                return None;
            }
            kinds.0 |= Self::bit(FaultKind::parse(part)?);
        }
        if kinds.is_empty() {
            None
        } else {
            Some(kinds)
        }
    }
}

/// Kill device `device` permanently once the transport has carried
/// `after_sends` frames: from then on every frame to or from it is lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub device: usize,
    pub after_sends: u64,
}

/// Deterministic fault-injection plan for [`FaultyTransport`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the injector's own [`Rng`] stream (independent of the
    /// training streams — injection never perturbs the model math).
    pub seed: u64,
    /// Per-send probability of injecting one fault from `kinds`.
    pub rate: f32,
    /// Which fault classes may fire.
    pub kinds: FaultKinds,
    /// Optional permanent device failure.
    pub kill: Option<KillSpec>,
}

pub const FAULT_SEED_VAR: &str = "FASTTUCKER_FAULT_SEED";
pub const FAULT_RATE_VAR: &str = "FASTTUCKER_FAULT_RATE";
pub const FAULT_KINDS_VAR: &str = "FASTTUCKER_FAULT_KINDS";

impl FaultPlan {
    /// Build a plan from the `FASTTUCKER_FAULT_{SEED,RATE,KINDS}`
    /// environment variables. `Ok(None)` when none are set; malformed
    /// values are **loud** typed errors (the PR 4 bench-env policy), not
    /// silent defaults.
    pub fn from_env() -> Result<Option<FaultPlan>, TransportError> {
        let seed = env_value(FAULT_SEED_VAR, std::env::var_os(FAULT_SEED_VAR))?;
        let rate = env_value(FAULT_RATE_VAR, std::env::var_os(FAULT_RATE_VAR))?;
        let kinds = env_value(FAULT_KINDS_VAR, std::env::var_os(FAULT_KINDS_VAR))?;
        FaultPlan::from_vars(seed.as_deref(), rate.as_deref(), kinds.as_deref())
    }

    /// The pure parser behind [`Self::from_env`] (testable without
    /// touching process-global environment state).
    pub fn from_vars(
        seed: Option<&str>,
        rate: Option<&str>,
        kinds: Option<&str>,
    ) -> Result<Option<FaultPlan>, TransportError> {
        if seed.is_none() && rate.is_none() && kinds.is_none() {
            return Ok(None);
        }
        let seed_v = match seed {
            None => 0x5EED,
            Some(s) => s.trim().parse::<u64>().map_err(|_| {
                TransportError::InvalidFaultEnv {
                    var: FAULT_SEED_VAR.into(),
                    value: s.into(),
                    reason: "expected an unsigned integer".into(),
                }
            })?,
        };
        let rate_v = match rate {
            None => 0.05,
            Some(s) => {
                let r = s.trim().parse::<f32>().map_err(|_| {
                    TransportError::InvalidFaultEnv {
                        var: FAULT_RATE_VAR.into(),
                        value: s.into(),
                        reason: "expected a float".into(),
                    }
                })?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(TransportError::InvalidFaultEnv {
                        var: FAULT_RATE_VAR.into(),
                        value: s.into(),
                        reason: "must lie in [0, 1]".into(),
                    });
                }
                r
            }
        };
        let kinds_v = match kinds {
            None => FaultKinds::ALL,
            Some(s) => FaultKinds::parse(s).ok_or_else(|| TransportError::InvalidFaultEnv {
                var: FAULT_KINDS_VAR.into(),
                value: s.into(),
                reason: "expected a comma-separated subset of \
                         drop,duplicate,reorder,corrupt,delay"
                    .into(),
            })?,
        };
        Ok(Some(FaultPlan { seed: seed_v, rate: rate_v, kinds: kinds_v, kill: None }))
    }
}

/// Interpret one raw environment value **loudly**: a set-but-non-unicode
/// value is a typed error, never a silent "unset". (ISSUE 8 bugfix: the
/// old `env::var(..).ok()` collapsed `VarError::NotUnicode` into `None`,
/// silently disabling a configured fault plan.) Pure over the raw
/// [`OsString`](std::ffi::OsString) so the failure path is unit-testable
/// without mutating process-global environment state.
fn env_value(
    var: &str,
    raw: Option<std::ffi::OsString>,
) -> Result<Option<String>, TransportError> {
    match raw {
        None => Ok(None),
        Some(os) => match os.into_string() {
            Ok(s) => Ok(Some(s)),
            Err(os) => Err(TransportError::InvalidFaultEnv {
                var: var.into(),
                value: os.to_string_lossy().into_owned(),
                reason: "value is not valid unicode".into(),
            }),
        },
    }
}

/// Seeded fault injector around the in-process oracle. Every decision
/// comes from its own deterministic [`Rng`] stream, so a (plan, traffic)
/// pair always produces the same fault sequence — the fault-matrix
/// property test depends on this.
pub struct FaultyTransport {
    inner: InProcTransport,
    plan: FaultPlan,
    kind_list: Vec<FaultKind>,
    rng: Rng,
    /// Frames held for a later-arrival inversion: flushed after the next
    /// send to the same destination, or on `tick`.
    held_reorder: Vec<(usize, Vec<u8>)>,
    /// Frames held until the next `tick`.
    held_delay: Vec<(usize, Vec<u8>)>,
    sends: u64,
    dead: Option<usize>,
}

impl FaultyTransport {
    pub fn new(inner: InProcTransport, plan: FaultPlan) -> FaultyTransport {
        FaultyTransport {
            inner,
            plan,
            kind_list: plan.kinds.list(),
            rng: Rng::new(plan.seed),
            held_reorder: Vec::new(),
            held_delay: Vec::new(),
            sends: 0,
            dead: None,
        }
    }

    fn flush_reorders_for(&mut self, dst: usize) {
        let mut i = 0;
        while i < self.held_reorder.len() {
            if self.held_reorder[i].0 == dst {
                let (d, bytes) = self.held_reorder.remove(i);
                let _ = self.inner.send(d, bytes);
            } else {
                i += 1;
            }
        }
    }
}

impl Transport for FaultyTransport {
    fn devices(&self) -> usize {
        self.inner.devices()
    }

    fn send(&mut self, dst: usize, mut bytes: Vec<u8>) -> Result<(), TransportError> {
        self.sends += 1;
        if self.dead.is_none() {
            if let Some(kill) = self.plan.kill {
                if self.sends > kill.after_sends {
                    log_warn!(
                        "fault injection: killing device {} after {} sends",
                        kill.device,
                        self.sends - 1
                    );
                    self.dead = Some(kill.device);
                }
            }
        }
        if let Some(dead) = self.dead {
            // A dead device neither sends nor receives: lose the frame.
            if dst == dead || Frame::peek_src(&bytes) == Some(dead) {
                return Ok(());
            }
        }
        let fault = if !self.kind_list.is_empty() && self.rng.uniform() < self.plan.rate {
            Some(self.kind_list[self.rng.gen_range(self.kind_list.len())])
        } else {
            None
        };
        match fault {
            Some(FaultKind::Drop) => Ok(()),
            Some(FaultKind::Duplicate) => {
                self.inner.send(dst, bytes.clone())?;
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
            Some(FaultKind::Reorder) => {
                self.held_reorder.push((dst, bytes));
                Ok(())
            }
            Some(FaultKind::Corrupt) => {
                // Flip one bit in the payload (or, for an empty payload,
                // the trailing checksum) — the header stays parseable and
                // the checksum check must catch the damage.
                let lo = FRAME_HEADER_LEN.min(bytes.len().saturating_sub(8));
                let hi = bytes.len();
                let idx = lo + self.rng.gen_range(hi - lo);
                bytes[idx] ^= 1 << self.rng.gen_range(8);
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
            Some(FaultKind::Delay) => {
                self.held_delay.push((dst, bytes));
                Ok(())
            }
            None => {
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
        }
    }

    fn recv(&mut self, dst: usize) -> Option<Vec<u8>> {
        self.inner.recv(dst)
    }

    fn tick(&mut self) {
        for (dst, bytes) in self.held_reorder.drain(..).chain(self.held_delay.drain(..)) {
            if Some(dst) != self.dead {
                let _ = self.inner.send(dst, bytes);
            }
        }
        self.inner.tick();
    }

    fn failed_device(&self) -> Option<usize> {
        self.dead
    }
}

/// Bounded-retry policy for the exchange protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum drain/resend attempts per barrier before the exchange
    /// fails ([`TransportError::Timeout`] / [`TransportError::DeviceDead`]).
    pub max_attempts: usize,
    /// Virtual-time ticks before attempt 1's resend; doubles each
    /// attempt (capped) — exponential backoff in tick units.
    pub backoff_base: usize,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 5, backoff_base: 1 }
    }
}

/// Recovery/fault counters for one stretch of exchanges (drained into
/// [`PlanAccum`](crate::metrics::PlanAccum) per epoch).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames handed to the transport (first sends + resends).
    pub frames_sent: u64,
    /// Serialized bytes handed to the transport.
    pub bytes_sent: u64,
    /// Frames that arrived, validated, and filled an expected panel.
    pub frames_delivered: u64,
    /// Resent frames (missing after a timeout + backoff window).
    pub retries: u64,
    /// Frames discarded by sequence-number dedup.
    pub duplicates_dropped: u64,
    /// Frames discarded for checksum/framing damage.
    pub checksum_failures: u64,
    /// In-order violations observed (a frame arriving after a
    /// higher-sequence frame to the same destination).
    pub reorders: u64,
    /// Drain attempts that found panels still missing.
    pub timeouts: u64,
}

impl TransportStats {
    /// Total detected fault events (anything a healthy exchange would
    /// not produce).
    pub fn faults_detected(&self) -> u64 {
        self.retries + self.duplicates_dropped + self.checksum_failures + self.reorders
            + self.timeouts
    }
}

/// The geometry of one panel the caller wants moved at a barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PanelSpec {
    pub kind: PanelKind,
    pub src_dev: usize,
    pub dst_dev: usize,
    /// Factor mode for `Rows` panels; 0 for `CoreGrad`.
    pub mode: usize,
    /// Chunk index for `Rows` panels; the worker id for `CoreGrad`.
    pub chunk: usize,
    pub row_start: usize,
    pub n_rows: usize,
}

/// Plain-data record of exchange activity, consumed by
/// [`crate::analysis::audit_exchange`] — the auditor's view of messages
/// in transit. One barrier's window runs from `BarrierStart` to
/// `ComputeStart`; in exact mode every delivered panel's *apply* must
/// land inside its own window, exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeEvent {
    /// The coordinator opened round `round`'s exchange window.
    BarrierStart { epoch: usize, round: usize },
    /// A panel frame was handed to the transport.
    Sent { epoch: usize, round: usize, src: usize, dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// A panel frame arrived, validated, and was accepted.
    Delivered {
        epoch: usize,
        round: usize,
        src: usize,
        dst: usize,
        mode: usize,
        chunk: usize,
        seq: u64,
    },
    /// The panel's bytes were written back into the factors/core-merge.
    Applied { epoch: usize, round: usize, dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// The coordinator closed the window and released the workers.
    ComputeStart { epoch: usize, round: usize },
}

/// Default per-destination dedup-window size: the number of delivered
/// sequence numbers retained for idempotent duplicate dropping.
pub const DEDUP_WINDOW: usize = 8192;

/// Checked narrowing into the u32 wire header (ISSUE 8 bugfix: a bare
/// `as u32` silently wrapped large dims / long runs into valid-looking
/// but wrongly routed frames).
fn frame_u32(field: &'static str, value: usize) -> Result<u32, TransportError> {
    u32::try_from(value).map_err(|_| TransportError::FrameOverflow { field, value })
}

/// Opaque handle to one in-flight round exchange opened by
/// [`Exchanger::begin_round`]. Single-use: [`Exchanger::collect`]
/// consumes the round's in-flight state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundToken(u64);

/// One round barrier's in-flight state: specs, pre-built frames with
/// pre-assigned sequence numbers, and per-slot delivery status.
struct PendingRound {
    token: u64,
    epoch: usize,
    round: usize,
    specs: Vec<PanelSpec>,
    /// One frame per slot. Headers are built — and seqs assigned — at
    /// [`Exchanger::begin_round`] in spec order, so the numbering is
    /// deterministic no matter which worker thread issues first; the
    /// payload is attached at [`Exchanger::issue`] and kept for resends
    /// and geometry validation.
    frames: Vec<Frame>,
    issued: Vec<bool>,
    got: Vec<Option<Vec<u8>>>,
    delivered_seq: Vec<u64>,
    /// Slots already handed out by [`Exchanger::take_ready`].
    taken: Vec<bool>,
    barrier_opened: bool,
    /// Highest seq seen per (dst, src) pair this round. Prefetch issues
    /// from different workers interleave nondeterministically across
    /// sources, but each source issues in increasing-seq order, so only
    /// a per-(dst, src) inversion is a genuine transport reorder.
    last_seq: HashMap<(usize, usize), u64>,
}

impl PendingRound {
    /// Slots neither delivered nor already handed out.
    fn missing(&self) -> usize {
        self.got
            .iter()
            .zip(&self.taken)
            .filter(|(g, &t)| g.is_none() && !t)
            .count()
    }
}

/// The exchange protocol driver: owns the transport, global sequence
/// numbering, per-destination dedup state, retry policy, counters, the
/// in-flight round set, and the optional audit event log.
pub struct Exchanger {
    transport: Box<dyn Transport + Send>,
    policy: RetryPolicy,
    next_seq: u64,
    /// Per-destination sets of **delivered** sequence numbers — late and
    /// duplicate arrivals of these are dropped idempotently, even across
    /// barriers (a delayed frame can surface rounds later). Bounded by
    /// `dedup_window` via `floor`.
    satisfied: Vec<HashSet<u64>>,
    /// Per-destination dedup floor: seqs below it were pruned from
    /// `satisfied`, and any arrival below it is dropped as a stale
    /// duplicate — never re-applied, never a protocol error.
    floor: Vec<u64>,
    /// Highest delivered seq per destination. The dedup window is keyed
    /// on what each receiver has actually seen, not the sender-side
    /// `next_seq` (ISSUE 8 bugfix: the old prune floored at
    /// `next_seq - 4096` over one global set, so under heavy
    /// reorder+duplicate plans a late duplicate below the floor stopped
    /// being recognized as a duplicate at all).
    delivered_high: Vec<u64>,
    /// Max retained `satisfied` entries per destination.
    dedup_window: usize,
    stats: TransportStats,
    events: Vec<ExchangeEvent>,
    record_events: bool,
    /// Rounds opened by [`Self::begin_round`] and not yet collected —
    /// under async prefetch, up to staleness-bound + 1 rounds at once.
    pending: Vec<PendingRound>,
    next_token: u64,
}

impl Exchanger {
    /// A channel exchanger over `devices` mailboxes; with a [`FaultPlan`]
    /// the oracle is wrapped in the seeded injector.
    pub fn new(devices: usize, fault: Option<FaultPlan>) -> Exchanger {
        let transport: Box<dyn Transport + Send> = match fault {
            Some(plan) => Box::new(FaultyTransport::new(InProcTransport::new(devices), plan)),
            None => Box::new(InProcTransport::new(devices)),
        };
        Exchanger::with_transport(transport)
    }

    /// An exchanger over an arbitrary [`Transport`] (tests inject
    /// capturing/replaying transports here; the multi-process backends —
    /// Unix socket, TCP — will plug in the same way).
    pub fn with_transport(transport: Box<dyn Transport + Send>) -> Exchanger {
        let devices = transport.devices();
        Exchanger {
            transport,
            policy: RetryPolicy::default(),
            next_seq: 0,
            satisfied: vec![HashSet::new(); devices],
            floor: vec![0; devices],
            delivered_high: vec![0; devices],
            dedup_window: DEDUP_WINDOW,
            stats: TransportStats::default(),
            events: Vec::new(),
            record_events: false,
            pending: Vec::new(),
            next_token: 0,
        }
    }

    pub fn set_policy(&mut self, policy: RetryPolicy) {
        self.policy = policy;
    }

    /// Shrink the per-destination dedup window (a test knob: the soak
    /// and regression tests cross the prune threshold without shipping
    /// thousands of real frames first).
    pub fn set_dedup_window(&mut self, window: usize) {
        self.dedup_window = window.max(2);
    }

    /// Record [`ExchangeEvent`]s for the in-flight-exchange auditor.
    pub fn enable_event_log(&mut self) {
        self.record_events = true;
    }

    pub fn events(&self) -> &[ExchangeEvent] {
        &self.events
    }

    pub fn clear_events(&mut self) {
        self.events.clear();
    }

    /// Drain and reset the recovery counters (one epoch's block).
    pub fn drain_stats(&mut self) -> TransportStats {
        std::mem::take(&mut self.stats)
    }

    /// Log a panel's write-back (the *apply* the auditor checks lands at
    /// the barrier).
    pub fn note_applied(&mut self, epoch: usize, round: usize, spec: &PanelSpec, seq: u64) {
        if self.record_events {
            self.events.push(ExchangeEvent::Applied {
                epoch,
                round,
                dst: spec.dst_dev,
                mode: spec.mode,
                chunk: spec.chunk,
                seq,
            });
        }
    }

    /// Log the end of a barrier's exchange window.
    pub fn note_compute_start(&mut self, epoch: usize, round: usize) {
        if self.record_events {
            self.events.push(ExchangeEvent::ComputeStart { epoch, round });
        }
    }

    /// Execute one barrier's exchange synchronously: open the window,
    /// send every panel, then drain/validate with dedup + reorder
    /// buffering and bounded resend-with-backoff. Returns each panel's
    /// payload with its sequence number, in the caller's panel order
    /// (deterministic). Literally [`Self::begin_round`] + issue-all +
    /// [`Self::collect`] with nothing prefetched.
    pub fn exchange(
        &mut self,
        epoch: usize,
        round: usize,
        panels: &[(PanelSpec, Vec<u8>)],
    ) -> Result<Vec<(PanelSpec, Vec<u8>, u64)>, TransportError> {
        if panels.is_empty() {
            return Ok(Vec::new());
        }
        let specs: Vec<PanelSpec> = panels.iter().map(|(s, _)| *s).collect();
        let token = self.begin_round(epoch, round, &specs)?;
        self.open_barrier(token)?;
        for (idx, (_, payload)) in panels.iter().enumerate() {
            self.issue(token, idx, payload.clone())?;
        }
        Ok(self
            .collect(token)?
            .into_iter()
            .map(|(_, spec, payload, seq)| (spec, payload, seq))
            .collect())
    }

    /// Open round `round`'s exchange: validate every header field
    /// against the wire format and pre-build every frame, assigning
    /// sequence numbers in spec order. Payloads are attached later by
    /// [`Self::issue`]; the round drains at [`Self::collect`] (or
    /// incrementally via [`Self::poll`] + [`Self::take_ready`]).
    pub fn begin_round(
        &mut self,
        epoch: usize,
        round: usize,
        specs: &[PanelSpec],
    ) -> Result<RoundToken, TransportError> {
        let mut frames = Vec::with_capacity(specs.len());
        for spec in specs {
            let seq = self.next_seq;
            self.next_seq += 1;
            frames.push(Frame {
                epoch: frame_u32("epoch", epoch)?,
                round: frame_u32("round", round)?,
                src: frame_u32("src_dev", spec.src_dev)?,
                dst: frame_u32("dst_dev", spec.dst_dev)?,
                kind: spec.kind,
                mode: frame_u32("mode", spec.mode)?,
                chunk: frame_u32("chunk", spec.chunk)?,
                row_start: frame_u32("row_start", spec.row_start)?,
                n_rows: frame_u32("n_rows", spec.n_rows)?,
                seq,
                payload: Vec::new(),
            });
        }
        let token = self.next_token;
        self.next_token += 1;
        let n = specs.len();
        self.pending.push(PendingRound {
            token,
            epoch,
            round,
            specs: specs.to_vec(),
            frames,
            issued: vec![false; n],
            got: vec![None; n],
            delivered_seq: vec![0; n],
            taken: vec![false; n],
            barrier_opened: false,
            last_seq: HashMap::new(),
        });
        Ok(RoundToken(token))
    }

    /// Hand slot `idx`'s payload to the transport. Under async prefetch
    /// this runs *during* the previous round's compute, as soon as the
    /// owning worker's pass has finalized the rows it ships.
    pub fn issue(
        &mut self,
        token: RoundToken,
        idx: usize,
        payload: Vec<u8>,
    ) -> Result<(), TransportError> {
        let i = self.pending_pos(token)?;
        let p = &mut self.pending[i];
        assert!(!p.issued[idx], "exchange slot {idx} issued twice");
        p.frames[idx].payload = payload;
        p.issued[idx] = true;
        let (epoch, round) = (p.epoch, p.round);
        let f = p.frames[idx].clone();
        self.send_frame(&f, epoch, round)
    }

    /// Emit the round's `BarrierStart` audit event (idempotent). The
    /// synchronous [`Self::exchange`] opens the window before its sends;
    /// the async path opens it when the coordinator reaches the barrier
    /// ([`Self::collect`] / [`Self::take_ready`] open it implicitly).
    pub fn open_barrier(&mut self, token: RoundToken) -> Result<(), TransportError> {
        let i = self.pending_pos(token)?;
        let p = &mut self.pending[i];
        if !p.barrier_opened {
            p.barrier_opened = true;
            let (epoch, round) = (p.epoch, p.round);
            if self.record_events {
                self.events.push(ExchangeEvent::BarrierStart { epoch, round });
            }
        }
        Ok(())
    }

    /// Drain whatever has already arrived — no retries, no backoff, no
    /// blocking. The relaxed bounded-staleness path calls this at each
    /// barrier before deciding what it can apply.
    pub fn poll(&mut self) -> Result<(), TransportError> {
        self.drain_all()
    }

    /// Hand out every slot of `token`'s round that has arrived and was
    /// not handed out before, as `(slot index, spec, payload, seq)`.
    /// Leaves the round in flight (stragglers keep draining); pair with
    /// [`Self::collect`] to force completion at the staleness bound.
    pub fn take_ready(
        &mut self,
        token: RoundToken,
    ) -> Result<Vec<(usize, PanelSpec, Vec<u8>, u64)>, TransportError> {
        self.open_barrier(token)?;
        let i = self.pending_pos(token)?;
        let p = &mut self.pending[i];
        let mut out = Vec::new();
        for idx in 0..p.specs.len() {
            if p.taken[idx] || p.got[idx].is_none() {
                continue;
            }
            p.taken[idx] = true;
            out.push((idx, p.specs[idx], p.got[idx].take().unwrap(), p.delivered_seq[idx]));
        }
        Ok(out)
    }

    /// Drain until every slot of `token`'s round has arrived, with the
    /// same bounded resend-with-backoff the synchronous exchange always
    /// used; emit the round's `BarrierStart` if the async path has not
    /// already, retire the round from the in-flight set, and return
    /// every slot not previously handed out by [`Self::take_ready`], in
    /// spec order.
    pub fn collect(
        &mut self,
        token: RoundToken,
    ) -> Result<Vec<(usize, PanelSpec, Vec<u8>, u64)>, TransportError> {
        let i = self.pending_pos(token)?;
        if self.pending[i].specs.is_empty() {
            self.pending.remove(i);
            return Ok(Vec::new());
        }
        self.open_barrier(token)?;
        self.drain_all()?;
        let mut attempt = 0usize;
        while self.pending[self.pending_pos(token)?].missing() > 0 {
            attempt += 1;
            if attempt > self.policy.max_attempts {
                let missing = self.pending[self.pending_pos(token)?].missing();
                if let Some(device) = self.transport.failed_device() {
                    return Err(TransportError::DeviceDead { device });
                }
                return Err(TransportError::Timeout { missing, attempts: attempt - 1 });
            }
            self.stats.timeouts += 1;
            // Exponential backoff in virtual time: each tick lets the
            // transport release delayed/held frames.
            let ticks = self.policy.backoff_base << (attempt - 1).min(6);
            for _ in 0..ticks {
                self.transport.tick();
            }
            self.drain_all()?;
            let pi = self.pending_pos(token)?;
            if self.pending[pi].missing() == 0 {
                break;
            }
            // Still missing after the release window: resend the issued
            // stragglers (idempotent — the receiver matches panels by
            // slot and dedups by seq).
            let (epoch, round) = (self.pending[pi].epoch, self.pending[pi].round);
            let resend: Vec<Frame> = {
                let p = &self.pending[pi];
                (0..p.frames.len())
                    .filter(|&idx| p.issued[idx] && p.got[idx].is_none() && !p.taken[idx])
                    .map(|idx| p.frames[idx].clone())
                    .collect()
            };
            for f in &resend {
                self.stats.retries += 1;
                self.send_frame(f, epoch, round)?;
            }
            self.drain_all()?;
        }
        let i = self.pending_pos(token)?;
        let p = self.pending.remove(i);
        let mut out = Vec::new();
        for (idx, ((spec, got), (seq, taken))) in p
            .specs
            .iter()
            .zip(p.got)
            .zip(p.delivered_seq.iter().zip(p.taken))
            .enumerate()
        {
            if taken {
                continue;
            }
            out.push((idx, *spec, got.expect("complete round has every slot"), *seq));
        }
        Ok(out)
    }

    fn pending_pos(&self, token: RoundToken) -> Result<usize, TransportError> {
        self.pending.iter().position(|p| p.token == token.0).ok_or_else(|| {
            TransportError::Malformed {
                detail: format!("round token {} is not in flight", token.0),
            }
        })
    }

    fn send_frame(&mut self, f: &Frame, epoch: usize, round: usize) -> Result<(), TransportError> {
        let bytes = f.encode();
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += bytes.len() as u64;
        self.transport.send(f.dst as usize, bytes)?;
        if self.record_events {
            self.events.push(ExchangeEvent::Sent {
                epoch,
                round,
                src: f.src as usize,
                dst: f.dst as usize,
                mode: f.mode as usize,
                chunk: f.chunk as usize,
                seq: f.seq,
            });
        }
        Ok(())
    }

    /// Empty every mailbox, validating, deduping, and routing frames to
    /// their in-flight rounds (under async prefetch several rounds are
    /// open at once). Damaged frames are discarded (recovered by
    /// resend); protocol violations abort.
    fn drain_all(&mut self) -> Result<(), TransportError> {
        let Exchanger {
            transport,
            pending,
            satisfied,
            floor,
            delivered_high,
            dedup_window,
            stats,
            events,
            record_events,
            ..
        } = self;
        for dst in 0..transport.devices() {
            while let Some(bytes) = transport.recv(dst) {
                let frame = match Frame::decode(&bytes) {
                    Ok(f) => f,
                    Err(e @ (TransportError::ChecksumMismatch { .. }
                    | TransportError::Malformed { .. })) => {
                        stats.checksum_failures += 1;
                        log_warn!("transport: discarding damaged frame ({e})");
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                // Below-floor arrivals are stale duplicates whose seqs
                // were pruned from the window: dropped before any
                // routing (ISSUE 8 bugfix — the old single-round drain
                // could only hard-error on them).
                if frame.seq < floor[dst] {
                    stats.duplicates_dropped += 1;
                    continue;
                }
                // Idempotent dedup: duplicates and stale late arrivals
                // of already-satisfied panels are dropped, never applied.
                if satisfied[dst].contains(&frame.seq) {
                    stats.duplicates_dropped += 1;
                    continue;
                }
                // Route to the in-flight round carrying this barrier.
                let Some(pi) = pending
                    .iter()
                    .position(|p| p.epoch == frame.epoch as usize && p.round == frame.round as usize)
                else {
                    let (ee, er) =
                        pending.iter().map(|p| (p.epoch, p.round)).min().unwrap_or((0, 0));
                    return Err(TransportError::EpochRoundMismatch {
                        expected_epoch: ee,
                        expected_round: er,
                        epoch: frame.epoch as usize,
                        round: frame.round as usize,
                        seq: frame.seq,
                    });
                };
                let p = &mut pending[pi];
                let idx = p.frames.iter().position(|f| {
                    f.dst as usize == dst
                        && f.kind == frame.kind
                        && f.mode == frame.mode
                        && f.chunk == frame.chunk
                });
                let Some(idx) = idx else {
                    return Err(TransportError::UnexpectedPanel {
                        dst,
                        mode: frame.mode as usize,
                        chunk: frame.chunk as usize,
                        seq: frame.seq,
                    });
                };
                if !p.issued[idx] {
                    // A frame for a slot whose payload was never handed
                    // to the transport cannot be legitimate traffic.
                    return Err(TransportError::UnexpectedPanel {
                        dst,
                        mode: frame.mode as usize,
                        chunk: frame.chunk as usize,
                        seq: frame.seq,
                    });
                }
                let expect = &p.frames[idx];
                if frame.src != expect.src
                    || frame.row_start != expect.row_start
                    || frame.n_rows != expect.n_rows
                    || frame.payload.len() != expect.payload.len()
                {
                    return Err(TransportError::Malformed {
                        detail: format!(
                            "panel geometry mismatch at seq {}: got (src {}, rows {}+{}, \
                             {} bytes), expected (src {}, rows {}+{}, {} bytes)",
                            frame.seq,
                            frame.src,
                            frame.row_start,
                            frame.n_rows,
                            frame.payload.len(),
                            expect.src,
                            expect.row_start,
                            expect.n_rows,
                            expect.payload.len()
                        ),
                    });
                }
                if p.got[idx].is_some() || p.taken[idx] {
                    // A resend's copy arriving after the original (or
                    // vice versa) under a different seq.
                    stats.duplicates_dropped += 1;
                    continue;
                }
                // Reorder observation: this (dst, src) pair saw a
                // higher-sequence frame earlier this round.
                let src = frame.src as usize;
                if let Some(&prev) = p.last_seq.get(&(dst, src)) {
                    if frame.seq < prev {
                        stats.reorders += 1;
                    }
                }
                let entry = p.last_seq.entry((dst, src)).or_insert(frame.seq);
                *entry = (*entry).max(frame.seq);
                satisfied[dst].insert(frame.seq);
                delivered_high[dst] = delivered_high[dst].max(frame.seq);
                stats.frames_delivered += 1;
                if *record_events {
                    events.push(ExchangeEvent::Delivered {
                        epoch: p.epoch,
                        round: p.round,
                        src,
                        dst,
                        mode: frame.mode as usize,
                        chunk: frame.chunk as usize,
                        seq: frame.seq,
                    });
                }
                p.delivered_seq[idx] = frame.seq;
                p.got[idx] = Some(frame.payload);
                prune_dedup(
                    &mut satisfied[dst],
                    &mut floor[dst],
                    delivered_high[dst],
                    *dedup_window,
                    pending,
                    dst,
                );
            }
        }
        Ok(())
    }
}

/// Bound `satisfied[dst]` to the dedup window, keyed on **delivered**
/// seqs: raise the floor to half a window below the highest delivery
/// this destination has seen, but never past a seq still in flight (an
/// outstanding panel's resend must not be mistaken for a stale
/// duplicate), and never downward.
fn prune_dedup(
    satisfied: &mut HashSet<u64>,
    floor: &mut u64,
    delivered_high: u64,
    dedup_window: usize,
    pending: &[PendingRound],
    dst: usize,
) {
    if satisfied.len() <= dedup_window {
        return;
    }
    let mut new_floor = delivered_high.saturating_sub((dedup_window / 2) as u64);
    for p in pending {
        for (idx, f) in p.frames.iter().enumerate() {
            if f.dst as usize == dst && p.got[idx].is_none() && !p.taken[idx] {
                new_floor = new_floor.min(f.seq);
            }
        }
    }
    if new_floor > *floor {
        *floor = new_floor;
        satisfied.retain(|&s| s >= new_floor);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(seq: u64, payload: Vec<u8>) -> Frame {
        Frame {
            epoch: 3,
            round: 2,
            src: 1,
            dst: 0,
            kind: PanelKind::Rows,
            mode: 1,
            chunk: 4,
            row_start: 20,
            n_rows: 5,
            seq,
            payload,
        }
    }

    #[test]
    fn frame_roundtrips_bitwise() {
        for payload in [vec![], vec![1u8, 2, 3], (0..=255u8).collect::<Vec<u8>>()] {
            let f = frame(77, payload);
            let bytes = f.encode();
            assert_eq!(Frame::decode(&bytes).unwrap(), f);
            assert_eq!(Frame::peek_src(&bytes), Some(1));
        }
        let mut f = frame(0, vec![9; 16]);
        f.kind = PanelKind::CoreGrad;
        assert_eq!(Frame::decode(&f.encode()).unwrap().kind, PanelKind::CoreGrad);
    }

    #[test]
    fn frame_decode_detects_every_single_bit_flip() {
        let bytes = frame(12, vec![5u8; 40]).encode();
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    Frame::decode(&bad).is_err(),
                    "flip at byte {i} bit {bit} went undetected"
                );
            }
        }
        assert!(matches!(
            Frame::decode(&bytes[..10]),
            Err(TransportError::Malformed { .. })
        ));
        let mut truncated = bytes.clone();
        truncated.truncate(bytes.len() - 4);
        assert!(Frame::decode(&truncated).is_err());
    }

    #[test]
    fn inproc_transport_is_fifo_per_destination() {
        let mut t = InProcTransport::new(2);
        t.send(0, vec![1]).unwrap();
        t.send(1, vec![2]).unwrap();
        t.send(0, vec![3]).unwrap();
        assert_eq!(t.recv(0), Some(vec![1]));
        assert_eq!(t.recv(0), Some(vec![3]));
        assert_eq!(t.recv(0), None);
        assert_eq!(t.recv(1), Some(vec![2]));
        assert!(t.send(5, vec![0]).is_err());
    }

    fn row_panels() -> Vec<(PanelSpec, Vec<u8>)> {
        // Two panels device 1 -> 0, one panel device 0 -> 1.
        let spec = |src_dev, dst_dev, mode, chunk, payload: &[u8]| {
            (
                PanelSpec {
                    kind: PanelKind::Rows,
                    src_dev,
                    dst_dev,
                    mode,
                    chunk,
                    row_start: 4 * chunk,
                    n_rows: payload.len() / 4,
                },
                payload.to_vec(),
            )
        };
        vec![
            spec(1, 0, 0, 1, &[1u8; 16]),
            spec(1, 0, 2, 3, &[2u8; 8]),
            spec(0, 1, 1, 2, &[3u8; 12]),
        ]
    }

    #[test]
    fn healthy_exchange_returns_payloads_in_panel_order() {
        let mut ex = Exchanger::new(2, None);
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        assert_eq!(out.len(), 3);
        for ((spec, payload), (ospec, opayload, _seq)) in panels.iter().zip(&out) {
            assert_eq!(spec, ospec);
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert_eq!(stats.frames_sent, 3);
        assert_eq!(stats.frames_delivered, 3);
        assert_eq!(stats.faults_detected(), 0);
    }

    #[test]
    fn dropped_frames_recover_by_resend() {
        // Deterministic: the injector's rng decides which sends drop;
        // with rate 0.5 over 3 first-sends plus retries, recovery must
        // either complete intact or time out loudly — and for this seed
        // grid at least one run must actually exercise the retry path.
        let mut recovered_with_retries = false;
        for seed in 0..16u64 {
            let plan = FaultPlan {
                seed,
                rate: 0.5,
                kinds: FaultKinds::single(FaultKind::Drop),
                kill: None,
            };
            let mut ex = Exchanger::new(2, Some(plan));
            let panels = row_panels();
            match ex.exchange(0, 1, &panels) {
                Ok(out) => {
                    for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
                        assert_eq!(payload, opayload);
                    }
                    if ex.drain_stats().retries > 0 {
                        recovered_with_retries = true;
                    }
                }
                Err(TransportError::Timeout { .. }) => {}
                Err(e) => panic!("seed {seed}: unexpected error {e}"),
            }
        }
        assert!(recovered_with_retries, "no seed exercised the retry path");
    }

    #[test]
    fn certain_drop_times_out_with_named_error() {
        let plan = FaultPlan {
            seed: 1,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Drop),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::Timeout { missing: 3, .. }), "got {err}");
    }

    #[test]
    fn duplicates_are_deduped_idempotently() {
        let plan = FaultPlan {
            seed: 2,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Duplicate),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert!(stats.duplicates_dropped >= 3, "{stats:?}");
        assert_eq!(stats.retries, 0);
    }

    #[test]
    fn corruption_is_always_detected_never_applied() {
        // Every send (including resends) flips a payload bit, so every
        // arrival must be rejected by the checksum and the exchange must
        // fail loudly — corrupt bytes can never reach the caller.
        let plan = FaultPlan {
            seed: 3,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Corrupt),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::Timeout { .. }), "got {err}");
        let stats = ex.drain_stats();
        assert!(stats.checksum_failures >= 3, "{stats:?}");
        assert_eq!(stats.frames_delivered, 0);
    }

    #[test]
    fn delays_recover_on_ticks_without_resends_or_with_dedup() {
        let plan = FaultPlan {
            seed: 4,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Delay),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert!(stats.timeouts > 0, "delay must cost at least one timeout: {stats:?}");
    }

    #[test]
    fn reorders_are_buffered_and_observed() {
        let plan = FaultPlan {
            seed: 5,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Reorder),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
    }

    #[test]
    fn killed_device_surfaces_as_device_dead() {
        let plan = FaultPlan {
            seed: 6,
            rate: 0.0,
            kinds: FaultKinds::NONE,
            kill: Some(KillSpec { device: 1, after_sends: 0 }),
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::DeviceDead { device: 1 }), "got {err}");
    }

    #[test]
    fn event_log_brackets_every_delivery_inside_its_window() {
        let mut ex = Exchanger::new(2, None);
        ex.enable_event_log();
        let panels = row_panels();
        let out = ex.exchange(1, 2, &panels).unwrap();
        for (spec, _, seq) in &out {
            ex.note_applied(1, 2, spec, *seq);
        }
        ex.note_compute_start(1, 2);
        let events = ex.events();
        assert!(matches!(events[0], ExchangeEvent::BarrierStart { epoch: 1, round: 2 }));
        assert!(matches!(events.last(), Some(ExchangeEvent::ComputeStart { epoch: 1, round: 2 })));
        let sent = events.iter().filter(|e| matches!(e, ExchangeEvent::Sent { .. })).count();
        let delivered =
            events.iter().filter(|e| matches!(e, ExchangeEvent::Delivered { .. })).count();
        let applied =
            events.iter().filter(|e| matches!(e, ExchangeEvent::Applied { .. })).count();
        assert_eq!((sent, delivered, applied), (3, 3, 3));
    }

    #[test]
    fn fault_plan_parsing_is_loud_on_garbage() {
        assert_eq!(FaultPlan::from_vars(None, None, None).unwrap(), None);
        let p = FaultPlan::from_vars(Some("9"), Some("0.25"), Some("drop,corrupt"))
            .unwrap()
            .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rate, 0.25);
        assert!(p.kinds.contains(FaultKind::Drop));
        assert!(p.kinds.contains(FaultKind::Corrupt));
        assert!(!p.kinds.contains(FaultKind::Delay));
        // Partial settings fill defaults.
        let p = FaultPlan::from_vars(None, Some("0.1"), None).unwrap().unwrap();
        assert_eq!(p.kinds, FaultKinds::ALL);
        // Garbage is a typed, named error — never a silent default.
        assert!(matches!(
            FaultPlan::from_vars(Some("not-a-seed"), None, None),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, Some("1.5"), None),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, None, Some("drop,explode")),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, None, Some("")),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("direct"), Some(TransportKind::Direct));
        assert_eq!(TransportKind::parse("Channel"), Some(TransportKind::Channel));
        assert_eq!(TransportKind::parse("auto"), Some(TransportKind::Auto));
        assert_eq!(TransportKind::parse("tcp"), None);
        assert_eq!(TransportKind::Direct.resolve(), TransportKind::Direct);
        assert_eq!(TransportKind::Channel.resolve(), TransportKind::Channel);
    }

    #[test]
    fn prefetch_mode_parses() {
        assert_eq!(PrefetchMode::parse("off"), Some(PrefetchMode::Off));
        assert_eq!(PrefetchMode::parse("Async"), Some(PrefetchMode::Async));
        assert_eq!(PrefetchMode::parse("auto"), Some(PrefetchMode::Auto));
        assert_eq!(PrefetchMode::parse("eager"), None);
        assert_eq!(PrefetchMode::Off.resolve(), PrefetchMode::Off);
        assert_eq!(PrefetchMode::Async.resolve(), PrefetchMode::Async);
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn frame_overflow_is_typed_never_wrapped() {
        let mut ex = Exchanger::new(2, None);
        let huge = u32::MAX as usize + 1;
        let mut spec = row_panels()[0].0;
        spec.row_start = huge;
        assert_eq!(
            ex.begin_round(0, 1, &[spec]).unwrap_err(),
            TransportError::FrameOverflow { field: "row_start", value: huge }
        );
        let mut spec = row_panels()[0].0;
        spec.n_rows = huge;
        assert!(matches!(
            ex.begin_round(0, 1, &[spec]).unwrap_err(),
            TransportError::FrameOverflow { field: "n_rows", .. }
        ));
        // epoch/round narrow through the same checked path, and the
        // synchronous exchange surfaces the identical typed error.
        let panels = row_panels();
        assert!(matches!(
            ex.exchange(huge, 0, &panels).unwrap_err(),
            TransportError::FrameOverflow { field: "epoch", .. }
        ));
        assert!(matches!(
            ex.exchange(0, huge, &panels).unwrap_err(),
            TransportError::FrameOverflow { field: "round", .. }
        ));
    }

    #[cfg(unix)]
    #[test]
    fn non_unicode_fault_env_is_loud() {
        use std::os::unix::ffi::OsStringExt;
        assert_eq!(env_value(FAULT_SEED_VAR, None).unwrap(), None);
        assert_eq!(env_value(FAULT_SEED_VAR, Some("7".into())).unwrap().as_deref(), Some("7"));
        // A set-but-non-unicode value is a typed error, not a silently
        // disabled fault plan (the old `env::var(..).ok()` behavior).
        let bad = std::ffi::OsString::from_vec(vec![b'4', 0x80, 0xfe]);
        assert!(matches!(
            env_value(FAULT_SEED_VAR, Some(bad)).unwrap_err(),
            TransportError::InvalidFaultEnv { .. }
        ));
    }

    /// Captures the first frame it ever carries and re-injects queued
    /// frames ahead of real traffic — the "late duplicate from far in
    /// the past" scenario the dedup-window bugfix exists for.
    struct ReplayTransport {
        inner: InProcTransport,
        first: std::sync::Arc<std::sync::Mutex<Option<(usize, Vec<u8>)>>>,
        inject: std::sync::Arc<std::sync::Mutex<Vec<(usize, Vec<u8>)>>>,
    }

    impl Transport for ReplayTransport {
        fn devices(&self) -> usize {
            self.inner.devices()
        }

        fn send(&mut self, dst: usize, bytes: Vec<u8>) -> Result<(), TransportError> {
            let mut first = self.first.lock().unwrap();
            if first.is_none() {
                *first = Some((dst, bytes.clone()));
            }
            drop(first);
            self.inner.send(dst, bytes)
        }

        fn recv(&mut self, dst: usize) -> Option<Vec<u8>> {
            {
                let mut inject = self.inject.lock().unwrap();
                if let Some(pos) = inject.iter().position(|(d, _)| *d == dst) {
                    return Some(inject.remove(pos).1);
                }
            }
            self.inner.recv(dst)
        }

        fn tick(&mut self) {
            self.inner.tick();
        }
    }

    #[test]
    fn late_duplicate_older_than_pruned_window_is_dropped_not_reapplied() {
        let first = std::sync::Arc::new(std::sync::Mutex::new(None));
        let inject = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let transport = ReplayTransport {
            inner: InProcTransport::new(2),
            first: first.clone(),
            inject: inject.clone(),
        };
        let mut ex = Exchanger::with_transport(Box::new(transport));
        // Cross the real DEDUP_WINDOW threshold: row_panels() delivers
        // 2 frames to device 0 per barrier, so ~4300 barriers push
        // device 0's satisfied set past 8192 and force a prune.
        let panels = row_panels();
        let barriers = DEDUP_WINDOW / 2 + 200;
        for round in 0..barriers {
            ex.exchange(0, round, &panels).unwrap();
        }
        let before = ex.drain_stats();
        assert_eq!(before.duplicates_dropped, 0, "healthy run must not count dups");
        // Re-deliver the very first frame (seq 0): a stale duplicate
        // from beyond the pruned window. The old prune floored the set
        // at sender-side `next_seq - 4096`, so the seq was forgotten and
        // the frame hard-errored as an EpochRoundMismatch; the
        // delivered-keyed floor drops it as the duplicate it is.
        inject.lock().unwrap().push(first.lock().unwrap().clone().unwrap());
        let out = ex.exchange(0, barriers, &panels).unwrap();
        assert_eq!(out.len(), panels.len());
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert_eq!(stats.duplicates_dropped, 1, "{stats:?}");
        assert_eq!(stats.checksum_failures, 0);
    }

    #[test]
    fn async_rounds_pipeline_without_interference() {
        let mut ex = Exchanger::new(2, None);
        ex.enable_event_log();
        let panels = row_panels();
        let specs: Vec<PanelSpec> = panels.iter().map(|(s, _)| *s).collect();
        let flipped: Vec<Vec<u8>> =
            panels.iter().map(|(_, p)| p.iter().map(|b| b ^ 0xff).collect()).collect();
        // Round 2 is opened and fully issued *before* round 1 collects —
        // the double-buffered prefetch shape.
        let t1 = ex.begin_round(0, 1, &specs).unwrap();
        let t2 = ex.begin_round(0, 2, &specs).unwrap();
        for (idx, (_, payload)) in panels.iter().enumerate() {
            ex.issue(t1, idx, payload.clone()).unwrap();
        }
        for (idx, payload) in flipped.iter().enumerate() {
            ex.issue(t2, idx, payload.clone()).unwrap();
        }
        let out1 = ex.collect(t1).unwrap();
        assert_eq!(out1.len(), 3);
        for ((spec, payload), (_, ospec, opayload, _)) in panels.iter().zip(&out1) {
            assert_eq!(spec, ospec);
            assert_eq!(payload, opayload);
        }
        // A collected token is spent.
        assert!(ex.collect(t1).is_err());
        let out2 = ex.collect(t2).unwrap();
        assert_eq!(out2.len(), 3);
        for (i, (_, ospec, opayload, _)) in out2.iter().enumerate() {
            assert_eq!(&specs[i], ospec);
            assert_eq!(&flipped[i], opayload);
        }
        let stats = ex.drain_stats();
        assert_eq!(stats.frames_sent, 6);
        assert_eq!(stats.frames_delivered, 6);
        assert_eq!(stats.faults_detected(), 0, "{stats:?}");
    }

    #[test]
    fn take_ready_defers_stragglers_and_collect_forces_them() {
        // Healthy: everything is ready at the barrier; collect retires
        // the round with nothing left over.
        let mut ex = Exchanger::new(2, None);
        let panels = row_panels();
        let specs: Vec<PanelSpec> = panels.iter().map(|(s, _)| *s).collect();
        let t = ex.begin_round(0, 1, &specs).unwrap();
        for (idx, (_, payload)) in panels.iter().enumerate() {
            ex.issue(t, idx, payload.clone()).unwrap();
        }
        ex.poll().unwrap();
        let ready = ex.take_ready(t).unwrap();
        assert_eq!(ready.len(), 3);
        assert!(ex.take_ready(t).unwrap().is_empty(), "slots hand out once");
        assert!(ex.collect(t).unwrap().is_empty());
        // All-delayed: nothing is ready at the barrier; the forced
        // collect ticks the held frames free and returns every slot.
        let plan = FaultPlan {
            seed: 8,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Delay),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let t = ex.begin_round(0, 1, &specs).unwrap();
        for (idx, (_, payload)) in panels.iter().enumerate() {
            ex.issue(t, idx, payload.clone()).unwrap();
        }
        ex.poll().unwrap();
        assert!(ex.take_ready(t).unwrap().is_empty());
        let out = ex.collect(t).unwrap();
        assert_eq!(out.len(), 3);
        for ((_, payload), (_, _, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
    }
}
