//! Fault-tolerant message transport for the device grid's parameter
//! exchange (ROADMAP item 2, transport half).
//!
//! Historically the grid's round-boundary "exchange" was bookkeeping: the
//! factor rows live in shared memory, so handing a chunk to its next
//! owner was free and infallible. This module makes the exchange a real
//! data path — boundary-row panels and core-gradient panels travel as
//! **serialized, framed, checksummed messages** between devices — so the
//! failure modes a multi-process/multi-node backend will have (lost,
//! duplicated, reordered, corrupted, delayed messages; dead peers) exist
//! here first, behind a deterministic in-process oracle, and every
//! detection/recovery path is testable bitwise.
//!
//! # Layers
//!
//! * [`Frame`] — the wire format: a fixed header (epoch, round,
//!   source/destination device, panel kind, mode, chunk, row range,
//!   sequence number, payload length) plus an opaque little-endian f32
//!   payload, trailed by an FNV-1a-64 checksum over everything before it.
//! * [`Transport`] — moves opaque frame bytes between device mailboxes.
//!   Deliberately **non-blocking and virtual-timed**: `recv` returns
//!   `None` when a mailbox is empty (the receiver's timeout signal) and
//!   [`Transport::tick`] advances virtual time, releasing delayed
//!   frames. Timeout/backoff are therefore attempt-counted, fully
//!   deterministic, and fast under test — no wall clocks.
//! * [`InProcTransport`] — per-device FIFO mailboxes; the bitwise
//!   oracle. Exact-mode training over it is bitwise-identical to the
//!   direct in-memory exchange at every device count (pinned by
//!   `tests/properties.rs::prop_channel_transport_exact_bitwise_matches_direct`).
//! * [`FaultyTransport`] — wraps the oracle and injects faults per a
//!   seeded [`FaultPlan`]: drops, duplicates, reorders, corruption
//!   (payload bit-flips the checksum must catch), delays (released on
//!   `tick`), and a permanent device kill.
//! * [`Exchanger`] — the protocol: a two-phase exchange per round
//!   barrier (send every inter-device panel, then drain/validate with
//!   sequence-number dedup, reorder buffering, and bounded
//!   resend-with-backoff), surfacing unrecoverable failures as typed
//!   [`TransportError`]s and counting every recovery in
//!   [`TransportStats`]. It can also record a plain-data
//!   [`ExchangeEvent`] stream for the in-flight-exchange auditor
//!   ([`crate::analysis::audit_exchange`]).
//!
//! # What recovers, what degrades, what fails
//!
//! * **Drops** recover by bounded resend with exponential virtual-time
//!   backoff (`TransportStats::retries` counts them).
//! * **Duplicates** are idempotently dropped by sequence-number dedup —
//!   a satisfied sequence number is never applied twice.
//! * **Reorders/delays** recover by buffering: panels are matched by
//!   (destination, kind, mode, chunk), not arrival order, and ticks
//!   release held frames before each retry round.
//! * **Corruption** is caught by the frame checksum; the frame is
//!   discarded and recovered like a drop. A corrupt frame is *never*
//!   applied — the factors cannot silently diverge.
//! * **Unrecoverable** conditions — retry budget exhausted, a killed
//!   device, protocol violations — surface as named [`TransportError`]
//!   variants from `train_epoch` (wrapped in
//!   [`AlgoError::Transport`](crate::algo::AlgoError)).
//!
//! All recovery activity is loud: per-epoch counters land in
//! [`PlanAccum`](crate::metrics::PlanAccum)'s transport block and a
//! warning is logged whenever an epoch saw faults.

use std::collections::HashSet;
use std::collections::VecDeque;

use crate::log_warn;
use crate::util::fnv1a64;
use crate::util::Rng;

/// Which exchange path the parallel engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Harness-controlled: the `FASTTUCKER_TRANSPORT` environment
    /// variable (`direct`/`channel`), else `Direct`.
    Auto,
    /// The historical shared-memory handover: no serialization, no
    /// failure modes. Fault injection cannot engage (configuring a
    /// [`FaultPlan`] under `Direct` is surfaced as a degraded run).
    Direct,
    /// Route every inter-device panel through a framed [`Transport`]
    /// channel ([`InProcTransport`], optionally wrapped in
    /// [`FaultyTransport`]). Exact mode stays bitwise-identical to
    /// `Direct` at every device count.
    Channel,
}

impl TransportKind {
    /// Parse `"auto"`, `"direct"`, or `"channel"` (case-insensitive).
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(TransportKind::Auto),
            "direct" => Some(TransportKind::Direct),
            "channel" => Some(TransportKind::Channel),
            _ => None,
        }
    }

    /// Resolve `Auto` against `FASTTUCKER_TRANSPORT` (same loud-fallback
    /// policy as [`resolve_devices`](super::device::resolve_devices)):
    /// unknown values warn and fall back to `Direct`. Never returns
    /// `Auto`.
    pub fn resolve(self) -> TransportKind {
        match self {
            TransportKind::Direct | TransportKind::Channel => self,
            TransportKind::Auto => match std::env::var("FASTTUCKER_TRANSPORT") {
                Ok(v) => match TransportKind::parse(&v) {
                    Some(TransportKind::Channel) => TransportKind::Channel,
                    Some(_) => TransportKind::Direct,
                    None => {
                        log_warn!(
                            "FASTTUCKER_TRANSPORT={v:?} is not \"direct\"/\"channel\" — \
                             falling back to direct"
                        );
                        TransportKind::Direct
                    }
                },
                Err(_) => TransportKind::Direct,
            },
        }
    }
}

/// Typed transport failures. Every fault class the receive path can
/// detect has a named variant; `Clone + PartialEq + Eq` so the variants
/// can ride inside [`crate::algo::AlgoError`] and be `matches!`-asserted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// A frame that cannot be parsed (bad magic, impossible lengths,
    /// unknown panel kind) or whose header disagrees with the expected
    /// panel geometry.
    Malformed { detail: String },
    /// Frame checksum verification failed (payload or header corrupted
    /// in flight). Best-effort header fields are included for the log.
    ChecksumMismatch { src: usize, dst: usize, seq: u64 },
    /// A frame for a different round barrier than the one in progress
    /// whose sequence number was never satisfied — a protocol violation,
    /// not a stale duplicate (those are deduped silently).
    EpochRoundMismatch {
        expected_epoch: usize,
        expected_round: usize,
        epoch: usize,
        round: usize,
        seq: u64,
    },
    /// A structurally valid frame that matches no panel this barrier
    /// expects.
    UnexpectedPanel { dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// The retry budget was exhausted with panels still missing.
    Timeout { missing: usize, attempts: usize },
    /// A device stopped sending and acknowledging permanently (the
    /// elastic-recovery trigger: reload the checkpoint, re-shard, resume).
    DeviceDead { device: usize },
    /// A `FASTTUCKER_FAULT_*` environment variable failed validation.
    InvalidFaultEnv { var: String, value: String, reason: String },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Malformed { detail } => {
                write!(f, "malformed transport frame: {detail}")
            }
            TransportError::ChecksumMismatch { src, dst, seq } => write!(
                f,
                "transport frame checksum mismatch (src device {src}, dst device {dst}, \
                 seq {seq}): frame discarded"
            ),
            TransportError::EpochRoundMismatch {
                expected_epoch,
                expected_round,
                epoch,
                round,
                seq,
            } => write!(
                f,
                "transport frame for epoch {epoch} round {round} (seq {seq}) arrived at \
                 the epoch {expected_epoch} round {expected_round} barrier and was never \
                 satisfied — protocol violation"
            ),
            TransportError::UnexpectedPanel { dst, mode, chunk, seq } => write!(
                f,
                "transport frame (dst device {dst}, mode {mode}, chunk {chunk}, seq {seq}) \
                 matches no panel expected at this barrier"
            ),
            TransportError::Timeout { missing, attempts } => write!(
                f,
                "transport exchange timed out: {missing} panel(s) still missing after \
                 {attempts} attempts"
            ),
            TransportError::DeviceDead { device } => write!(
                f,
                "device {device} is unreachable (no frames after retry budget) — \
                 reload the last checkpoint into a re-sharded engine to resume"
            ),
            TransportError::InvalidFaultEnv { var, value, reason } => {
                write!(f, "{var}={value:?} is invalid: {reason}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelKind {
    /// A contiguous factor-row panel (`n_rows` rows of mode `mode`,
    /// starting at `row_start`) changing device ownership at a round
    /// boundary.
    Rows,
    /// One worker's per-epoch Eq. 17 core-gradient panel (`chunk` holds
    /// the worker id), shipped to the root device for the merge.
    CoreGrad,
}

/// Frame magic: "FTXM" (FastTucker eXchange Message).
pub const FRAME_MAGIC: [u8; 4] = *b"FTXM";
/// Fixed header length in bytes (before the payload).
pub const FRAME_HEADER_LEN: usize = 53;

/// One exchange message: header + opaque payload + trailing checksum.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub epoch: u32,
    pub round: u32,
    pub src: u32,
    pub dst: u32,
    pub kind: PanelKind,
    pub mode: u32,
    pub chunk: u32,
    pub row_start: u32,
    pub n_rows: u32,
    pub seq: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Serialize: `magic | header fields | payload | fnv1a64 checksum`
    /// (checksum over every preceding byte, little-endian throughout —
    /// the same hand-rolled idiom as [`crate::model::checkpoint`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(FRAME_HEADER_LEN + self.payload.len() + 8);
        out.extend_from_slice(&FRAME_MAGIC);
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.dst.to_le_bytes());
        out.push(match self.kind {
            PanelKind::Rows => 0,
            PanelKind::CoreGrad => 1,
        });
        out.extend_from_slice(&self.mode.to_le_bytes());
        out.extend_from_slice(&self.chunk.to_le_bytes());
        out.extend_from_slice(&self.row_start.to_le_bytes());
        out.extend_from_slice(&self.n_rows.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u64).to_le_bytes());
        debug_assert_eq!(out.len(), FRAME_HEADER_LEN);
        out.extend_from_slice(&self.payload);
        let checksum = fnv1a64(&out);
        out.extend_from_slice(&checksum.to_le_bytes());
        out
    }

    /// Parse and validate a frame. Checksum failure and structural
    /// damage come back as named errors; the caller decides whether to
    /// recover (discard + retry) or abort.
    pub fn decode(bytes: &[u8]) -> Result<Frame, TransportError> {
        let malformed = |detail: String| TransportError::Malformed { detail };
        if bytes.len() < FRAME_HEADER_LEN + 8 {
            return Err(malformed(format!(
                "{} bytes, need at least {}",
                bytes.len(),
                FRAME_HEADER_LEN + 8
            )));
        }
        if bytes[0..4] != FRAME_MAGIC {
            return Err(malformed(format!("bad magic {:?}", &bytes[0..4])));
        }
        let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
        let src = u32_at(12) as usize;
        let dst = u32_at(16) as usize;
        let seq = u64_at(37);
        let payload_len = u64_at(45) as usize;
        if bytes.len() != FRAME_HEADER_LEN + payload_len + 8 {
            return Err(malformed(format!(
                "payload length {} disagrees with frame size {}",
                payload_len,
                bytes.len()
            )));
        }
        let stored = u64_at(bytes.len() - 8);
        if fnv1a64(&bytes[..bytes.len() - 8]) != stored {
            return Err(TransportError::ChecksumMismatch { src, dst, seq });
        }
        let kind = match bytes[20] {
            0 => PanelKind::Rows,
            1 => PanelKind::CoreGrad,
            k => return Err(malformed(format!("unknown panel kind {k}"))),
        };
        Ok(Frame {
            epoch: u32_at(4),
            round: u32_at(8),
            src: src as u32,
            dst: dst as u32,
            kind,
            mode: u32_at(21),
            chunk: u32_at(25),
            row_start: u32_at(29),
            n_rows: u32_at(33),
            seq,
            payload: bytes[FRAME_HEADER_LEN..bytes.len() - 8].to_vec(),
        })
    }

    /// Best-effort source-device peek on raw frame bytes (used by the
    /// fault injector's kill filter without a full decode).
    pub fn peek_src(bytes: &[u8]) -> Option<usize> {
        if bytes.len() < FRAME_HEADER_LEN || bytes[0..4] != FRAME_MAGIC {
            return None;
        }
        Some(u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize)
    }
}

/// Moves opaque frame bytes between device mailboxes.
///
/// Deterministic, non-blocking semantics: `send` enqueues (or loses —
/// the caller cannot tell), `recv` dequeues or reports an empty mailbox,
/// and `tick` advances *virtual* time, releasing any frames an
/// implementation is holding (delays, reorders). There are no wall-clock
/// timeouts anywhere — the [`Exchanger`] counts attempts instead, which
/// keeps every fault scenario fast and bit-reproducible.
pub trait Transport {
    /// Number of device mailboxes.
    fn devices(&self) -> usize;
    /// Enqueue `bytes` for device `dst`. An `Err` is an immediate local
    /// failure (bad destination); silent loss is allowed and is what
    /// retries exist for.
    fn send(&mut self, dst: usize, bytes: Vec<u8>) -> Result<(), TransportError>;
    /// Dequeue the next frame for device `dst`, if any.
    fn recv(&mut self, dst: usize) -> Option<Vec<u8>>;
    /// Advance virtual time one step, releasing held frames.
    fn tick(&mut self);
    /// A device known to have failed permanently, if any — lets the
    /// exchanger distinguish [`TransportError::DeviceDead`] from a plain
    /// [`TransportError::Timeout`] when the retry budget runs out.
    fn failed_device(&self) -> Option<usize> {
        None
    }
}

/// The bitwise oracle: per-device FIFO mailboxes, no loss, no delay.
pub struct InProcTransport {
    boxes: Vec<VecDeque<Vec<u8>>>,
}

impl InProcTransport {
    pub fn new(devices: usize) -> InProcTransport {
        assert!(devices >= 1);
        InProcTransport { boxes: (0..devices).map(|_| VecDeque::new()).collect() }
    }
}

impl Transport for InProcTransport {
    fn devices(&self) -> usize {
        self.boxes.len()
    }

    fn send(&mut self, dst: usize, bytes: Vec<u8>) -> Result<(), TransportError> {
        match self.boxes.get_mut(dst) {
            Some(q) => {
                q.push_back(bytes);
                Ok(())
            }
            None => Err(TransportError::Malformed {
                detail: format!("send to device {dst} of {}", self.boxes.len()),
            }),
        }
    }

    fn recv(&mut self, dst: usize) -> Option<Vec<u8>> {
        self.boxes.get_mut(dst)?.pop_front()
    }

    fn tick(&mut self) {}
}

/// One injectable fault class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Frame silently lost.
    Drop,
    /// Frame delivered twice.
    Duplicate,
    /// Frame held back and delivered after a later frame to the same
    /// destination (a true inversion), or on the next tick.
    Reorder,
    /// One payload bit flipped; the stale checksum makes it detectable.
    Corrupt,
    /// Frame held until the next tick.
    Delay,
}

const ALL_FAULT_KINDS: [FaultKind; 5] = [
    FaultKind::Drop,
    FaultKind::Duplicate,
    FaultKind::Reorder,
    FaultKind::Corrupt,
    FaultKind::Delay,
];

impl FaultKind {
    pub fn parse(s: &str) -> Option<FaultKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "drop" => Some(FaultKind::Drop),
            "duplicate" | "dup" => Some(FaultKind::Duplicate),
            "reorder" => Some(FaultKind::Reorder),
            "corrupt" => Some(FaultKind::Corrupt),
            "delay" => Some(FaultKind::Delay),
            _ => None,
        }
    }
}

/// A `Copy` set of fault classes (bitmask), so a [`FaultPlan`] can live
/// inside the `Copy` engine options.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultKinds(u8);

impl FaultKinds {
    pub const NONE: FaultKinds = FaultKinds(0);
    pub const ALL: FaultKinds = FaultKinds(0b1_1111);

    fn bit(kind: FaultKind) -> u8 {
        1 << (kind as usize)
    }

    pub fn single(kind: FaultKind) -> FaultKinds {
        FaultKinds(Self::bit(kind))
    }

    pub fn of(kinds: &[FaultKind]) -> FaultKinds {
        FaultKinds(kinds.iter().fold(0, |acc, &k| acc | Self::bit(k)))
    }

    pub fn contains(self, kind: FaultKind) -> bool {
        self.0 & Self::bit(kind) != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The contained kinds in declaration order (deterministic).
    pub fn list(self) -> Vec<FaultKind> {
        ALL_FAULT_KINDS.iter().copied().filter(|&k| self.contains(k)).collect()
    }

    /// Parse a comma-separated kind list, e.g. `"drop,duplicate"`.
    pub fn parse(s: &str) -> Option<FaultKinds> {
        let mut kinds = FaultKinds::NONE;
        for part in s.split(',') {
            if part.trim().is_empty() {
                return None;
            }
            kinds.0 |= Self::bit(FaultKind::parse(part)?);
        }
        if kinds.is_empty() {
            None
        } else {
            Some(kinds)
        }
    }
}

/// Kill device `device` permanently once the transport has carried
/// `after_sends` frames: from then on every frame to or from it is lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub device: usize,
    pub after_sends: u64,
}

/// Deterministic fault-injection plan for [`FaultyTransport`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the injector's own [`Rng`] stream (independent of the
    /// training streams — injection never perturbs the model math).
    pub seed: u64,
    /// Per-send probability of injecting one fault from `kinds`.
    pub rate: f32,
    /// Which fault classes may fire.
    pub kinds: FaultKinds,
    /// Optional permanent device failure.
    pub kill: Option<KillSpec>,
}

pub const FAULT_SEED_VAR: &str = "FASTTUCKER_FAULT_SEED";
pub const FAULT_RATE_VAR: &str = "FASTTUCKER_FAULT_RATE";
pub const FAULT_KINDS_VAR: &str = "FASTTUCKER_FAULT_KINDS";

impl FaultPlan {
    /// Build a plan from the `FASTTUCKER_FAULT_{SEED,RATE,KINDS}`
    /// environment variables. `Ok(None)` when none are set; malformed
    /// values are **loud** typed errors (the PR 4 bench-env policy), not
    /// silent defaults.
    pub fn from_env() -> Result<Option<FaultPlan>, TransportError> {
        let get = |var: &str| std::env::var(var).ok();
        FaultPlan::from_vars(
            get(FAULT_SEED_VAR).as_deref(),
            get(FAULT_RATE_VAR).as_deref(),
            get(FAULT_KINDS_VAR).as_deref(),
        )
    }

    /// The pure parser behind [`Self::from_env`] (testable without
    /// touching process-global environment state).
    pub fn from_vars(
        seed: Option<&str>,
        rate: Option<&str>,
        kinds: Option<&str>,
    ) -> Result<Option<FaultPlan>, TransportError> {
        if seed.is_none() && rate.is_none() && kinds.is_none() {
            return Ok(None);
        }
        let seed_v = match seed {
            None => 0x5EED,
            Some(s) => s.trim().parse::<u64>().map_err(|_| {
                TransportError::InvalidFaultEnv {
                    var: FAULT_SEED_VAR.into(),
                    value: s.into(),
                    reason: "expected an unsigned integer".into(),
                }
            })?,
        };
        let rate_v = match rate {
            None => 0.05,
            Some(s) => {
                let r = s.trim().parse::<f32>().map_err(|_| {
                    TransportError::InvalidFaultEnv {
                        var: FAULT_RATE_VAR.into(),
                        value: s.into(),
                        reason: "expected a float".into(),
                    }
                })?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(TransportError::InvalidFaultEnv {
                        var: FAULT_RATE_VAR.into(),
                        value: s.into(),
                        reason: "must lie in [0, 1]".into(),
                    });
                }
                r
            }
        };
        let kinds_v = match kinds {
            None => FaultKinds::ALL,
            Some(s) => FaultKinds::parse(s).ok_or_else(|| TransportError::InvalidFaultEnv {
                var: FAULT_KINDS_VAR.into(),
                value: s.into(),
                reason: "expected a comma-separated subset of \
                         drop,duplicate,reorder,corrupt,delay"
                    .into(),
            })?,
        };
        Ok(Some(FaultPlan { seed: seed_v, rate: rate_v, kinds: kinds_v, kill: None }))
    }
}

/// Seeded fault injector around the in-process oracle. Every decision
/// comes from its own deterministic [`Rng`] stream, so a (plan, traffic)
/// pair always produces the same fault sequence — the fault-matrix
/// property test depends on this.
pub struct FaultyTransport {
    inner: InProcTransport,
    plan: FaultPlan,
    kind_list: Vec<FaultKind>,
    rng: Rng,
    /// Frames held for a later-arrival inversion: flushed after the next
    /// send to the same destination, or on `tick`.
    held_reorder: Vec<(usize, Vec<u8>)>,
    /// Frames held until the next `tick`.
    held_delay: Vec<(usize, Vec<u8>)>,
    sends: u64,
    dead: Option<usize>,
}

impl FaultyTransport {
    pub fn new(inner: InProcTransport, plan: FaultPlan) -> FaultyTransport {
        FaultyTransport {
            inner,
            plan,
            kind_list: plan.kinds.list(),
            rng: Rng::new(plan.seed),
            held_reorder: Vec::new(),
            held_delay: Vec::new(),
            sends: 0,
            dead: None,
        }
    }

    fn flush_reorders_for(&mut self, dst: usize) {
        let mut i = 0;
        while i < self.held_reorder.len() {
            if self.held_reorder[i].0 == dst {
                let (d, bytes) = self.held_reorder.remove(i);
                let _ = self.inner.send(d, bytes);
            } else {
                i += 1;
            }
        }
    }
}

impl Transport for FaultyTransport {
    fn devices(&self) -> usize {
        self.inner.devices()
    }

    fn send(&mut self, dst: usize, mut bytes: Vec<u8>) -> Result<(), TransportError> {
        self.sends += 1;
        if self.dead.is_none() {
            if let Some(kill) = self.plan.kill {
                if self.sends > kill.after_sends {
                    log_warn!(
                        "fault injection: killing device {} after {} sends",
                        kill.device,
                        self.sends - 1
                    );
                    self.dead = Some(kill.device);
                }
            }
        }
        if let Some(dead) = self.dead {
            // A dead device neither sends nor receives: lose the frame.
            if dst == dead || Frame::peek_src(&bytes) == Some(dead) {
                return Ok(());
            }
        }
        let fault = if !self.kind_list.is_empty() && self.rng.uniform() < self.plan.rate {
            Some(self.kind_list[self.rng.gen_range(self.kind_list.len())])
        } else {
            None
        };
        match fault {
            Some(FaultKind::Drop) => Ok(()),
            Some(FaultKind::Duplicate) => {
                self.inner.send(dst, bytes.clone())?;
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
            Some(FaultKind::Reorder) => {
                self.held_reorder.push((dst, bytes));
                Ok(())
            }
            Some(FaultKind::Corrupt) => {
                // Flip one bit in the payload (or, for an empty payload,
                // the trailing checksum) — the header stays parseable and
                // the checksum check must catch the damage.
                let lo = FRAME_HEADER_LEN.min(bytes.len().saturating_sub(8));
                let hi = bytes.len();
                let idx = lo + self.rng.gen_range(hi - lo);
                bytes[idx] ^= 1 << self.rng.gen_range(8);
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
            Some(FaultKind::Delay) => {
                self.held_delay.push((dst, bytes));
                Ok(())
            }
            None => {
                self.inner.send(dst, bytes)?;
                self.flush_reorders_for(dst);
                Ok(())
            }
        }
    }

    fn recv(&mut self, dst: usize) -> Option<Vec<u8>> {
        self.inner.recv(dst)
    }

    fn tick(&mut self) {
        for (dst, bytes) in self.held_reorder.drain(..).chain(self.held_delay.drain(..)) {
            if Some(dst) != self.dead {
                let _ = self.inner.send(dst, bytes);
            }
        }
        self.inner.tick();
    }

    fn failed_device(&self) -> Option<usize> {
        self.dead
    }
}

/// Bounded-retry policy for the exchange protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum drain/resend attempts per barrier before the exchange
    /// fails ([`TransportError::Timeout`] / [`TransportError::DeviceDead`]).
    pub max_attempts: usize,
    /// Virtual-time ticks before attempt 1's resend; doubles each
    /// attempt (capped) — exponential backoff in tick units.
    pub backoff_base: usize,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 5, backoff_base: 1 }
    }
}

/// Recovery/fault counters for one stretch of exchanges (drained into
/// [`PlanAccum`](crate::metrics::PlanAccum) per epoch).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames handed to the transport (first sends + resends).
    pub frames_sent: u64,
    /// Serialized bytes handed to the transport.
    pub bytes_sent: u64,
    /// Frames that arrived, validated, and filled an expected panel.
    pub frames_delivered: u64,
    /// Resent frames (missing after a timeout + backoff window).
    pub retries: u64,
    /// Frames discarded by sequence-number dedup.
    pub duplicates_dropped: u64,
    /// Frames discarded for checksum/framing damage.
    pub checksum_failures: u64,
    /// In-order violations observed (a frame arriving after a
    /// higher-sequence frame to the same destination).
    pub reorders: u64,
    /// Drain attempts that found panels still missing.
    pub timeouts: u64,
}

impl TransportStats {
    /// Total detected fault events (anything a healthy exchange would
    /// not produce).
    pub fn faults_detected(&self) -> u64 {
        self.retries + self.duplicates_dropped + self.checksum_failures + self.reorders
            + self.timeouts
    }
}

/// The geometry of one panel the caller wants moved at a barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PanelSpec {
    pub kind: PanelKind,
    pub src_dev: usize,
    pub dst_dev: usize,
    /// Factor mode for `Rows` panels; 0 for `CoreGrad`.
    pub mode: usize,
    /// Chunk index for `Rows` panels; the worker id for `CoreGrad`.
    pub chunk: usize,
    pub row_start: usize,
    pub n_rows: usize,
}

/// Plain-data record of exchange activity, consumed by
/// [`crate::analysis::audit_exchange`] — the auditor's view of messages
/// in transit. One barrier's window runs from `BarrierStart` to
/// `ComputeStart`; in exact mode every delivered panel's *apply* must
/// land inside its own window, exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeEvent {
    /// The coordinator opened round `round`'s exchange window.
    BarrierStart { epoch: usize, round: usize },
    /// A panel frame was handed to the transport.
    Sent { epoch: usize, round: usize, src: usize, dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// A panel frame arrived, validated, and was accepted.
    Delivered {
        epoch: usize,
        round: usize,
        src: usize,
        dst: usize,
        mode: usize,
        chunk: usize,
        seq: u64,
    },
    /// The panel's bytes were written back into the factors/core-merge.
    Applied { epoch: usize, round: usize, dst: usize, mode: usize, chunk: usize, seq: u64 },
    /// The coordinator closed the window and released the workers.
    ComputeStart { epoch: usize, round: usize },
}

/// The exchange protocol driver: owns the transport, global sequence
/// numbering, dedup state, retry policy, counters, and the optional
/// audit event log.
pub struct Exchanger {
    transport: Box<dyn Transport + Send>,
    policy: RetryPolicy,
    next_seq: u64,
    /// Sequence numbers already satisfied — late/duplicate arrivals of
    /// these are dropped idempotently, even across barriers (a delayed
    /// frame can surface rounds later). Pruned below `next_seq - 4096`
    /// to stay bounded.
    satisfied: HashSet<u64>,
    stats: TransportStats,
    events: Vec<ExchangeEvent>,
    record_events: bool,
}

impl Exchanger {
    /// A channel exchanger over `devices` mailboxes; with a [`FaultPlan`]
    /// the oracle is wrapped in the seeded injector.
    pub fn new(devices: usize, fault: Option<FaultPlan>) -> Exchanger {
        let transport: Box<dyn Transport + Send> = match fault {
            Some(plan) => Box::new(FaultyTransport::new(InProcTransport::new(devices), plan)),
            None => Box::new(InProcTransport::new(devices)),
        };
        Exchanger {
            transport,
            policy: RetryPolicy::default(),
            next_seq: 0,
            satisfied: HashSet::new(),
            stats: TransportStats::default(),
            events: Vec::new(),
            record_events: false,
        }
    }

    pub fn set_policy(&mut self, policy: RetryPolicy) {
        self.policy = policy;
    }

    /// Record [`ExchangeEvent`]s for the in-flight-exchange auditor.
    pub fn enable_event_log(&mut self) {
        self.record_events = true;
    }

    pub fn events(&self) -> &[ExchangeEvent] {
        &self.events
    }

    pub fn clear_events(&mut self) {
        self.events.clear();
    }

    /// Drain and reset the recovery counters (one epoch's block).
    pub fn drain_stats(&mut self) -> TransportStats {
        std::mem::take(&mut self.stats)
    }

    /// Log a panel's write-back (the *apply* the auditor checks lands at
    /// the barrier).
    pub fn note_applied(&mut self, epoch: usize, round: usize, spec: &PanelSpec, seq: u64) {
        if self.record_events {
            self.events.push(ExchangeEvent::Applied {
                epoch,
                round,
                dst: spec.dst_dev,
                mode: spec.mode,
                chunk: spec.chunk,
                seq,
            });
        }
    }

    /// Log the end of a barrier's exchange window.
    pub fn note_compute_start(&mut self, epoch: usize, round: usize) {
        if self.record_events {
            self.events.push(ExchangeEvent::ComputeStart { epoch, round });
        }
    }

    /// Execute one barrier's exchange: send every panel, then
    /// drain/validate with dedup + reorder buffering and bounded
    /// resend-with-backoff. Returns each panel's payload with its
    /// sequence number, in the caller's panel order (deterministic).
    pub fn exchange(
        &mut self,
        epoch: usize,
        round: usize,
        panels: &[(PanelSpec, Vec<u8>)],
    ) -> Result<Vec<(PanelSpec, Vec<u8>, u64)>, TransportError> {
        if panels.is_empty() {
            return Ok(Vec::new());
        }
        if self.record_events {
            self.events.push(ExchangeEvent::BarrierStart { epoch, round });
        }
        // Keep the dedup set bounded: anything 4096 sequence numbers in
        // the past can no longer be in flight on the in-proc transports.
        if self.satisfied.len() > 8192 {
            let floor = self.next_seq.saturating_sub(4096);
            self.satisfied.retain(|&s| s >= floor);
        }
        let frames: Vec<Frame> = panels
            .iter()
            .map(|(spec, payload)| {
                let seq = self.next_seq;
                self.next_seq += 1;
                Frame {
                    epoch: epoch as u32,
                    round: round as u32,
                    src: spec.src_dev as u32,
                    dst: spec.dst_dev as u32,
                    kind: spec.kind,
                    mode: spec.mode as u32,
                    chunk: spec.chunk as u32,
                    row_start: spec.row_start as u32,
                    n_rows: spec.n_rows as u32,
                    seq,
                    payload: payload.clone(),
                }
            })
            .collect();
        for f in &frames {
            self.send_frame(f, epoch, round)?;
        }

        let n_devices = self.transport.devices();
        let mut got: Vec<Option<Vec<u8>>> = vec![None; frames.len()];
        let mut last_seq: Vec<Option<u64>> = vec![None; n_devices];
        let mut delivered_seq: Vec<u64> = vec![0; frames.len()];

        self.drain(epoch, round, panels, &frames, &mut got, &mut last_seq, &mut delivered_seq)?;
        let mut attempt = 0usize;
        while got.iter().any(|g| g.is_none()) {
            attempt += 1;
            if attempt > self.policy.max_attempts {
                let missing = got.iter().filter(|g| g.is_none()).count();
                if let Some(device) = self.transport.failed_device() {
                    return Err(TransportError::DeviceDead { device });
                }
                return Err(TransportError::Timeout { missing, attempts: attempt - 1 });
            }
            self.stats.timeouts += 1;
            // Exponential backoff in virtual time: each tick lets the
            // transport release delayed/held frames.
            let ticks = self.policy.backoff_base << (attempt - 1).min(6);
            for _ in 0..ticks {
                self.transport.tick();
            }
            self.drain(epoch, round, panels, &frames, &mut got, &mut last_seq, &mut delivered_seq)?;
            if got.iter().all(|g| g.is_some()) {
                break;
            }
            // Still missing after the release window: resend (idempotent
            // — the receiver matches panels by slot and dedups by seq).
            for (idx, f) in frames.iter().enumerate() {
                if got[idx].is_none() {
                    self.stats.retries += 1;
                    self.send_frame(f, epoch, round)?;
                }
            }
            self.drain(epoch, round, panels, &frames, &mut got, &mut last_seq, &mut delivered_seq)?;
        }

        Ok(panels
            .iter()
            .zip(got)
            .zip(delivered_seq)
            .map(|(((spec, _), payload), seq)| (*spec, payload.unwrap(), seq))
            .collect())
    }

    fn send_frame(&mut self, f: &Frame, epoch: usize, round: usize) -> Result<(), TransportError> {
        let bytes = f.encode();
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += bytes.len() as u64;
        self.transport.send(f.dst as usize, bytes)?;
        if self.record_events {
            self.events.push(ExchangeEvent::Sent {
                epoch,
                round,
                src: f.src as usize,
                dst: f.dst as usize,
                mode: f.mode as usize,
                chunk: f.chunk as usize,
                seq: f.seq,
            });
        }
        Ok(())
    }

    /// Empty every mailbox, validating and slotting frames. Damaged
    /// frames are discarded (recovered by resend); protocol violations
    /// abort.
    #[allow(clippy::too_many_arguments)]
    fn drain(
        &mut self,
        epoch: usize,
        round: usize,
        panels: &[(PanelSpec, Vec<u8>)],
        frames: &[Frame],
        got: &mut [Option<Vec<u8>>],
        last_seq: &mut [Option<u64>],
        delivered_seq: &mut [u64],
    ) -> Result<(), TransportError> {
        for dst in 0..self.transport.devices() {
            while let Some(bytes) = self.transport.recv(dst) {
                let frame = match Frame::decode(&bytes) {
                    Ok(f) => f,
                    Err(e @ (TransportError::ChecksumMismatch { .. }
                    | TransportError::Malformed { .. })) => {
                        self.stats.checksum_failures += 1;
                        log_warn!("transport: discarding damaged frame ({e})");
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                // Idempotent dedup: duplicates and stale late arrivals
                // of already-satisfied panels are dropped, never applied.
                if self.satisfied.contains(&frame.seq) {
                    self.stats.duplicates_dropped += 1;
                    continue;
                }
                if frame.epoch as usize != epoch || frame.round as usize != round {
                    return Err(TransportError::EpochRoundMismatch {
                        expected_epoch: epoch,
                        expected_round: round,
                        epoch: frame.epoch as usize,
                        round: frame.round as usize,
                        seq: frame.seq,
                    });
                }
                let idx = frames.iter().position(|f| {
                    f.dst as usize == dst
                        && f.kind == frame.kind
                        && f.mode == frame.mode
                        && f.chunk == frame.chunk
                });
                let Some(idx) = idx else {
                    return Err(TransportError::UnexpectedPanel {
                        dst,
                        mode: frame.mode as usize,
                        chunk: frame.chunk as usize,
                        seq: frame.seq,
                    });
                };
                let expect = &frames[idx];
                if frame.src != expect.src
                    || frame.row_start != expect.row_start
                    || frame.n_rows != expect.n_rows
                    || frame.payload.len() != panels[idx].1.len()
                {
                    return Err(TransportError::Malformed {
                        detail: format!(
                            "panel geometry mismatch at seq {}: got (src {}, rows {}+{}, \
                             {} bytes), expected (src {}, rows {}+{}, {} bytes)",
                            frame.seq,
                            frame.src,
                            frame.row_start,
                            frame.n_rows,
                            frame.payload.len(),
                            expect.src,
                            expect.row_start,
                            expect.n_rows,
                            panels[idx].1.len()
                        ),
                    });
                }
                if got[idx].is_some() {
                    // A resend's copy arriving after the original (or
                    // vice versa) under a different seq.
                    self.stats.duplicates_dropped += 1;
                    continue;
                }
                // Reorder observation: this destination saw a
                // higher-sequence frame earlier.
                if let Some(prev) = last_seq[dst] {
                    if frame.seq < prev {
                        self.stats.reorders += 1;
                    }
                }
                last_seq[dst] = Some(last_seq[dst].map_or(frame.seq, |p| p.max(frame.seq)));
                self.satisfied.insert(frame.seq);
                self.stats.frames_delivered += 1;
                if self.record_events {
                    self.events.push(ExchangeEvent::Delivered {
                        epoch,
                        round,
                        src: frame.src as usize,
                        dst,
                        mode: frame.mode as usize,
                        chunk: frame.chunk as usize,
                        seq: frame.seq,
                    });
                }
                delivered_seq[idx] = frame.seq;
                got[idx] = Some(frame.payload);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(seq: u64, payload: Vec<u8>) -> Frame {
        Frame {
            epoch: 3,
            round: 2,
            src: 1,
            dst: 0,
            kind: PanelKind::Rows,
            mode: 1,
            chunk: 4,
            row_start: 20,
            n_rows: 5,
            seq,
            payload,
        }
    }

    #[test]
    fn frame_roundtrips_bitwise() {
        for payload in [vec![], vec![1u8, 2, 3], (0..=255u8).collect::<Vec<u8>>()] {
            let f = frame(77, payload);
            let bytes = f.encode();
            assert_eq!(Frame::decode(&bytes).unwrap(), f);
            assert_eq!(Frame::peek_src(&bytes), Some(1));
        }
        let mut f = frame(0, vec![9; 16]);
        f.kind = PanelKind::CoreGrad;
        assert_eq!(Frame::decode(&f.encode()).unwrap().kind, PanelKind::CoreGrad);
    }

    #[test]
    fn frame_decode_detects_every_single_bit_flip() {
        let bytes = frame(12, vec![5u8; 40]).encode();
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    Frame::decode(&bad).is_err(),
                    "flip at byte {i} bit {bit} went undetected"
                );
            }
        }
        assert!(matches!(
            Frame::decode(&bytes[..10]),
            Err(TransportError::Malformed { .. })
        ));
        let mut truncated = bytes.clone();
        truncated.truncate(bytes.len() - 4);
        assert!(Frame::decode(&truncated).is_err());
    }

    #[test]
    fn inproc_transport_is_fifo_per_destination() {
        let mut t = InProcTransport::new(2);
        t.send(0, vec![1]).unwrap();
        t.send(1, vec![2]).unwrap();
        t.send(0, vec![3]).unwrap();
        assert_eq!(t.recv(0), Some(vec![1]));
        assert_eq!(t.recv(0), Some(vec![3]));
        assert_eq!(t.recv(0), None);
        assert_eq!(t.recv(1), Some(vec![2]));
        assert!(t.send(5, vec![0]).is_err());
    }

    fn row_panels() -> Vec<(PanelSpec, Vec<u8>)> {
        // Two panels device 1 -> 0, one panel device 0 -> 1.
        let spec = |src_dev, dst_dev, mode, chunk, payload: &[u8]| {
            (
                PanelSpec {
                    kind: PanelKind::Rows,
                    src_dev,
                    dst_dev,
                    mode,
                    chunk,
                    row_start: 4 * chunk,
                    n_rows: payload.len() / 4,
                },
                payload.to_vec(),
            )
        };
        vec![
            spec(1, 0, 0, 1, &[1u8; 16]),
            spec(1, 0, 2, 3, &[2u8; 8]),
            spec(0, 1, 1, 2, &[3u8; 12]),
        ]
    }

    #[test]
    fn healthy_exchange_returns_payloads_in_panel_order() {
        let mut ex = Exchanger::new(2, None);
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        assert_eq!(out.len(), 3);
        for ((spec, payload), (ospec, opayload, _seq)) in panels.iter().zip(&out) {
            assert_eq!(spec, ospec);
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert_eq!(stats.frames_sent, 3);
        assert_eq!(stats.frames_delivered, 3);
        assert_eq!(stats.faults_detected(), 0);
    }

    #[test]
    fn dropped_frames_recover_by_resend() {
        // Deterministic: the injector's rng decides which sends drop;
        // with rate 0.5 over 3 first-sends plus retries, recovery must
        // either complete intact or time out loudly — and for this seed
        // grid at least one run must actually exercise the retry path.
        let mut recovered_with_retries = false;
        for seed in 0..16u64 {
            let plan = FaultPlan {
                seed,
                rate: 0.5,
                kinds: FaultKinds::single(FaultKind::Drop),
                kill: None,
            };
            let mut ex = Exchanger::new(2, Some(plan));
            let panels = row_panels();
            match ex.exchange(0, 1, &panels) {
                Ok(out) => {
                    for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
                        assert_eq!(payload, opayload);
                    }
                    if ex.drain_stats().retries > 0 {
                        recovered_with_retries = true;
                    }
                }
                Err(TransportError::Timeout { .. }) => {}
                Err(e) => panic!("seed {seed}: unexpected error {e}"),
            }
        }
        assert!(recovered_with_retries, "no seed exercised the retry path");
    }

    #[test]
    fn certain_drop_times_out_with_named_error() {
        let plan = FaultPlan {
            seed: 1,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Drop),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::Timeout { missing: 3, .. }), "got {err}");
    }

    #[test]
    fn duplicates_are_deduped_idempotently() {
        let plan = FaultPlan {
            seed: 2,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Duplicate),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert!(stats.duplicates_dropped >= 3, "{stats:?}");
        assert_eq!(stats.retries, 0);
    }

    #[test]
    fn corruption_is_always_detected_never_applied() {
        // Every send (including resends) flips a payload bit, so every
        // arrival must be rejected by the checksum and the exchange must
        // fail loudly — corrupt bytes can never reach the caller.
        let plan = FaultPlan {
            seed: 3,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Corrupt),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::Timeout { .. }), "got {err}");
        let stats = ex.drain_stats();
        assert!(stats.checksum_failures >= 3, "{stats:?}");
        assert_eq!(stats.frames_delivered, 0);
    }

    #[test]
    fn delays_recover_on_ticks_without_resends_or_with_dedup() {
        let plan = FaultPlan {
            seed: 4,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Delay),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
        let stats = ex.drain_stats();
        assert!(stats.timeouts > 0, "delay must cost at least one timeout: {stats:?}");
    }

    #[test]
    fn reorders_are_buffered_and_observed() {
        let plan = FaultPlan {
            seed: 5,
            rate: 1.0,
            kinds: FaultKinds::single(FaultKind::Reorder),
            kill: None,
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let panels = row_panels();
        let out = ex.exchange(0, 1, &panels).unwrap();
        for ((_, payload), (_, opayload, _)) in panels.iter().zip(&out) {
            assert_eq!(payload, opayload);
        }
    }

    #[test]
    fn killed_device_surfaces_as_device_dead() {
        let plan = FaultPlan {
            seed: 6,
            rate: 0.0,
            kinds: FaultKinds::NONE,
            kill: Some(KillSpec { device: 1, after_sends: 0 }),
        };
        let mut ex = Exchanger::new(2, Some(plan));
        let err = ex.exchange(0, 1, &row_panels()).unwrap_err();
        assert!(matches!(err, TransportError::DeviceDead { device: 1 }), "got {err}");
    }

    #[test]
    fn event_log_brackets_every_delivery_inside_its_window() {
        let mut ex = Exchanger::new(2, None);
        ex.enable_event_log();
        let panels = row_panels();
        let out = ex.exchange(1, 2, &panels).unwrap();
        for (spec, _, seq) in &out {
            ex.note_applied(1, 2, spec, *seq);
        }
        ex.note_compute_start(1, 2);
        let events = ex.events();
        assert!(matches!(events[0], ExchangeEvent::BarrierStart { epoch: 1, round: 2 }));
        assert!(matches!(events.last(), Some(ExchangeEvent::ComputeStart { epoch: 1, round: 2 })));
        let sent = events.iter().filter(|e| matches!(e, ExchangeEvent::Sent { .. })).count();
        let delivered =
            events.iter().filter(|e| matches!(e, ExchangeEvent::Delivered { .. })).count();
        let applied =
            events.iter().filter(|e| matches!(e, ExchangeEvent::Applied { .. })).count();
        assert_eq!((sent, delivered, applied), (3, 3, 3));
    }

    #[test]
    fn fault_plan_parsing_is_loud_on_garbage() {
        assert_eq!(FaultPlan::from_vars(None, None, None).unwrap(), None);
        let p = FaultPlan::from_vars(Some("9"), Some("0.25"), Some("drop,corrupt"))
            .unwrap()
            .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rate, 0.25);
        assert!(p.kinds.contains(FaultKind::Drop));
        assert!(p.kinds.contains(FaultKind::Corrupt));
        assert!(!p.kinds.contains(FaultKind::Delay));
        // Partial settings fill defaults.
        let p = FaultPlan::from_vars(None, Some("0.1"), None).unwrap().unwrap();
        assert_eq!(p.kinds, FaultKinds::ALL);
        // Garbage is a typed, named error — never a silent default.
        assert!(matches!(
            FaultPlan::from_vars(Some("not-a-seed"), None, None),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, Some("1.5"), None),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, None, Some("drop,explode")),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
        assert!(matches!(
            FaultPlan::from_vars(None, None, Some("")),
            Err(TransportError::InvalidFaultEnv { .. })
        ));
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("direct"), Some(TransportKind::Direct));
        assert_eq!(TransportKind::parse("Channel"), Some(TransportKind::Channel));
        assert_eq!(TransportKind::parse("auto"), Some(TransportKind::Auto));
        assert_eq!(TransportKind::parse("tcp"), None);
        assert_eq!(TransportKind::Direct.resolve(), TransportKind::Direct);
        assert_eq!(TransportKind::Channel.resolve(), TransportKind::Channel);
    }
}
