//! The multi-device FastTucker engine: M worker threads ("GPUs") execute
//! the Latin-square schedule over the `M^N` block partition, each updating
//! only the factor chunks it owns in the current round (paper Section 5.3).
//!
//! Per epoch:
//! 1. Build (or reuse) the block partition of the training nonzeros.
//! 2. For each of the `M^{N-1}` rounds, run M scoped threads; worker `g`
//!    runs **one batched kernel call** over its block-local nonzeros
//!    (fiber-grouped by [`BatchPlan`], the same Theorem-1/2 math as the
//!    serial engine via [`crate::kernel::batched`]), writing factor rows
//!    through [`SharedFactors`] (disjointness guaranteed by the schedule)
//!    and accumulating core gradients worker-locally.
//! 3. Ledger the parameter exchange the paper's GPUs would perform at each
//!    round boundary, all-reduce the core gradients, apply the core update.
//!    With `transport = channel` the exchange is real: boundary-row
//!    panels and core-gradient panels travel as framed, checksummed
//!    messages through [`crate::parallel::transport`], bitwise-identical
//!    in exact mode and fault-tolerant (retry/dedup/reorder-buffering)
//!    under injection.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, EpochStats, SgdHyper};
use crate::kernel::{
    apply_core_grad_raw, build_strided, planner, BatchPlan, BatchSizing, CoreLayout,
    DispatchPool, Exactness, FiberStats, Lanes, PlanParams, SimdLevel, ThreadCount,
};
use crate::log_warn;
use crate::metrics::{CommLedger, PlanAccum, PlanStats};
use crate::model::{CoreRepr, TuckerModel};
use crate::parallel::device::{DeviceCount, DeviceGrid};
use crate::parallel::shared::{dispatch_plan, SharedFactors};
use crate::parallel::transport::{
    ExchangeEvent, Exchanger, FaultPlan, PanelKind, PanelSpec, PrefetchMode, RoundToken,
    TransportError, TransportKind,
};
use crate::parallel::{BlockPartition, LatinSchedule};
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// How the M workers execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Execution {
    /// Real OS threads — wall-clock speedup on multi-core hosts.
    Threads,
    /// Discrete-event simulation: workers run sequentially, each timed;
    /// a round costs `max` over its workers (what M real devices would
    /// take) and the ledger/figures use that simulated time. This is the
    /// honest mode on single-core testbeds (see DESIGN.md
    /// §Hardware-Adaptation) and is fully deterministic.
    Simulated,
}

impl Execution {
    /// Threads when the host has >1 core, else Simulated.
    pub fn auto() -> Execution {
        match std::thread::available_parallelism() {
            Ok(n) if n.get() > 1 => Execution::Threads,
            _ => Execution::Simulated,
        }
    }
}

/// Options for the multi-device engine.
#[derive(Clone, Copy, Debug)]
pub struct ParallelOptions {
    /// Number of simulated devices M.
    pub workers: usize,
    pub hyper: SgdHyper,
    pub layout: CoreLayout,
    pub execution: Execution,
    /// Batch sizing of the per-block batched kernel calls: `Auto` (the
    /// default) routes through the planner cost model — the same policy
    /// as the serial engine — so caps and fiber-tile widths follow the
    /// dataset instead of a hard-coded constant; `Fixed(n)` pins a
    /// single-fiber cap (`Fixed(0)`/`Fixed(1)` degenerate to scalar-sized
    /// groups).
    pub batch: BatchSizing,
    /// Collision semantics of the blocks' plans (see
    /// [`crate::kernel::plan::Exactness`]).
    pub exactness: Exactness,
    /// Panel-microkernel lane width for the workers' batched kernel
    /// calls (`Auto` = planner-chosen from `R_core`; bitwise-neutral in
    /// exact mode).
    pub lanes: Lanes,
    /// Panel-microkernel SIMD level (ISSUE 10 tentpole): `Auto` =
    /// `FASTTUCKER_SIMD` or runtime feature detection
    /// ([`SimdLevel::resolve`]); every level combines per-lane partial
    /// sums in the scalar association, so exact mode stays bitwise at
    /// any setting.
    pub simd: SimdLevel,
    /// Accumulate the per-sample contraction in f64 while storage stays
    /// f32 (ISSUE 10 tentpole, relaxed mode only): stabler hogwild at
    /// the cost of the pooled dispatch path — wide plans run
    /// sequentially (see
    /// [`dispatch_plan`](crate::parallel::shared::dispatch_plan)).
    pub wide_accum: bool,
    /// Split-group factor (≥ 1, default 1): each worker's plan cuts long
    /// tiled groups into sub-groups at fiber sub-run boundaries (exact
    /// mode — bitwise identical to the unsplit plan, pinned by the
    /// integration tests) or anywhere (relaxed). Sub-groups are the
    /// independently dispatchable work units of split-group execution:
    /// today each Latin worker drains its own sub-groups in order, and
    /// because exact-mode splits are execution-order-neutral the same
    /// plan can be fanned out across more workers (or an in-group thread
    /// pool / the PJRT backend) without changing results.
    pub split: usize,
    /// In-group thread pool width (ISSUE 4 tentpole): each Latin worker
    /// owns a [`DispatchPool`] fanning its plan's split sub-groups across
    /// this many threads. Exact mode executes the sub-group coloring's
    /// barrier-separated waves and stays **bitwise identical** to
    /// sequential dispatch; relaxed mode dispatches one hogwild wave.
    /// `Auto` = `FASTTUCKER_POOL_THREADS` or sequential (see
    /// [`planner::resolve_threads`]).
    pub threads: ThreadCount,
    /// Device-shard grid width (ISSUE 5 tentpole): the `workers` Latin
    /// workers — and with them the training nonzeros and mode-row
    /// ownership — are grouped onto this many virtual devices
    /// ([`DeviceGrid`]), each with its own planner decision and dispatch
    /// pools, a per-round boundary-row exchange, and a fixed-device-order
    /// Eq. 17 core-gradient merge. **Exact mode is bitwise-identical at
    /// every `D`** (the grid only re-labels which device is accounted
    /// for each row-disjoint worker pass); relaxed mode additionally
    /// switches the core merge to the two-stage device tree, inside the
    /// relaxed accuracy envelope. `Auto` = `FASTTUCKER_DEVICES` or one
    /// device per worker (the historical semantics).
    pub devices: DeviceCount,
    /// Exchange path (ISSUE 7 tentpole): `Direct` keeps the historical
    /// shared-memory handover; `Channel` routes every inter-device
    /// boundary-row panel and per-epoch core-gradient panel through the
    /// framed, checksummed [`Transport`](crate::parallel::Transport)
    /// layer — bitwise-identical in exact mode at every `D`, with typed
    /// fault detection and recovery. `Auto` = `FASTTUCKER_TRANSPORT` or
    /// direct.
    pub transport: TransportKind,
    /// Deterministic fault-injection plan for the channel transport
    /// (fault-matrix tests, chaos CI). `None` falls back to the
    /// `FASTTUCKER_FAULT_{SEED,RATE,KINDS}` environment variables. A
    /// plan configured while `transport` resolves to `Direct` cannot
    /// engage — that run is marked degraded, never silently clean.
    pub fault: Option<FaultPlan>,
    /// Async boundary prefetch (ISSUE 8 tentpole): `Async` double-buffers
    /// the round exchange — round r+1's outgoing panels enter the
    /// transport the moment each owning worker finishes its round-r pass
    /// (legal: the Latin schedule gives it exclusive chunk ownership all
    /// round), and are collected + applied at round r+1's barrier,
    /// hiding the transfer behind compute. Because the **apply** never
    /// moves off the barrier, exact mode stays bitwise-identical to the
    /// synchronous path at every `(D, threads, split, transport)`
    /// setting. Requires the channel transport: `Async` over a resolved
    /// `Direct` transport cannot engage and marks the run degraded.
    /// `Auto` = `FASTTUCKER_PREFETCH` or off.
    pub prefetch: PrefetchMode,
    /// Bounded staleness for relaxed-mode prefetch (ISSUE 8): boundary
    /// rows may be applied up to this many rounds late. At each barrier
    /// the engine applies whatever has arrived and defers stragglers,
    /// forcing a blocking collect only when a panel's age reaches the
    /// bound (and at epoch end). `0` — the default, and the only value
    /// exact mode accepts — applies every panel at its own barrier.
    /// `staleness > 0` without relaxed exactness *and* engaged async
    /// prefetch cannot engage and marks the run degraded.
    pub staleness: usize,
    /// Test/tuning override of the transport's delivered-sequence dedup
    /// window (min 2; `None` keeps the transport default). Small windows
    /// let the soak tests cross the prune threshold in a few epochs.
    pub dedup_window: Option<usize>,
}

impl Default for ParallelOptions {
    fn default() -> Self {
        ParallelOptions {
            workers: 2,
            hyper: SgdHyper::default(),
            layout: CoreLayout::Packed,
            execution: Execution::auto(),
            batch: BatchSizing::Auto,
            exactness: Exactness::Exact,
            lanes: Lanes::Auto,
            simd: SimdLevel::Auto,
            wide_accum: false,
            split: 1,
            threads: ThreadCount::Auto,
            devices: DeviceCount::Auto,
            transport: TransportKind::Auto,
            fault: None,
            prefetch: PrefetchMode::Auto,
            staleness: 0,
            dedup_window: None,
        }
    }
}

/// Multi-device FastTucker trainer.
pub struct ParallelFastTucker {
    pub opts: ParallelOptions,
    partition: Option<BlockPartition>,
    /// `(revision, nnz, dims, workers, devices)` — dims included so a
    /// same-sized tensor with a different shape rebuilds the partition
    /// AND the grid (a stale grid's `owned_rows` would mis-slice the
    /// per-device stats, or panic on a shrunken mode 0); the content
    /// revision (ISSUE 9) so a long-lived engine fed appended or swapped
    /// nonzeros — even at identical `(nnz, dims)` — re-derives both.
    partition_for: Option<(u64, usize, Vec<usize>, usize, DeviceCount)>,
    /// The device-shard grid the workers are grouped onto (rebuilt with
    /// the partition; `D = 1 ..= workers`).
    grid: Option<DeviceGrid>,
    /// Degenerate-grid marker (clamped device count, grid wider than the
    /// shortest mode, or an empty device shard) — surfaced on every
    /// worker pass through [`PlanStats::degraded`].
    grid_degraded: bool,
    /// One in-group [`DispatchPool`] per Latin worker (T = 1 degenerates
    /// to the plain per-worker workspace of earlier PRs), sized by its
    /// device's planner decision.
    pools: Vec<DispatchPool>,
    /// Planner decisions for the current dataset, one per device — each
    /// device sizes cap/tile from its own shard's fiber statistics
    /// (resolved in `ensure_state`; indexed by device id).
    device_params: Vec<PlanParams>,
    /// Per-mode-0-row nonzero counts of the current training tensor
    /// (rebuilt with the partition): one shared O(nnz) counting pass
    /// serves the empty-shard degrade check and every device's planner
    /// stats (each shard is a contiguous slice of it).
    mode0_counts: Vec<u32>,
    /// Fingerprint the decisions were made for: `(revision, nnz, dims,
    /// sample count, r_core, j, sizing, exactness, lanes, split, workers,
    /// devices)` — every input the cost model reads (dims + workers +
    /// devices pin the shard geometry `owned_rows` slices by, the
    /// revision pins the fiber statistics to the exact nonzero content),
    /// so the per-device resolution runs once per dataset/config, not
    /// once per epoch.
    #[allow(clippy::type_complexity)]
    device_params_for: Option<(
        u64,
        usize,
        Vec<usize>,
        usize,
        usize,
        usize,
        BatchSizing,
        Exactness,
        Lanes,
        SimdLevel,
        bool,
        usize,
        usize,
        usize,
    )>,
    /// The channel exchanger (ISSUE 7): present when `transport`
    /// resolves to `Channel`, rebuilt with the partition/grid. Fault and
    /// kill state persist across epochs — a device killed by injection
    /// stays dead until the engine is rebuilt (the elastic-recovery
    /// path: reload the checkpoint into a fresh engine).
    exchanger: Option<Exchanger>,
    /// Resolved prefetch engagement (decided with the exchanger in
    /// `ensure_state`): true only when async prefetch is requested AND
    /// the channel transport is live.
    prefetch_async: bool,
    /// Effective staleness bound (0 unless relaxed exactness + engaged
    /// prefetch; see [`ParallelOptions::staleness`]).
    staleness: usize,
    /// Communication ledger accumulated across epochs.
    pub ledger: CommLedger,
    /// Plan observability accumulated across epochs (one record per
    /// worker pass; device occupancy and inter-device comm per epoch).
    pub plan_accum: PlanAccum,
    /// Cache-invalidation observability (ISSUE 9): how many times each
    /// fingerprint-guarded state block was (re)derived over this engine's
    /// lifetime. A long-lived session asserts on these to prove an append
    /// dropped exactly the touched state — and that epochs on unchanged
    /// data dropped nothing.
    rebuilds: EngineRebuilds,
}

/// Rebuild counters for the fingerprint-guarded engine state (PlanAccum
/// style: plain monotone `u64`s, snapshot by value).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineRebuilds {
    /// Partition + device grid + exchanger rebuilds (the
    /// `(revision, nnz, dims, workers, devices)` fingerprint missed).
    pub partition: u64,
    /// Per-device planner re-decisions (the full cost-model fingerprint
    /// missed).
    pub planner: u64,
}

impl ParallelFastTucker {
    pub fn new(opts: ParallelOptions) -> Self {
        assert!(opts.workers >= 1);
        ParallelFastTucker {
            opts,
            partition: None,
            partition_for: None,
            grid: None,
            grid_degraded: false,
            exchanger: None,
            prefetch_async: false,
            staleness: 0,
            pools: Vec::new(),
            mode0_counts: Vec::new(),
            device_params: Vec::new(),
            device_params_for: None,
            ledger: CommLedger::new(),
            plan_accum: PlanAccum::new(),
            rebuilds: EngineRebuilds::default(),
        }
    }

    /// Lifetime rebuild counters of the fingerprint-guarded state (see
    /// [`EngineRebuilds`]).
    pub fn rebuilds(&self) -> EngineRebuilds {
        self.rebuilds
    }

    fn ensure_state(
        &mut self,
        train: &SparseTensor,
        order: usize,
        r_core: usize,
        j: usize,
    ) -> AlgoResult<()> {
        let fp = (
            train.revision(),
            train.nnz(),
            train.dims().to_vec(),
            self.opts.workers,
            self.opts.devices,
        );
        if self.partition_for.as_ref() != Some(&fp) {
            self.rebuilds.partition += 1;
            // Checked build: an overflowing M^N block space surfaces as a
            // typed error before any allocation (ISSUE 4 satellite; the
            // grid constructor carries the same guard).
            self.partition = Some(BlockPartition::try_build(train, self.opts.workers)?);
            let grid = DeviceGrid::try_new(self.opts.devices, self.opts.workers, train.dims())?;
            // One O(nnz) counting pass serves both the empty-shard check
            // below and the per-device planner stats (a shard's size is
            // the sum of its contiguous counts slice — equal to
            // `grid.shard_sizes`, without another tensor walk).
            self.mode0_counts = FiberStats::mode0_counts(train);
            // Division-step degrade check: a grid leaving a device with
            // an empty shard (more devices than busy mode-0 chunks —
            // e.g. a one-nnz tensor on D ≥ 2) is degenerate but must
            // train, not panic (ISSUE 5 satellite).
            let mut degraded = grid.degraded();
            if grid.devices() > 1 {
                let sizes = grid.shard_sizes_from_counts(&self.mode0_counts);
                if sizes.iter().any(|&c| c == 0) {
                    log_warn!(
                        "device grid: shard sizes {sizes:?} leave a device idle — \
                         degenerate division (recorded in PlanStats::degraded)"
                    );
                    degraded = true;
                }
            }
            // strict-audit: independently re-verify levels 0 + 1 of the
            // disjointness contract (device grid + the Latin schedule it
            // coarsens) with the first-principles auditor before any
            // worker touches the factors (`crate::analysis::audit`).
            #[cfg(feature = "strict-audit")]
            {
                let schedule = LatinSchedule::try_new(self.opts.workers, order)?;
                crate::analysis::audit_schedule_and_grid(&grid, &schedule, train)
                    .assert_clean("device grid / Latin schedule");
            }
            // ISSUE 7: the exchange path is decided with the grid. A
            // programmatic fault plan wins over the environment; a plan
            // that cannot engage (direct transport) is a degraded run,
            // never a silent ignore. Invalid FASTTUCKER_FAULT_* values
            // abort with a typed error.
            let fault = match self.opts.fault {
                Some(plan) => Some(plan),
                None => FaultPlan::from_env()?,
            };
            self.exchanger = match self.opts.transport.resolve() {
                TransportKind::Channel => {
                    let mut ex = Exchanger::new(grid.devices(), fault);
                    ex.enable_event_log();
                    Some(ex)
                }
                _ => {
                    if fault.is_some() {
                        log_warn!(
                            "a FaultPlan is configured but the transport resolves to \
                             direct — fault injection cannot engage (recorded in \
                             PlanStats::degraded)"
                        );
                        degraded = true;
                    }
                    None
                }
            };
            if let (Some(ex), Some(w)) = (self.exchanger.as_mut(), self.opts.dedup_window) {
                ex.set_dedup_window(w);
            }
            // ISSUE 8: async prefetch engages only on the channel
            // transport — the direct handover has no transfer to hide. A
            // requested async that cannot engage is a degraded run, the
            // same rule as the fault plan above.
            self.prefetch_async =
                match (self.opts.prefetch.resolve(), self.exchanger.is_some()) {
                    (PrefetchMode::Async, true) => true,
                    (PrefetchMode::Async, false) => {
                        log_warn!(
                            "async prefetch is configured but the transport resolves \
                             to direct — there is no transfer to overlap (recorded \
                             in PlanStats::degraded)"
                        );
                        degraded = true;
                        false
                    }
                    _ => false,
                };
            // Bounded staleness is the relaxed-mode prefetch variant;
            // exact mode owes every panel to its own barrier.
            self.staleness = if self.opts.staleness == 0 {
                0
            } else if self.prefetch_async && self.opts.exactness == Exactness::Relaxed {
                self.opts.staleness
            } else {
                log_warn!(
                    "staleness = {} requires relaxed exactness and engaged async \
                     prefetch — applying panels at their own barriers instead \
                     (recorded in PlanStats::degraded)",
                    self.opts.staleness
                );
                degraded = true;
                0
            };
            self.grid_degraded = degraded;
            self.grid = Some(grid);
            self.partition_for = Some(fp);
        }
        // One planner decision per DEVICE, each from its own shard's
        // mode-0 fiber statistics (a device visits its whole shard every
        // epoch, so shard-level stats are the right input — the device
        // analogue of the historical per-dataset rationale). Exact-mode
        // bitwise identity across D does not require the decisions to
        // agree: a plan's sample order ignores every capacity parameter
        // (see `kernel::plan`). Scalar-degenerate sizings map to cap 1.
        // Cached on every cost-model input so the O(nnz) counting pass
        // runs once per dataset/config, not per epoch.
        let grid = self.grid.as_ref().unwrap();
        let m = ((train.nnz() as f64) * self.opts.hyper.sample_frac)
            .round()
            .max(1.0) as usize;
        let params_fp = (
            train.revision(),
            train.nnz(),
            train.dims().to_vec(),
            m,
            r_core,
            j,
            self.opts.batch,
            self.opts.exactness,
            self.opts.lanes,
            self.opts.simd,
            self.opts.wide_accum,
            self.opts.split,
            self.opts.workers,
            grid.devices(),
        );
        if self.device_params_for.as_ref() != Some(&params_fp) {
            self.rebuilds.planner += 1;
            self.device_params = match self.opts.batch {
                BatchSizing::Fixed(_) => {
                    let p = self
                        .opts
                        .batch
                        .resolve(
                            train,
                            m,
                            order,
                            r_core,
                            j,
                            self.opts.exactness,
                            self.opts.lanes,
                            self.opts.simd,
                            self.opts.split,
                        )
                        .unwrap_or(PlanParams {
                            max_batch: 1,
                            exactness: self.opts.exactness,
                            ..Default::default()
                        })
                        .with_wide_accum(self.opts.wide_accum);
                    vec![p; grid.devices()]
                }
                BatchSizing::Auto => {
                    // The counting pass from the partition rebuild,
                    // sliced per device (each shard is a contiguous
                    // mode-0 row range).
                    let counts = &self.mode0_counts;
                    (0..grid.devices())
                        .map(|dev| {
                            let (lo, hi) = grid.owned_rows(dev, 0);
                            let mut slice = counts[lo..hi].to_vec();
                            let shard: usize =
                                slice.iter().map(|&c| c as usize).sum();
                            let hint = ((shard as f64) * self.opts.hyper.sample_frac)
                                .round()
                                .max(1.0) as usize;
                            let stats =
                                FiberStats::from_mode0_counts(&mut slice).scaled_to(hint);
                            planner::choose_params(
                                &stats,
                                order,
                                r_core,
                                j,
                                self.opts.exactness,
                                self.opts.lanes,
                                self.opts.simd,
                                self.opts.split,
                            )
                            .with_wide_accum(self.opts.wide_accum)
                        })
                        .collect()
                }
            };
            self.device_params_for = Some(params_fp);
        }
        let threads = planner::resolve_threads(self.opts.threads, self.opts.exactness);
        let stale = self.pools.len() != self.opts.workers
            || self.pools.iter().enumerate().any(|(g, p)| {
                let cap = self.device_params[grid.device_of(g)].max_batch;
                p.shape() != (order, r_core, j, cap) || p.threads() != threads
            });
        if stale {
            self.pools = (0..self.opts.workers)
                .map(|g| {
                    let cap = self.device_params[grid.device_of(g)].max_batch;
                    DispatchPool::new(threads, order, r_core, j, cap)
                })
                .collect();
        }
        Ok(())
    }

    /// One multi-device epoch. Returns stats; communication volume goes to
    /// `self.ledger`.
    pub fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            CoreRepr::Dense(_) => {
                return Err(AlgoError::core_mismatch("parallel/fasttucker", "Kruskal", "dense"))
            }
        };
        let (order, r_core, j) = (core.order(), core.rank(), core.j(0));
        self.ensure_state(train, order, r_core, j)?;
        let m = self.opts.workers;
        let h = self.opts.hyper;
        let layout = self.opts.layout;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);
        let strided = if layout == CoreLayout::Strided {
            build_strided(&core)
        } else {
            Vec::new()
        };

        let schedule = LatinSchedule::try_new(m, order)?;
        let partition = self.partition.as_ref().unwrap();
        let grid = self.grid.as_ref().unwrap();
        let grid_degraded = self.grid_degraded;
        let n_devices = grid.devices();

        // Per-worker RNG streams, forked deterministically (in global
        // worker order, independent of the device grouping — part of the
        // exact-mode D-invariance contract).
        let mut worker_rngs: Vec<Rng> = (0..m).map(|_| rng.fork()).collect();

        if let Some(ex) = self.exchanger.as_mut() {
            // One epoch's audit window: the event log is drained per
            // epoch (see `exchange_events`) and stays bounded.
            ex.clear_events();
        }
        let execution = self.opts.execution;
        let t0 = Instant::now();
        let mut samples = 0usize;
        let mut simulated_secs = 0.0f64;
        let mut device_samples = vec![0u64; n_devices];
        let mut comm_rows = 0u64;
        let mut comm_bytes = 0u64;
        // ISSUE 8 overlap accounting: panels issued ahead of their
        // barrier, exchange seconds hidden behind compute (worker-side
        // serialize/issue/poll) vs exposed (coordinator blocking at a
        // barrier).
        let use_async = self.prefetch_async && self.exchanger.is_some();
        let staleness = self.staleness;
        let mut prefetch_issued = 0u64;
        let mut hidden_secs = 0.0f64;
        let mut exposed_secs = 0.0f64;
        // The per-epoch core-merge token when the merge is pipelined
        // (opened at the last round's barrier, collected after the loop).
        let mut merge_token: Option<RoundToken> = None;
        let mut epoch_err: Option<TransportError> = None;
        #[cfg(feature = "shadow-ledger")]
        crate::analysis::shadow::set_epoch(epoch);
        {
            let shared = SharedFactors::new(&mut model.factors);
            // Under async prefetch the exchanger leaves `self` for the
            // round loop so worker threads can issue outgoing panels
            // through a shared lock the moment their pass ends; the
            // coordinator keeps using it at the barriers via the same
            // lock, and it returns to `self` for the core merge below.
            let ex_mutex: Option<Mutex<Exchanger>> =
                if use_async { self.exchanger.take().map(Mutex::new) } else { None };
            // Rows panels in flight ahead of their barrier, oldest
            // first: `(token, round, slots outstanding)`. Exact mode
            // never holds more than one (forced collect at age 0);
            // relaxed holds up to `staleness + 1`.
            let mut inflight: VecDeque<(RoundToken, usize, usize)> = VecDeque::new();
            for round in 0..schedule.rounds() {
                #[cfg(feature = "shadow-ledger")]
                crate::analysis::shadow::set_round(round);
                let assignments = schedule.round_assignments(round);
                // Parameter-exchange bookkeeping at the round boundary,
                // in fixed (dst worker, mode) order — the apply order of
                // both exchange paths. The per-worker ledger keeps the
                // historical "each worker is a GPU" accounting; the
                // inter-device counters count only rows that actually
                // cross a device boundary (intra-device handovers are
                // free).
                let handovers = grid.round_handovers(&schedule, round);
                for ho in &handovers {
                    self.ledger.record_factor_exchange((ho.n_rows * j * 4) as u64);
                    if ho.crosses {
                        comm_rows += ho.n_rows as u64;
                        comm_bytes += (ho.n_rows * j * 4) as u64;
                    }
                }
                let mut prefetch_round: Option<PrefetchRound> = None;
                if let Some(mx) = &ex_mutex {
                    // Async barrier: apply this round's prefetched
                    // panels (issued while the previous round computed)
                    // plus any relaxed-mode stragglers whose staleness
                    // bound is due. The transfer moved early; the apply
                    // itself never leaves the barrier, and the
                    // coordinator is the only live actor here, so the
                    // writes cannot race.
                    let ex = &mut *mx.lock().unwrap();
                    if let Err(e) = drain_due_prefetch(
                        ex,
                        &shared,
                        &mut inflight,
                        epoch,
                        round,
                        staleness,
                        j,
                        &mut exposed_secs,
                    ) {
                        epoch_err = Some(e);
                        break;
                    }
                    ex.note_compute_start(epoch, round);
                    // Open the next barrier's panels before this round
                    // computes: headers + deterministic sequence numbers
                    // now (in spec order), payloads issued post-pass by
                    // their owning workers. The last round opens the
                    // per-epoch core-merge panels instead — each
                    // worker's Eq. 17 gradient is final after its last
                    // pass.
                    let next = round + 1;
                    let mut specs: Vec<PanelSpec> = Vec::new();
                    let mut jobs: Vec<Vec<PrefetchSlot>> = vec![Vec::new(); m];
                    if next < schedule.rounds() {
                        for ho in grid.round_handovers(&schedule, next) {
                            if !ho.crosses {
                                continue;
                            }
                            jobs[ho.src_worker].push(PrefetchSlot::Rows {
                                idx: specs.len(),
                                mode: ho.mode,
                                row_start: ho.row_start,
                                n_rows: ho.n_rows,
                            });
                            specs.push(PanelSpec {
                                kind: PanelKind::Rows,
                                src_dev: grid.device_of(ho.src_worker),
                                dst_dev: grid.device_of(ho.dst_worker),
                                mode: ho.mode,
                                chunk: ho.chunk,
                                row_start: ho.row_start,
                                n_rows: ho.n_rows,
                            });
                        }
                    } else if h.update_core
                        && self.opts.exactness == Exactness::Exact
                        && n_devices > 1
                    {
                        let root_end = grid.workers_of(0).end;
                        for g in root_end..m {
                            jobs[g].push(PrefetchSlot::CoreGrad { idx: specs.len() });
                            specs.push(PanelSpec {
                                kind: PanelKind::CoreGrad,
                                src_dev: grid.device_of(g),
                                dst_dev: 0,
                                mode: 0,
                                chunk: g,
                                row_start: 0,
                                n_rows: 0,
                            });
                        }
                    }
                    if !specs.is_empty() {
                        match ex.begin_round(epoch, next, &specs) {
                            Ok(token) => {
                                if next < schedule.rounds() {
                                    inflight.push_back((token, next, specs.len()));
                                } else {
                                    merge_token = Some(token);
                                }
                                prefetch_round = Some(PrefetchRound { token, jobs, j });
                            }
                            Err(e) => {
                                epoch_err = Some(e);
                                break;
                            }
                        }
                    }
                } else if self.exchanger.is_some() {
                    // Synchronous channel exchange: the boundary rows
                    // travel as framed, checksummed messages and are
                    // written back from the *validated* payloads — a
                    // bitwise no-op when healthy (exact little-endian
                    // f32 round-trip), a typed error when unrecoverable.
                    // The coordinator is the only live actor at the
                    // barrier, so the writes cannot race.
                    let mut panels: Vec<(PanelSpec, Vec<u8>)> = Vec::new();
                    for ho in &handovers {
                        if !ho.crosses {
                            continue;
                        }
                        let spec = PanelSpec {
                            kind: PanelKind::Rows,
                            src_dev: grid.device_of(ho.src_worker),
                            dst_dev: grid.device_of(ho.dst_worker),
                            mode: ho.mode,
                            chunk: ho.chunk,
                            row_start: ho.row_start,
                            n_rows: ho.n_rows,
                        };
                        let payload = rows_payload(
                            &shared,
                            ho.mode,
                            ho.row_start,
                            ho.row_start + ho.n_rows,
                            j,
                        );
                        panels.push((spec, payload));
                    }
                    let ex = self.exchanger.as_mut().unwrap();
                    let tx = Instant::now();
                    let delivered = ex.exchange(epoch, round, &panels)?;
                    if !panels.is_empty() {
                        exposed_secs += tx.elapsed().as_secs_f64();
                    }
                    for (spec, payload, seq) in &delivered {
                        apply_rows_payload(&shared, spec, payload, j);
                        ex.note_applied(epoch, round, spec, *seq);
                    }
                    ex.note_compute_start(epoch, round);
                }
                let prefetch_ctx = ex_mutex.as_ref().zip(prefetch_round.as_ref());
                let (count, round_secs, round_plans, pf) = match execution {
                    Execution::Threads => run_round_threads(
                        &shared,
                        &core,
                        &strided,
                        layout,
                        train,
                        partition,
                        &assignments,
                        &mut self.pools,
                        &mut worker_rngs,
                        lr_f,
                        h,
                        grid,
                        &self.device_params,
                        grid_degraded,
                        &mut device_samples,
                        prefetch_ctx,
                    ),
                    Execution::Simulated => run_round_simulated(
                        &shared,
                        &core,
                        &strided,
                        layout,
                        train,
                        partition,
                        &assignments,
                        &mut self.pools,
                        &mut worker_rngs,
                        lr_f,
                        h,
                        grid,
                        &self.device_params,
                        grid_degraded,
                        &mut device_samples,
                        prefetch_ctx,
                    ),
                };
                samples += count;
                simulated_secs += round_secs;
                self.plan_accum.merge(&round_plans);
                prefetch_issued += pf.issued;
                hidden_secs += pf.hidden_secs;
                if let Some(e) = pf.err {
                    epoch_err = Some(e);
                    break;
                }
            }
            // Epoch-end barrier: anything still deferred by the relaxed
            // staleness bound is due now — epochs stay self-contained
            // (staleness never crosses an epoch, and every audit window
            // closes before the event log is read).
            if epoch_err.is_none() {
                if let Some(mx) = &ex_mutex {
                    let ex = &mut *mx.lock().unwrap();
                    if let Err(e) = drain_due_prefetch(
                        ex,
                        &shared,
                        &mut inflight,
                        epoch,
                        schedule.rounds(),
                        0,
                        j,
                        &mut exposed_secs,
                    ) {
                        epoch_err = Some(e);
                    }
                }
            }
            if let Some(mx) = ex_mutex {
                self.exchanger = Some(mx.into_inner().unwrap());
            }
        }
        if let Some(e) = epoch_err {
            return Err(AlgoError::Transport(e));
        }
        // Threads mode reports wall time; Simulated mode reports the
        // discrete-event parallel time (sum over rounds of the slowest
        // *device*, each device executing its workers serially).
        let factor_secs = match execution {
            Execution::Threads => t0.elapsed().as_secs_f64(),
            Execution::Simulated => simulated_secs,
        };

        // Core all-reduce + update (Eq. 17 merge in fixed device order).
        let t1 = Instant::now();
        let mut core_secs = 0.0;
        if h.update_core {
            // Each pool's gradient lives wholly on its primary workspace
            // (the DispatchPool invariant: sequential passes and the
            // exact tape replay both target it).
            match self.opts.exactness {
                Exactness::Exact => match self.exchanger.as_mut() {
                    Some(ex) if n_devices > 1 => {
                        // Channel path, same flat fold in global worker
                        // order: the root device's pools fold locally;
                        // every off-root pool ships its (grad, count) as
                        // a CoreGrad panel to the root. Worker ranges
                        // are contiguous and panels come back in send
                        // order, so the fold order — and the bits —
                        // match the direct handover exactly.
                        let root_end = grid.workers_of(0).end;
                        let (head, tail) = self.pools.split_at_mut(root_end);
                        let (first, rest) = head.split_at_mut(1);
                        let (grad0, count0) = first[0].core_grad_mut();
                        for ws in rest.iter_mut() {
                            let (grad, count) = ws.core_grad_mut();
                            crate::kernel::batched::merge_core_grad(grad0, count0, grad, count);
                        }
                        let merge_round = schedule.rounds();
                        let t2 = Instant::now();
                        let delivered: Vec<(PanelSpec, Vec<u8>, u64)> =
                            if let Some(token) = merge_token.take() {
                                // Pipelined merge (ISSUE 8): the off-root
                                // gradients entered the transport as each
                                // worker's last pass ended (which also
                                // zeroed its pool's gradient, mirroring
                                // merge_core_grad's source-zeroing);
                                // collect and fold here in spec (= global
                                // worker) order — the same flat fold, the
                                // same bits as the synchronous panels.
                                ex.collect(token)?
                                    .into_iter()
                                    .map(|(_, spec, payload, seq)| (spec, payload, seq))
                                    .collect()
                            } else {
                                let mut panels: Vec<(PanelSpec, Vec<u8>)> = Vec::new();
                                for (off, ws) in tail.iter_mut().enumerate() {
                                    let g = root_end + off;
                                    let (grad, count) = ws.core_grad_mut();
                                    panels.push((
                                        PanelSpec {
                                            kind: PanelKind::CoreGrad,
                                            src_dev: grid.device_of(g),
                                            dst_dev: 0,
                                            mode: 0,
                                            chunk: g,
                                            row_start: 0,
                                            n_rows: 0,
                                        },
                                        core_grad_payload(grad, *count),
                                    ));
                                    // Mirror merge_core_grad's
                                    // source-zeroing: the panel now owns
                                    // the gradient.
                                    grad.fill(0.0);
                                    *count = 0;
                                }
                                ex.exchange(epoch, merge_round, &panels)?
                            };
                        exposed_secs += t2.elapsed().as_secs_f64();
                        let mut scratch = vec![0.0f32; grad0.len()];
                        for (spec, payload, seq) in &delivered {
                            let mut cnt = read_core_grad_payload(payload, &mut scratch);
                            crate::kernel::batched::merge_core_grad(
                                grad0,
                                count0,
                                &mut scratch,
                                &mut cnt,
                            );
                            ex.note_applied(epoch, merge_round, spec, *seq);
                        }
                        ex.note_compute_start(epoch, merge_round);
                    }
                    _ => {
                        // Flat left fold in global worker order — the
                        // bitwise contract. Identical at every D: device
                        // worker ranges are contiguous, so device-major
                        // order IS worker order and the fold never
                        // reassociates.
                        let (first, rest) = self.pools.split_at_mut(1);
                        let (grad0, count0) = first[0].core_grad_mut();
                        for ws in rest.iter_mut() {
                            let (grad, count) = ws.core_grad_mut();
                            crate::kernel::batched::merge_core_grad(grad0, count0, grad, count);
                        }
                    }
                },
                Exactness::Relaxed => {
                    // The paper's two-stage all-reduce tree: device-local
                    // fold (free), then one gradient panel per non-root
                    // device, merged in fixed device order. Reassociates
                    // the f32 sums — covered by the relaxed accuracy
                    // envelope, not the bitwise contract. At D = workers
                    // the local folds are no-ops and this degenerates to
                    // the flat fold.
                    for dev in 0..n_devices {
                        let r = grid.workers_of(dev);
                        let dev_pools = &mut self.pools[r.start..r.end];
                        let (first, rest) = dev_pools.split_at_mut(1);
                        let (grad0, count0) = first[0].core_grad_mut();
                        for ws in rest.iter_mut() {
                            let (grad, count) = ws.core_grad_mut();
                            crate::kernel::batched::merge_core_grad(
                                grad0, count0, grad, count,
                            );
                        }
                    }
                    match self.exchanger.as_mut() {
                        Some(ex) if n_devices > 1 => {
                            // The tree's inter-device stage over the
                            // channel: one pre-folded panel per non-root
                            // device leader, merged in device order
                            // (panel order == send order).
                            let merge_round = schedule.rounds();
                            let mut panels: Vec<(PanelSpec, Vec<u8>)> = Vec::new();
                            for dev in 1..n_devices {
                                let leader = grid.workers_of(dev).start;
                                let (grad, count) = self.pools[leader].core_grad_mut();
                                panels.push((
                                    PanelSpec {
                                        kind: PanelKind::CoreGrad,
                                        src_dev: dev,
                                        dst_dev: 0,
                                        mode: 0,
                                        chunk: leader,
                                        row_start: 0,
                                        n_rows: 0,
                                    },
                                    core_grad_payload(grad, *count),
                                ));
                                grad.fill(0.0);
                                *count = 0;
                            }
                            let t2 = Instant::now();
                            let delivered = ex.exchange(epoch, merge_round, &panels)?;
                            exposed_secs += t2.elapsed().as_secs_f64();
                            let (grad0, count0) = self.pools[0].core_grad_mut();
                            let mut scratch = vec![0.0f32; grad0.len()];
                            for (spec, payload, seq) in &delivered {
                                let mut cnt = read_core_grad_payload(payload, &mut scratch);
                                crate::kernel::batched::merge_core_grad(
                                    grad0,
                                    count0,
                                    &mut scratch,
                                    &mut cnt,
                                );
                                ex.note_applied(epoch, merge_round, spec, *seq);
                            }
                            ex.note_compute_start(epoch, merge_round);
                        }
                        _ => {
                            for dev in 1..n_devices {
                                let leader = grid.workers_of(dev).start;
                                let (head, tail) = self.pools.split_at_mut(leader);
                                let (grad0, count0) = head[0].core_grad_mut();
                                let (grad, count) = tail[0].core_grad_mut();
                                crate::kernel::batched::merge_core_grad(grad0, count0, grad, count);
                            }
                        }
                    }
                }
            }
            // Inter-device Eq. 17 traffic. Exact mode's flat fold cannot
            // pre-reduce panels on their device (that reassociation is
            // exactly what the relaxed tree does), so every worker pool
            // off the root device ships its own panel; the relaxed tree
            // ships one pre-folded panel per non-root device.
            let shipped_panels = match self.opts.exactness {
                Exactness::Exact => (m - grid.workers_of(0).len()) as u64,
                Exactness::Relaxed => n_devices as u64 - 1,
            };
            comm_bytes += shipped_panels * (order * r_core * j * 4) as u64;
            self.ledger
                .record_core_allreduce((m * order * r_core * j * 4) as u64);
            let core_mut = match &mut model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            let (grad0, count0) = self.pools[0].core_grad_mut();
            apply_core_grad_raw(grad0, count0, core_mut, lr_c, h.lambda_core);
            core_secs = t1.elapsed().as_secs_f64();
        }

        // Per-device observability: grid width, the busiest device's
        // sample share (occupancy), and the epoch's inter-device traffic.
        let max_device = device_samples.iter().copied().max().unwrap_or(0);
        self.plan_accum
            .record_device_epoch(n_devices, samples as u64, max_device);
        self.plan_accum.record_comm(comm_rows, comm_bytes);

        // Transport observability: recovered faults are loud — counters
        // in the accumulator plus a warning — but NOT `degraded`, which
        // stays reserved for geometry/config trouble (a transparently
        // recovered exchange is still a correct exchange).
        if let Some(ex) = self.exchanger.as_mut() {
            // Overlap observability (ISSUE 8): how much of the exchange
            // cost compute hid this epoch. A synchronous channel run
            // records only exposed seconds (efficiency 0); an async run
            // with healthy delivery hides nearly everything.
            self.plan_accum.record_overlap(prefetch_issued, hidden_secs, exposed_secs);
            let ts = ex.drain_stats();
            self.plan_accum.record_transport(&ts);
            if ts.faults_detected() > 0 {
                log_warn!(
                    "transport recovered faults this epoch: {} retries, {} duplicates \
                     dropped, {} checksum failures, {} reorders, {} timeouts",
                    ts.retries,
                    ts.duplicates_dropped,
                    ts.checksum_failures,
                    ts.reorders,
                    ts.timeouts
                );
            }
            // strict-audit: independently re-verify the in-flight
            // exchange protocol (every delivered panel applied exactly
            // once, within its staleness bound — 0 in exact mode, where
            // every apply lands at its own barrier even under async
            // prefetch) from the event stream.
            #[cfg(feature = "strict-audit")]
            crate::analysis::audit_exchange_with_staleness(ex.events(), self.staleness)
                .assert_clean("in-flight exchange protocol");
        }

        Ok(EpochStats { samples, factor_secs, core_secs })
    }

    /// The channel exchanger's event log for the most recent epoch
    /// (empty under the direct transport) — the input of the in-flight
    /// exchange auditor ([`crate::analysis::audit_exchange`]).
    pub fn exchange_events(&self) -> &[ExchangeEvent] {
        self.exchanger.as_ref().map(|ex| ex.events()).unwrap_or(&[])
    }
}

/// One round's prefetch work order (ISSUE 8): the token opened at the
/// round's barrier for panels due at a *later* barrier, plus, per
/// worker, the slots that worker must serialize and issue into the
/// exchanger the moment its pass ends.
struct PrefetchRound {
    token: RoundToken,
    /// Per-worker slot lists (indexed by global Latin worker id).
    jobs: Vec<Vec<PrefetchSlot>>,
    /// Columns per factor row (payload geometry for `Rows` slots).
    j: usize,
}

/// One prefetch slot; `idx` is the slot's position in its round's spec
/// order — the exchanger's issue key.
#[derive(Clone, Copy, Debug)]
enum PrefetchSlot {
    /// Boundary rows `row_start .. row_start + n_rows` of `mode`, owned
    /// (and last written) by the issuing worker this round.
    Rows { idx: usize, mode: usize, row_start: usize, n_rows: usize },
    /// The worker's complete Eq. 17 core-gradient block — issued only
    /// after the worker's *last* round pass, when the gradient is final
    /// (the issue zeroes the pool's gradient, like `merge_core_grad`).
    CoreGrad { idx: usize },
}

/// What a round runner observed of the prefetch path: slots issued,
/// seconds of exchange work hidden behind compute, and the first
/// transport error a worker hit while issuing (surfaced after the
/// round — the barrier would otherwise time out on the missing frames).
#[derive(Default)]
struct PrefetchOutcome {
    issued: u64,
    hidden_secs: f64,
    err: Option<TransportError>,
}

/// Post-pass prefetch issue (ISSUE 8): serialize and send this worker's
/// outgoing slots. Runs on the worker's own thread while other workers
/// may still be computing — sound because the Latin schedule gives the
/// worker exclusive ownership of every row it serializes for the whole
/// round (see `SharedFactors::row_exchange`'s contract), and the
/// exchanger is behind the shared lock.
fn issue_prefetch_slots(
    ex: &Mutex<Exchanger>,
    pr: &PrefetchRound,
    slots: &[PrefetchSlot],
    shared: &SharedFactors,
    pool: &mut DispatchPool,
) -> (u64, f64, Option<TransportError>) {
    if slots.is_empty() {
        return (0, 0.0, None);
    }
    let t0 = Instant::now();
    let mut issued = 0u64;
    let mut err = None;
    let mut ex = ex.lock().unwrap();
    for slot in slots {
        let (idx, payload) = match *slot {
            PrefetchSlot::Rows { idx, mode, row_start, n_rows } => {
                (idx, rows_payload(shared, mode, row_start, row_start + n_rows, pr.j))
            }
            PrefetchSlot::CoreGrad { idx } => {
                let (grad, count) = pool.core_grad_mut();
                let payload = core_grad_payload(grad, *count);
                // Mirror merge_core_grad's source-zeroing: the panel
                // now owns the gradient.
                grad.fill(0.0);
                *count = 0;
                (idx, payload)
            }
        };
        if let Err(e) = ex.issue(pr.token, idx, payload) {
            err = Some(e);
            break;
        }
        issued += 1;
    }
    // Drain whatever already arrived inside the hidden window, so the
    // next barrier finds its completion set as full as possible.
    if err.is_none() {
        if let Err(e) = ex.poll() {
            err = Some(e);
        }
    }
    (issued, t0.elapsed().as_secs_f64(), err)
}

/// Execute one scheduling round on real threads; returns (samples, wall
/// secs of the round, merged plan stats, prefetch outcome). Workers
/// spawn individually (the Latin level makes them row-disjoint
/// regardless of their device), the device grid only attributes each
/// pass to its device. With a prefetch context, each worker issues its
/// outgoing next-round panels right after its own pass — while the
/// other workers are still computing, which is where the hidden-comm
/// overlap comes from.
#[allow(clippy::too_many_arguments)]
fn run_round_threads(
    shared: &SharedFactors,
    core: &crate::kruskal::KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    train: &SparseTensor,
    partition: &BlockPartition,
    assignments: &[Vec<usize>],
    pools: &mut [DispatchPool],
    rngs: &mut [Rng],
    lr_f: f32,
    h: SgdHyper,
    grid: &DeviceGrid,
    device_params: &[PlanParams],
    grid_degraded: bool,
    device_samples: &mut [u64],
    prefetch: Option<(&Mutex<Exchanger>, &PrefetchRound)>,
) -> (usize, f64, PlanAccum, PrefetchOutcome) {
    let t0 = Instant::now();
    let mut samples = 0usize;
    let mut plans = PlanAccum::new();
    let mut outcome = PrefetchOutcome::default();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for ((g, pool), wrng) in (0..assignments.len())
            .zip(pools.iter_mut())
            .zip(rngs.iter_mut())
        {
            let block = partition.block(&assignments[g]);
            let params = device_params[grid.device_of(g)];
            let job = prefetch.map(|(mx, pr)| (mx, pr, pr.jobs[g].as_slice()));
            let handle = scope.spawn(move || {
                #[cfg(feature = "shadow-ledger")]
                crate::analysis::shadow::set_worker(g);
                let (count, stats) = worker_pass(
                    shared, core, strided, layout, train, block, pool, wrng, lr_f, h, params,
                );
                let (issued, hidden, err) = match job {
                    Some((mx, pr, slots)) => issue_prefetch_slots(mx, pr, slots, shared, pool),
                    None => (0, 0.0, None),
                };
                (count, stats, issued, hidden, err)
            });
            handles.push(handle);
        }
        for (g, hdl) in handles.into_iter().enumerate() {
            let (count, stats, issued, hidden, err) = hdl.join().expect("worker panicked");
            samples += count;
            let dev = grid.device_of(g);
            device_samples[dev] += count as u64;
            if let Some(mut s) = stats {
                s.device = dev;
                s.degraded |= grid_degraded;
                plans.record(&s);
            }
            outcome.issued += issued;
            outcome.hidden_secs += hidden;
            if outcome.err.is_none() {
                outcome.err = err;
            }
        }
    });
    (samples, t0.elapsed().as_secs_f64(), plans, outcome)
}

/// Execute one round as a discrete-event simulation: workers run
/// sequentially, each timed; a device executes its workers serially, so
/// the round "takes" the slowest **device's** summed time — exactly what
/// D synchronized devices hosting W workers would observe (at D = W this
/// is the historical slowest-worker time).
#[allow(clippy::too_many_arguments)]
fn run_round_simulated(
    shared: &SharedFactors,
    core: &crate::kruskal::KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    train: &SparseTensor,
    partition: &BlockPartition,
    assignments: &[Vec<usize>],
    pools: &mut [DispatchPool],
    rngs: &mut [Rng],
    lr_f: f32,
    h: SgdHyper,
    grid: &DeviceGrid,
    device_params: &[PlanParams],
    grid_degraded: bool,
    device_samples: &mut [u64],
    prefetch: Option<(&Mutex<Exchanger>, &PrefetchRound)>,
) -> (usize, f64, PlanAccum, PrefetchOutcome) {
    let mut samples = 0usize;
    let mut plans = PlanAccum::new();
    let mut outcome = PrefetchOutcome::default();
    let mut device_secs = vec![0.0f64; grid.devices()];
    for ((g, pool), wrng) in (0..assignments.len())
        .zip(pools.iter_mut())
        .zip(rngs.iter_mut())
    {
        let block = partition.block(&assignments[g]);
        let dev = grid.device_of(g);
        #[cfg(feature = "shadow-ledger")]
        crate::analysis::shadow::set_worker(g);
        let t0 = Instant::now();
        let (count, stats) = worker_pass(
            shared, core, strided, layout, train, block, pool, wrng, lr_f, h,
            device_params[dev],
        );
        device_secs[dev] += t0.elapsed().as_secs_f64();
        // Post-pass prefetch issue, outside the simulated compute clock:
        // on the modeled hardware the transfer overlaps the remaining
        // devices' compute (that is the point), so its cost lands in the
        // hidden-comm counter instead of the round's device time.
        if let Some((mx, pr)) = prefetch {
            let (issued, hidden, err) =
                issue_prefetch_slots(mx, pr, &pr.jobs[g], shared, pool);
            outcome.issued += issued;
            outcome.hidden_secs += hidden;
            if outcome.err.is_none() {
                outcome.err = err;
            }
        }
        samples += count;
        device_samples[dev] += count as u64;
        if let Some(mut s) = stats {
            s.device = dev;
            s.degraded |= grid_degraded;
            plans.record(&s);
        }
    }
    let slowest = device_secs.iter().copied().fold(0.0f64, f64::max);
    (samples, slowest, plans, outcome)
}

/// Serialize a contiguous factor-row panel (rows `s..e` of `mode`, `j`
/// columns) as little-endian f32 bytes — the exact-round-trip payload of
/// a `Rows` frame. Exactness matters: because `to_le_bytes`/
/// `from_le_bytes` round-trip every f32 bit pattern, a healthy
/// send-and-apply is a bitwise no-op, and any divergence after an
/// exchange can only mean undetected corruption.
fn rows_payload(shared: &SharedFactors, mode: usize, s: usize, e: usize, j: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity((e - s) * j * 4);
    for i in s..e {
        // SAFETY: the caller is one of `row_exchange`'s two exclusive
        // readers — the coordinator at the round barrier (no worker
        // threads live; the synchronous path), or the worker owning
        // these rows' chunk this round, after its own pass (the async
        // prefetch path) — so this read cannot race.
        let row = unsafe { shared.row_exchange(mode, i) };
        for &v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Write a validated `Rows` payload back into the factors — the exact
/// inverse of [`rows_payload`], and the only place transported bytes
/// reach the model, which is why it runs strictly after frame checksum
/// and geometry validation.
fn apply_rows_payload(shared: &SharedFactors, spec: &PanelSpec, payload: &[u8], j: usize) {
    debug_assert_eq!(payload.len(), spec.n_rows * j * 4);
    for r in 0..spec.n_rows {
        // SAFETY: coordinator-serial at the round barrier — no worker
        // threads are live — so this exclusive write cannot race (see
        // `SharedFactors::row_mut_exchange`).
        let row = unsafe { shared.row_mut_exchange(spec.mode, spec.row_start + r) };
        for (c, item) in row.iter_mut().enumerate() {
            let o = (r * j + c) * 4;
            *item = f32::from_le_bytes(payload[o..o + 4].try_into().unwrap());
        }
    }
}

/// Barrier-side half of the prefetch pipeline (ISSUE 8): collect and
/// apply every in-flight rows round whose staleness bound is due at
/// `barrier_round` (with `staleness = 0` — exact mode and the epoch-end
/// drain — that is all of them), then, for the rounds still inside the
/// bound, apply whatever has already arrived without blocking and
/// retire rounds that complete early. Applies always run here, on the
/// coordinator at the barrier, in spec order — the bitwise contract's
/// apply order. Blocking time lands in `exposed_secs`; the hidden cost
/// was already paid worker-side.
#[allow(clippy::too_many_arguments)]
fn drain_due_prefetch(
    ex: &mut Exchanger,
    shared: &SharedFactors,
    inflight: &mut VecDeque<(RoundToken, usize, usize)>,
    epoch: usize,
    barrier_round: usize,
    staleness: usize,
    j: usize,
    exposed_secs: &mut f64,
) -> Result<(), TransportError> {
    while let Some(&(token, round, _)) = inflight.front() {
        if barrier_round - round < staleness {
            break;
        }
        inflight.pop_front();
        let t0 = Instant::now();
        let delivered = ex.collect(token)?;
        *exposed_secs += t0.elapsed().as_secs_f64();
        for (_, spec, payload, seq) in &delivered {
            apply_rows_payload(shared, spec, payload, j);
            ex.note_applied(epoch, round, spec, *seq);
        }
    }
    if inflight.is_empty() {
        return Ok(());
    }
    // Relaxed slack: the remaining rounds are younger than the bound —
    // apply their arrived panels opportunistically and defer the rest.
    ex.poll()?;
    let mut still = VecDeque::with_capacity(inflight.len());
    while let Some((token, round, mut remaining)) = inflight.pop_front() {
        let ready = ex.take_ready(token)?;
        for (_, spec, payload, seq) in &ready {
            apply_rows_payload(shared, spec, payload, j);
            ex.note_applied(epoch, round, spec, *seq);
        }
        remaining -= ready.len();
        if remaining == 0 {
            // Every slot applied — retire the round in the exchanger
            // (instant: nothing is missing, so collect cannot block).
            let leftover = ex.collect(token)?;
            debug_assert!(leftover.is_empty(), "retired round returned panels");
        } else {
            still.push_back((token, round, remaining));
        }
    }
    *inflight = still;
    Ok(())
}

/// Serialize one pool's Eq. 17 gradient block as a `CoreGrad` payload:
/// the sample count (u64 LE) followed by the gradient as little-endian
/// f32 — another exact round-trip.
fn core_grad_payload(grad: &[f32], count: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + grad.len() * 4);
    out.extend_from_slice(&(count as u64).to_le_bytes());
    for &v in grad {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`core_grad_payload`]: fills `grad` and returns the count.
fn read_core_grad_payload(payload: &[u8], grad: &mut [f32]) -> usize {
    debug_assert_eq!(payload.len(), 8 + grad.len() * 4);
    let count = u64::from_le_bytes(payload[0..8].try_into().unwrap()) as usize;
    for (i, item) in grad.iter_mut().enumerate() {
        let o = 8 + i * 4;
        *item = f32::from_le_bytes(payload[o..o + 4].try_into().unwrap());
    }
    count
}

/// One worker's pass over its block: the sampled (or full) block-local
/// nonzeros are grouped into fiber tiles by the worker's **device-level**
/// planner decision and dispatched as **one batched kernel call** — the same Theorem-1/2
/// math as the serial engine, with each fiber's shared mode-0 row staged
/// once per sub-run. With an in-group pool (`threads > 1`) the plan's
/// split sub-groups fan across the pool's threads: exact mode as the
/// sub-group coloring's barrier-separated waves (bitwise identical to
/// sequential dispatch — unless the conflict density makes threading
/// pointless, in which case the pass falls back to the sequential
/// executor), relaxed mode as one hogwild wave.
#[allow(clippy::too_many_arguments)]
fn worker_pass(
    shared: &SharedFactors,
    core: &crate::kruskal::KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    train: &SparseTensor,
    block: &[u32],
    pool: &mut DispatchPool,
    rng: &mut Rng,
    lr_f: f32,
    h: SgdHyper,
    params: PlanParams,
) -> (usize, Option<PlanStats>) {
    if block.is_empty() {
        return (0, None);
    }
    // Draw the worker's sample ids up front (same RNG stream as the
    // historical per-sample draws), then group them by mode-0 fiber. The
    // full-pass case plans straight over the block slice; planning
    // scratch and the plan's own buffers are reused across rounds via the
    // worker's pool (see `PlanScratch::recycle`), so per-pass planning
    // allocates nothing after warmup.
    let plan = if h.sample_frac >= 1.0 {
        BatchPlan::build_params_with_scratch(train, block, params, pool.plan_scratch_mut())
    } else {
        let n_samples = (((block.len() as f64) * h.sample_frac).round() as usize).max(1);
        let ids: Vec<u32> = (0..n_samples)
            .map(|_| block[rng.gen_range(block.len())])
            .collect();
        BatchPlan::build_params_with_scratch(train, &ids, params, pool.plan_scratch_mut())
    };
    let mut plan_stats = plan.stats();

    // SAFETY (level 1 of the three-level disjointness contract, see
    // `SharedFactors`): every id in the plan lies inside this worker's
    // block, and the Latin schedule gives the worker exclusive ownership
    // of every factor chunk the block spans for the duration of this
    // round. Level 2 (intra-pool) is handled inside `dispatch_plan`
    // (exact coloring waves / atomic hogwild access).
    let stats = unsafe {
        dispatch_plan(
            pool,
            train,
            &plan,
            core,
            strided,
            layout,
            shared,
            lr_f,
            h.lambda_factor,
            h.update_core,
            &mut plan_stats,
        )
    };
    pool.plan_scratch_mut().recycle(plan);
    (stats.samples, Some(plan_stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    fn planted(seed: u64) -> (crate::data::synth::Planted, PlantedSpec) {
        let spec = PlantedSpec {
            dims: vec![40, 40, 40],
            nnz: 8000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        (planted_tucker(&mut rng, &spec), spec)
    }

    #[test]
    fn parallel_converges_like_serial() {
        let (p, spec) = planted(1);
        for execution in [Execution::Threads, Execution::Simulated] {
            for workers in [1usize, 2, 4] {
                let mut rng = Rng::new(2);
                let mut model =
                    TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
                let mut opts = ParallelOptions::default();
                opts.workers = workers;
                opts.execution = execution;
                opts.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
                opts.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
                let mut engine = ParallelFastTucker::new(opts);
                let before = rmse(&model, &p.tensor);
                for epoch in 0..15 {
                    engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
                }
                let after = rmse(&model, &p.tensor);
                assert!(
                    after < 0.6 * before,
                    "workers={workers} {execution:?}: rmse {before} -> {after}"
                );
            }
        }
    }

    #[test]
    fn simulated_and_threaded_produce_identical_models() {
        // Same worker RNG streams + conflict-free schedule => the two
        // execution modes compute bit-identical factor updates.
        let (p, spec) = planted(21);
        let run = |execution| {
            let mut rng = Rng::new(22);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 3;
            opts.execution = execution;
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(23);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            model
        };
        let a = run(Execution::Threads);
        let b = run(Execution::Simulated);
        for n in 0..3 {
            assert_eq!(
                a.factors.mat(n).data(),
                b.factors.mat(n).data(),
                "mode {n} diverged between execution modes"
            );
        }
    }

    #[test]
    fn visits_every_nonzero_once_per_epoch() {
        let (p, spec) = planted(3);
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 3;
        let mut engine = ParallelFastTucker::new(opts);
        let stats = engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert_eq!(stats.samples, p.tensor.nnz());
    }

    #[test]
    fn auto_batching_records_plan_stats_and_tiles_hollow_blocks() {
        // The default (planner) policy: multi-device runs share the
        // serial engine's batching decision — no hard-coded cap — and the
        // engine exposes per-pass plan observability. Hollow tensor with
        // wide trailing modes: tiling must engage.
        let spec = PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(31);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        assert_eq!(opts.batch, BatchSizing::Auto);
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let acc = engine.plan_accum;
        assert!(acc.builds > 0, "no plan stats recorded");
        assert_eq!(acc.samples as usize, p.tensor.nnz());
        assert!(acc.tile > 1, "planner did not tile: {acc:?}");
        assert!(
            acc.mean_fibers_per_group() > 1.0,
            "tiling never engaged: {acc:?}"
        );

        // Relaxed mode threads through and merges groups further.
        let mut ropts = ParallelOptions::default();
        ropts.workers = 2;
        ropts.exactness = Exactness::Relaxed;
        let mut rengine = ParallelFastTucker::new(ropts);
        let mut model2 = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        rengine.train_epoch(&mut model2, &p.tensor, 0, &mut rng).unwrap();
        assert!(
            rengine.plan_accum.mean_group_len() >= acc.mean_group_len(),
            "relaxed {:?} vs exact {:?}",
            rengine.plan_accum,
            acc
        );
    }

    #[test]
    fn split_group_execution_is_bitwise_neutral_in_exact_mode() {
        // ISSUE 3 satellite: exact-mode split-group execution (sub-group
        // cuts at fiber sub-run boundaries) must leave the trained model
        // bitwise identical to the unsplit engine — the property that
        // lets sub-groups be dispatched independently.
        let spec = PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut prng = Rng::new(51);
        let p = planted_tucker(&mut prng, &spec);
        let run = |split: usize| {
            let mut rng = Rng::new(52);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 2;
            opts.split = split;
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(53);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, engine.plan_accum)
        };
        let (unsplit, acc1) = run(1);
        let (split, acc64) = run(64);
        assert_eq!(acc1.splits, 0);
        assert!(acc64.splits > 0, "split rule never engaged: {acc64:?}");
        assert!(acc64.groups > acc1.groups);
        for n in 0..3 {
            for (a, b) in unsplit
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(split.factors.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under split");
            }
        }
    }

    #[test]
    fn in_group_threading_is_bitwise_neutral_in_exact_mode() {
        // ISSUE 4 tentpole, worker level: fanning each Latin worker's
        // split sub-groups across an in-group pool (coloring waves) must
        // leave the trained model bitwise identical to sequential
        // dispatch — including the core updates (the tape replay), so we
        // train multiple epochs. Hollow workload with wide trailing
        // modes: low conflict density, the pays-off gate engages.
        let spec = PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut prng = Rng::new(71);
        let p = planted_tucker(&mut prng, &spec);
        let run = |threads: usize| {
            let mut rng = Rng::new(72);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 2;
            opts.split = 8;
            opts.threads = crate::kernel::ThreadCount::Fixed(threads);
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(73);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, engine.plan_accum)
        };
        let (seq, acc1) = run(1);
        let (pooled, acc3) = run(3);
        assert_eq!(acc1.threads, 1);
        assert_eq!(acc3.threads, 3, "pool never engaged: {acc3:?}");
        assert!(acc3.waves > 0, "coloring never ran: {acc3:?}");
        assert!(
            (acc3.groups as f64) / (acc3.waves as f64) >= 2.0,
            "waves expose no parallelism: {acc3:?}"
        );
        for n in 0..3 {
            for (a, b) in seq
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(pooled.factors.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under pooling");
            }
        }
    }

    #[test]
    fn device_grid_is_bitwise_neutral_in_exact_mode() {
        // ISSUE 5 tentpole, engine level: grouping the Latin workers onto
        // D devices (per-device planner decisions, device-attributed
        // passes, fixed-order core merge) must leave the multi-epoch
        // trained model — factors AND core — bitwise identical to D = 1.
        let (p, spec) = planted(101);
        let run = |devices: usize| {
            let mut rng = Rng::new(102);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = crate::parallel::DeviceCount::Fixed(devices);
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(103);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, engine.plan_accum)
        };
        let (base, acc1) = run(1);
        assert_eq!(acc1.devices, 1);
        assert_eq!(acc1.comm_rows, 0, "a single device communicates nothing");
        for devices in [2usize, 3, 4] {
            let (sharded, acc) = run(devices);
            assert_eq!(acc.devices, devices);
            assert!(acc.comm_rows > 0, "D={devices}: no boundary rows counted");
            assert!(acc.device_occupancy() > 0.0 && acc.device_occupancy() <= 1.0);
            for n in 0..3 {
                for (a, b) in base
                    .factors
                    .mat(n)
                    .data()
                    .iter()
                    .zip(sharded.factors.mat(n).data().iter())
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "D={devices}: mode {n} diverged");
                }
            }
            let (ck, cs) = match (&base.core, &sharded.core) {
                (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
                _ => unreachable!(),
            };
            for n in 0..3 {
                for (a, b) in ck.factor(n).data().iter().zip(cs.factor(n).data().iter()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "D={devices}: core mode {n} diverged (merge order)"
                    );
                }
            }
        }
    }

    #[test]
    fn degenerate_device_grids_degrade_loudly() {
        // ISSUE 5 satellite, engine level: D > workers clamps and trains
        // (marked degraded), and a one-nnz tensor on a multi-device grid
        // trains (idle shard marked degraded) — never a panic.
        let (p, spec) = planted(111);
        let mut rng = Rng::new(112);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.devices = crate::parallel::DeviceCount::Fixed(8);
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert_eq!(engine.plan_accum.devices, 2, "grid must clamp to the worker count");
        assert!(engine.plan_accum.degraded > 0, "clamped grid not recorded as degraded");

        let one = crate::tensor::SparseTensor::new_unchecked(
            vec![40, 40, 40],
            vec![1, 2, 3],
            vec![3.0],
        );
        let mut model = TuckerModel::init_kruskal(&mut rng, &[40, 40, 40], 4, 4);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.devices = crate::parallel::DeviceCount::Fixed(2);
        let mut engine = ParallelFastTucker::new(opts);
        let stats = engine.train_epoch(&mut model, &one, 0, &mut rng).unwrap();
        assert_eq!(stats.samples, 1);
        assert!(
            engine.plan_accum.degraded > 0,
            "idle device shard not recorded as degraded: {:?}",
            engine.plan_accum
        );
    }

    #[test]
    fn relaxed_pool_fallback_degrades_loudly() {
        // ISSUE 6 satellite: a relaxed pass whose plan cannot feed the
        // in-group pool (a single group — nothing to hogwild across
        // threads) used to fall back to sequential dispatch *silently*.
        // It must surface through `PlanStats::degraded` at the engine
        // level, while a healthy relaxed workload with real group
        // fan-out stays clean.
        let one = crate::tensor::SparseTensor::new_unchecked(
            vec![40, 40, 40],
            vec![1, 2, 3],
            vec![3.0],
        );
        let mut rng = Rng::new(61);
        let mut model = TuckerModel::init_kruskal(&mut rng, &[40, 40, 40], 4, 4);
        let mut opts = ParallelOptions::default();
        opts.workers = 1;
        opts.exactness = Exactness::Relaxed;
        opts.threads = ThreadCount::Fixed(2);
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &one, 0, &mut rng).unwrap();
        assert!(
            engine.plan_accum.degraded > 0,
            "single-group relaxed plan under a 2-thread pool not marked degraded: {:?}",
            engine.plan_accum
        );

        // Healthy relaxed run: ~1000 nonzeros per pass at cap 64 fan out
        // into many groups, the pool hogwilds them, nothing degrades.
        let (p, spec) = planted(62);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.exactness = Exactness::Relaxed;
        opts.threads = ThreadCount::Fixed(2);
        opts.batch = BatchSizing::Fixed(64);
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert_eq!(
            engine.plan_accum.degraded, 0,
            "healthy relaxed workload wrongly marked degraded: {:?}",
            engine.plan_accum
        );
    }

    #[test]
    fn overflowing_worker_geometry_surfaces_as_algo_error() {
        // ISSUE 4 satellite, engine level: a worker count whose M^N
        // block space overflows must produce a typed error from
        // train_epoch, not a silent wrap / OOM.
        let (p, spec) = planted(9);
        let mut rng = Rng::new(10);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 1 << 22; // (2^22)^3 = 2^66 blocks
        let mut engine = ParallelFastTucker::new(opts);
        let err = engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap_err();
        assert!(
            matches!(err, AlgoError::PartitionOverflow { workers, order }
                if workers == 1 << 22 && order == 3),
            "wrong error: {err}"
        );
    }

    #[test]
    fn ledger_accumulates_exchanges() {
        let (p, spec) = planted(5);
        let mut rng = Rng::new(6);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        // M=2, N=3: 4 rounds, rounds 1..3 each exchange >= 1 chunk per
        // worker, plus one core all-reduce.
        assert!(engine.ledger.factor_bytes > 0);
        assert!(engine.ledger.core_bytes > 0);
    }

    #[test]
    fn channel_transport_is_bitwise_neutral_and_counts_frames() {
        // ISSUE 7 tentpole, engine level: routing the boundary rows and
        // core-gradient panels through the framed channel transport must
        // leave the trained model — factors AND core — bitwise identical
        // to the direct handover, while actually moving frames for
        // D > 1 (and none for D = 1, where nothing crosses a device).
        let (p, spec) = planted(141);
        let run = |transport, devices: usize| {
            let mut rng = Rng::new(142);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = crate::parallel::DeviceCount::Fixed(devices);
            opts.transport = transport;
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(143);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, engine)
        };
        let (direct, _) = run(TransportKind::Direct, 2);
        let (channel, engine) = run(TransportKind::Channel, 2);
        assert!(engine.plan_accum.frames_sent > 0, "no frames moved at D=2");
        assert_eq!(
            engine.plan_accum.transport_faults(),
            0,
            "healthy channel reported faults: {:?}",
            engine.plan_accum
        );
        assert!(!engine.exchange_events().is_empty(), "event log empty");
        for n in 0..3 {
            for (a, b) in direct
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(channel.factors.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged over the channel");
            }
        }
        let (ck, cs) = match (&direct.core, &channel.core) {
            (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
            _ => unreachable!(),
        };
        for n in 0..3 {
            for (a, b) in ck.factor(n).data().iter().zip(cs.factor(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "core mode {n} diverged over the channel");
            }
        }
        let (_, engine1) = run(TransportKind::Channel, 1);
        assert_eq!(
            engine1.plan_accum.frames_sent, 0,
            "a single device must ship nothing"
        );
    }

    #[test]
    fn fault_plan_on_direct_transport_degrades_loudly() {
        // A configured FaultPlan that cannot engage (direct transport)
        // must be surfaced, not silently ignored.
        let (p, spec) = planted(151);
        let mut rng = Rng::new(152);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.transport = TransportKind::Direct;
        opts.fault = Some(FaultPlan {
            seed: 1,
            rate: 0.5,
            kinds: crate::parallel::FaultKinds::ALL,
            kill: None,
        });
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert!(
            engine.plan_accum.degraded > 0,
            "ignored fault plan not marked degraded: {:?}",
            engine.plan_accum
        );
    }

    #[test]
    fn killed_device_surfaces_from_train_epoch() {
        // ISSUE 7 elastic-recovery trigger: a permanently dead device
        // must abort the epoch with the named typed error (the caller's
        // cue to reload a checkpoint into a re-sharded engine), never
        // hang or silently train on partial exchanges.
        let (p, spec) = planted(161);
        let mut rng = Rng::new(162);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = crate::parallel::DeviceCount::Fixed(2);
        opts.transport = TransportKind::Channel;
        opts.fault = Some(FaultPlan {
            seed: 1,
            rate: 0.0,
            kinds: crate::parallel::FaultKinds::NONE,
            kill: Some(crate::parallel::KillSpec { device: 1, after_sends: 3 }),
        });
        let mut engine = ParallelFastTucker::new(opts);
        let err = engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap_err();
        assert!(
            matches!(
                err,
                AlgoError::Transport(crate::parallel::TransportError::DeviceDead { device: 1 })
            ),
            "wrong error: {err}"
        );
    }

    #[test]
    fn async_prefetch_is_bitwise_neutral_in_exact_mode() {
        // ISSUE 8 tentpole, engine level: double-buffering the boundary
        // exchange (transfer moves early, apply stays at the barrier)
        // must leave the trained model — factors AND core — bitwise
        // identical to both the synchronous channel exchange and the
        // direct handover, in both execution modes, while actually
        // hiding exchange work behind compute.
        let (p, spec) = planted(171);
        for execution in [Execution::Threads, Execution::Simulated] {
            let run = |transport, prefetch| {
                let mut rng = Rng::new(172);
                let mut model =
                    TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
                let mut opts = ParallelOptions::default();
                opts.workers = 4;
                opts.devices = crate::parallel::DeviceCount::Fixed(2);
                opts.execution = execution;
                opts.transport = transport;
                opts.prefetch = prefetch;
                let mut engine = ParallelFastTucker::new(opts);
                let mut rng2 = Rng::new(173);
                for epoch in 0..2 {
                    engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
                }
                (model, engine)
            };
            let (direct, _) = run(TransportKind::Direct, PrefetchMode::Off);
            let (sync, sync_engine) = run(TransportKind::Channel, PrefetchMode::Off);
            let (async_m, async_engine) = run(TransportKind::Channel, PrefetchMode::Async);
            // The async run moved real panels ahead of their barriers
            // and hid real exchange seconds behind compute.
            let acc = &async_engine.plan_accum;
            assert!(acc.prefetch_issued > 0, "{execution:?}: nothing prefetched: {acc:?}");
            assert!(acc.comm_hidden_secs > 0.0, "{execution:?}: no hidden comm: {acc:?}");
            assert!(
                acc.overlap_efficiency().unwrap_or(0.0) > 0.0,
                "{execution:?}: zero overlap efficiency: {acc:?}"
            );
            assert_eq!(acc.degraded, 0, "{execution:?}: async run degraded: {acc:?}");
            assert_eq!(
                acc.transport_faults(),
                0,
                "{execution:?}: healthy async channel reported faults: {acc:?}"
            );
            // The synchronous run prefetches nothing (its exchange cost
            // is all exposed).
            assert_eq!(sync_engine.plan_accum.prefetch_issued, 0);
            assert_eq!(sync_engine.plan_accum.comm_hidden_secs, 0.0);
            for n in 0..3 {
                let (d, s, a) = (
                    direct.factors.mat(n).data(),
                    sync.factors.mat(n).data(),
                    async_m.factors.mat(n).data(),
                );
                for ((x, y), z) in d.iter().zip(s.iter()).zip(a.iter()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{execution:?}: mode {n} sync channel diverged from direct"
                    );
                    assert_eq!(
                        x.to_bits(),
                        z.to_bits(),
                        "{execution:?}: mode {n} async prefetch diverged from direct"
                    );
                }
            }
            let (dk, sk, ak) = match (&direct.core, &sync.core, &async_m.core) {
                (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b), CoreRepr::Kruskal(c)) => (a, b, c),
                _ => unreachable!(),
            };
            for n in 0..3 {
                for ((x, y), z) in dk
                    .factor(n)
                    .data()
                    .iter()
                    .zip(sk.factor(n).data().iter())
                    .zip(ak.factor(n).data().iter())
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "{execution:?}: core mode {n} (sync)");
                    assert_eq!(x.to_bits(), z.to_bits(), "{execution:?}: core mode {n} (async)");
                }
            }
        }
    }

    #[test]
    fn async_prefetch_on_direct_transport_degrades_loudly() {
        // Async prefetch needs a transfer to hide; on the direct
        // handover the request cannot engage and must be surfaced as a
        // degraded run (same rule as an unengageable FaultPlan), while
        // training proceeds unharmed.
        let (p, spec) = planted(181);
        let mut rng = Rng::new(182);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 2;
        opts.transport = TransportKind::Direct;
        opts.prefetch = PrefetchMode::Async;
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert!(
            engine.plan_accum.degraded > 0,
            "unengageable async prefetch not marked degraded: {:?}",
            engine.plan_accum
        );
        assert_eq!(engine.plan_accum.prefetch_issued, 0);
    }

    #[test]
    fn staleness_without_relaxed_async_degrades_and_clamps() {
        // A staleness bound only means something when the apply may
        // leave its barrier — relaxed exactness with engaged async
        // prefetch. Anywhere else it clamps to 0 (every panel at its own
        // barrier), loudly, and the run stays bitwise exact.
        let (p, spec) = planted(191);
        let run = |transport, prefetch, staleness: usize| {
            let mut rng = Rng::new(192);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = crate::parallel::DeviceCount::Fixed(2);
            opts.transport = transport;
            opts.prefetch = prefetch;
            opts.staleness = staleness;
            let mut engine = ParallelFastTucker::new(opts);
            let mut rng2 = Rng::new(193);
            for epoch in 0..2 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, engine)
        };
        let (direct, _) = run(TransportKind::Direct, PrefetchMode::Off, 0);
        // Exact mode: staleness must clamp (exact owes every panel to
        // its own barrier) and the model must stay bitwise identical.
        let (clamped, engine) = run(TransportKind::Channel, PrefetchMode::Async, 2);
        assert!(
            engine.plan_accum.degraded > 0,
            "exact-mode staleness not marked degraded: {:?}",
            engine.plan_accum
        );
        for n in 0..3 {
            for (a, b) in direct
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(clamped.factors.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under clamped staleness");
            }
        }
        // No async prefetch (sync channel): same clamp rule even in
        // relaxed mode — there is no in-flight panel to defer.
        let mut rng = Rng::new(194);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = crate::parallel::DeviceCount::Fixed(2);
        opts.transport = TransportKind::Channel;
        opts.exactness = Exactness::Relaxed;
        opts.prefetch = PrefetchMode::Off;
        opts.staleness = 1;
        let mut engine = ParallelFastTucker::new(opts);
        engine.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert!(
            engine.plan_accum.degraded > 0,
            "staleness without prefetch not marked degraded: {:?}",
            engine.plan_accum
        );
    }

    #[test]
    fn relaxed_bounded_staleness_trains_and_audits_clean() {
        // The relaxed-mode prefetch variant: panels may be applied up to
        // S rounds late. Covered by the accuracy envelope (convergence),
        // not the bitwise contract — and the event log must satisfy the
        // staleness-aware auditor, not the strict S = 0 one.
        let (p, spec) = planted(201);
        for staleness in [1usize, 2] {
            let mut rng = Rng::new(202);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut opts = ParallelOptions::default();
            opts.workers = 4;
            opts.devices = crate::parallel::DeviceCount::Fixed(2);
            opts.exactness = Exactness::Relaxed;
            opts.transport = TransportKind::Channel;
            opts.prefetch = PrefetchMode::Async;
            opts.staleness = staleness;
            opts.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
            opts.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
            let mut engine = ParallelFastTucker::new(opts);
            let before = rmse(&model, &p.tensor);
            for epoch in 0..15 {
                engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
                let report = crate::analysis::audit_exchange_with_staleness(
                    engine.exchange_events(),
                    staleness,
                );
                assert!(report.ok(), "S={staleness} epoch {epoch} audit: {report}");
            }
            assert_eq!(
                engine.plan_accum.degraded, 0,
                "engaged bounded staleness wrongly degraded: {:?}",
                engine.plan_accum
            );
            let after = rmse(&model, &p.tensor);
            assert!(
                after < 0.6 * before,
                "S={staleness}: rmse {before} -> {after} (outside the relaxed envelope)"
            );
        }
    }

    #[test]
    fn single_worker_matches_partition_order_serial_run() {
        // With M=1 the engine degenerates to a serial full pass (block
        // order); RMSE after an epoch must match a serial FastTucker run
        // over the same sample order. We check convergence consistency
        // rather than bitwise equality (sample orders differ).
        let (p, spec) = planted(7);
        let mut rng = Rng::new(8);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut opts = ParallelOptions::default();
        opts.workers = 1;
        let mut engine = ParallelFastTucker::new(opts);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..10 {
            engine.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        assert!(rmse(&model, &p.tensor) < before);
    }
}
