//! The Latin-square round schedule (paper Section 5.3): in round `t`,
//! worker `g` processes the block whose mode-0 chunk is `g` and whose
//! mode-`k` chunk is `(g + d_k(t)) mod M`, where `(d_1..d_{N-1})` are the
//! base-M digits of `t`. Properties (pinned by tests):
//!
//! * **Conflict-freedom** — within a round, any two workers differ in
//!   *every* mode's chunk index, so factor-row writes never collide.
//! * **Coverage** — over the `M^{N-1}` rounds of a cycle, every one of the
//!   `M^N` blocks is processed exactly once.

use crate::algo::{AlgoError, AlgoResult};

/// The schedule for `m` workers over an order-`order` tensor.
#[derive(Clone, Debug)]
pub struct LatinSchedule {
    m: usize,
    order: usize,
    /// `M^{N-1}`, checked at construction (`usize::pow` silently wraps in
    /// release builds — ISSUE 4 regression).
    rounds: usize,
}

impl LatinSchedule {
    /// Checked constructor: fails with [`AlgoError::PartitionOverflow`]
    /// when the `M^{N-1}` round count (or the `M^N` block space the
    /// schedule cycles over) overflows `usize`, instead of silently
    /// wrapping in release builds.
    pub fn try_new(m: usize, order: usize) -> AlgoResult<Self> {
        assert!(m >= 1 && order >= 1);
        let rounds = m
            .checked_pow((order - 1) as u32)
            .ok_or(AlgoError::PartitionOverflow { workers: m, order })?;
        // The cycle visits M^N blocks; a schedule whose block space
        // overflows — or exceeds the partition's materialization budget
        // (the matching BlockPartition would abort on allocation) — is
        // unusable even if the round count fits.
        m.checked_pow(order as u32)
            .filter(|&n| n <= crate::parallel::BlockPartition::MAX_BLOCKS)
            .ok_or(AlgoError::PartitionOverflow { workers: m, order })?;
        Ok(LatinSchedule { m, order, rounds })
    }

    /// Panicking constructor for infallible call sites (small, validated
    /// `m`/`order`); prefer [`Self::try_new`] on config-driven paths.
    pub fn new(m: usize, order: usize) -> Self {
        Self::try_new(m, order).expect("LatinSchedule geometry overflows usize")
    }

    /// Rounds per full cycle: `M^{N-1}`.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Block chunk-coordinates assigned to `worker` in `round`.
    pub fn assignment(&self, round: usize, worker: usize) -> Vec<usize> {
        assert!(worker < self.m);
        assert!(round < self.rounds());
        let mut coords = Vec::with_capacity(self.order);
        coords.push(worker);
        let mut t = round;
        for _ in 1..self.order {
            let d = t % self.m;
            t /= self.m;
            coords.push((worker + d) % self.m);
        }
        coords
    }

    /// All assignments of one round, indexed by worker.
    pub fn round_assignments(&self, round: usize) -> Vec<Vec<usize>> {
        (0..self.m).map(|g| self.assignment(round, g)).collect()
    }

    /// The worker processing chunk `chunk` of `mode` in `round` — the
    /// inverse of [`Self::assignment`]. The device-shard layer uses it to
    /// find the *source* of a chunk handover: an exchange is inter-device
    /// traffic only when the previous owner lives on a different device
    /// ([`DeviceGrid`](super::DeviceGrid)).
    pub fn owner_of(&self, round: usize, mode: usize, chunk: usize) -> usize {
        assert!(mode < self.order && chunk < self.m && round < self.rounds());
        if mode == 0 {
            // Mode 0 is worker-pinned: chunk g belongs to worker g.
            return chunk;
        }
        // assignment(round, g)[mode] = (g + d_mode) % m with d_mode the
        // mode-th base-m digit of `round`; invert for g.
        let mut t = round;
        let mut d = 0usize;
        for _ in 0..mode {
            d = t % self.m;
            t /= self.m;
        }
        (chunk + self.m - d) % self.m
    }

    /// The factor chunks worker `g` must receive before `round` that it
    /// did not own in `round - 1` — the paper's parameter-exchange set.
    /// Returns `(mode, chunk)` pairs; empty for round 0 (initial broadcast
    /// is accounted separately).
    pub fn incoming_chunks(&self, round: usize, worker: usize) -> Vec<(usize, usize)> {
        if round == 0 {
            return Vec::new();
        }
        let prev = self.assignment(round - 1, worker);
        let cur = self.assignment(round, worker);
        prev.iter()
            .zip(cur.iter())
            .enumerate()
            .filter(|(_, (p, c))| p != c)
            .map(|(n, (_, &c))| (n, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn two_gpu_example_matches_paper() {
        // Paper Fig. 2: M=2, N=3 -> 4 rounds; GPU1 visits (1,1,1) (1,1,2)
        // (1,2,2)... in 1-based notation. Our round digit order differs but
        // the invariants are what matter; spot-check worker 0 and 1 are
        // always complementary.
        let s = LatinSchedule::new(2, 3);
        assert_eq!(s.rounds(), 4);
        for round in 0..4 {
            let a = s.assignment(round, 0);
            let b = s.assignment(round, 1);
            for n in 0..3 {
                assert_ne!(a[n], b[n], "round {round} mode {n}");
            }
        }
    }

    #[test]
    fn prop_conflict_free_and_covering() {
        forall("latin schedule conflict-free + covering", 32, |rng| {
            let m = 1 + rng.gen_range(5);
            let order = 2 + rng.gen_range(4);
            let s = LatinSchedule::new(m, order);
            let mut seen = std::collections::HashSet::new();
            for round in 0..s.rounds() {
                let assigns = s.round_assignments(round);
                // Conflict-freedom: each mode's chunks are a permutation.
                for n in 0..order {
                    let mut chunks: Vec<usize> =
                        assigns.iter().map(|a| a[n]).collect();
                    chunks.sort_unstable();
                    assert_eq!(chunks, (0..m).collect::<Vec<_>>(), "mode {n}");
                }
                for a in assigns {
                    assert!(seen.insert(a), "block processed twice");
                }
            }
            // Coverage: all M^N blocks seen.
            assert_eq!(seen.len(), m.pow(order as u32));
        });
    }

    #[test]
    fn incoming_chunks_only_changed_modes() {
        let s = LatinSchedule::new(3, 3);
        for worker in 0..3 {
            assert!(s.incoming_chunks(0, worker).is_empty());
            for round in 1..s.rounds() {
                let prev = s.assignment(round - 1, worker);
                let cur = s.assignment(round, worker);
                let incoming = s.incoming_chunks(round, worker);
                for (n, c) in &incoming {
                    assert_eq!(cur[*n], *c);
                    assert_ne!(prev[*n], *c);
                }
                // Mode 0 never changes (worker-pinned).
                assert!(incoming.iter().all(|(n, _)| *n != 0));
            }
        }
    }

    #[test]
    fn owner_of_inverts_assignment() {
        forall("owner_of == assignment⁻¹", 16, |rng| {
            let m = 1 + rng.gen_range(5);
            let order = 2 + rng.gen_range(4);
            let s = LatinSchedule::new(m, order);
            for round in 0..s.rounds() {
                let assigns = s.round_assignments(round);
                for mode in 0..order {
                    for chunk in 0..m {
                        let owner = s.owner_of(round, mode, chunk);
                        assert_eq!(
                            assigns[owner][mode], chunk,
                            "round {round} mode {mode} chunk {chunk}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn single_worker_schedule_visits_all_blocks() {
        let s = LatinSchedule::new(1, 4);
        assert_eq!(s.rounds(), 1);
        assert_eq!(s.assignment(0, 0), vec![0, 0, 0, 0]);
    }

    #[test]
    fn overflowing_geometry_is_a_typed_error_not_a_wrap() {
        // ISSUE 4 regression: m.pow(order) silently wrapped in release
        // builds. 2^22 workers on an order-3 tensor needs 2^66 blocks.
        let err = LatinSchedule::try_new(1 << 22, 3).unwrap_err();
        assert!(
            matches!(
                err,
                crate::algo::AlgoError::PartitionOverflow { workers, order }
                    if workers == 1 << 22 && order == 3
            ),
            "wrong error: {err}"
        );
        // Round count itself overflowing (order - 1 exponent).
        assert!(LatinSchedule::try_new(1 << 33, 3).is_err());
        // Representable-but-absurd block space (beyond the partition's
        // materialization budget) is rejected the same way.
        assert!(LatinSchedule::try_new(100_000, 3).is_err());
        // Large-but-valid geometry still constructs.
        let s = LatinSchedule::try_new(4, 5).unwrap();
        assert_eq!(s.rounds(), 256);
    }
}
