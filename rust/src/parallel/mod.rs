//! Multi-device training simulation (paper Section 5.3, Figs. 7–8).
//!
//! The paper splits each mode into `M` contiguous chunks, yielding `M^N`
//! tensor blocks; in each scheduling round the `M` GPUs process `M` blocks
//! whose per-mode chunk indices are pairwise distinct (a Latin-square
//! anti-diagonal), so no two devices ever write the same factor rows and
//! no locking is needed. Between rounds the devices exchange only the
//! factor chunks that change owners; core gradients are accumulated
//! locally and all-reduced once per epoch.
//!
//! Here "devices" are OS threads, and the exchange is a ledger entry (the
//! data is shared memory), which preserves exactly what the paper's
//! experiments measure: the conflict-freedom of the schedule, the
//! per-round load balance, and the scaling curve shape.
//!
//! The [`device`] layer (ISSUE 5) makes the device notion explicit: a
//! [`DeviceGrid`] shards the `M` Latin workers (and with them the
//! training nonzeros and mode-row ownership) across `D ≤ M` virtual
//! devices, each with its own planner decision and dispatch pools, with
//! a per-round boundary-row exchange and a fixed-order Eq. 17 core-
//! gradient merge — exact mode is bitwise-identical at every `D`.

pub mod device;
pub mod partition;
pub mod schedule;
pub mod shared;
pub mod worker;

pub use device::{DeviceCount, DeviceGrid};
pub use partition::BlockPartition;
pub use schedule::LatinSchedule;
pub use worker::{Execution, ParallelFastTucker, ParallelOptions};
