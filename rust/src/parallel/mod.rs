//! Multi-device training simulation (paper Section 5.3, Figs. 7–8).
//!
//! The paper splits each mode into `M` contiguous chunks, yielding `M^N`
//! tensor blocks; in each scheduling round the `M` GPUs process `M` blocks
//! whose per-mode chunk indices are pairwise distinct (a Latin-square
//! anti-diagonal), so no two devices ever write the same factor rows and
//! no locking is needed. Between rounds the devices exchange only the
//! factor chunks that change owners; core gradients are accumulated
//! locally and all-reduced once per epoch.
//!
//! Here "devices" are OS threads. The [`device`] layer (ISSUE 5) makes
//! the device notion explicit: a [`DeviceGrid`] shards the `M` Latin
//! workers (and with them the training nonzeros and mode-row ownership)
//! across `D ≤ M` virtual devices, each with its own planner decision
//! and dispatch pools, with a per-round boundary-row exchange and a
//! fixed-order Eq. 17 core-gradient merge — exact mode is
//! bitwise-identical at every `D`.
//!
//! The [`transport`] layer (ISSUE 7) makes the *exchange* explicit: with
//! `transport = channel`, every inter-device boundary-row panel and
//! per-epoch core-gradient panel is serialized into a framed, checksummed
//! message and routed through a [`Transport`] implementation instead of
//! handed over in shared memory. The contract is three-way:
//!
//! * **Bitwise** — over the healthy [`InProcTransport`], exact-mode
//!   training is bitwise-identical (factors, core, residual trajectory)
//!   to the direct handover at every `D`, because the payloads are exact
//!   little-endian f32 round-trips applied at the same round barrier by
//!   the same coordinator.
//! * **Retries** — drops, duplicates, reorders, delays, and detected
//!   corruption recover transparently (bounded resend with virtual-time
//!   backoff, sequence-number dedup, out-of-order buffering). Recovery
//!   is loud: it lands in the [`metrics::PlanAccum`](crate::metrics::PlanAccum)
//!   transport counter block and a per-epoch warning, never in the
//!   factors.
//! * **Degrades/fails** — what cannot be recovered is *typed*: the
//!   exchange aborts with a named [`TransportError`]
//!   ([`AlgoError::Transport`](crate::algo::AlgoError) from
//!   `train_epoch`), and a dead device surfaces as
//!   [`TransportError::DeviceDead`] so the caller can reload the last
//!   checkpoint into a freshly sharded engine (any new `D`) and resume —
//!   bitwise-equal to a run that never failed. A [`FaultPlan`] configured
//!   while `transport = direct` cannot engage and marks the run degraded.
//!
//! The direct in-memory handover remains the default; the channel path
//! exists so the failure modes of a real multi-process backend (socket /
//! TCP — the ROADMAP item 2 follow-up) are testable before that backend
//! lands.
//!
//! # Async prefetch: what moves early, what may not (ISSUE 8)
//!
//! With `prefetch = async` (channel transport only), the exchange is
//! double-buffered around the round barrier. What moves early is only
//! the **transfer**: round r+1's panel headers are opened (and sequence
//! numbers assigned, deterministically, in spec order) before round r
//! computes, and each outgoing payload is serialized and handed to the
//! transport as soon as its owning worker finishes its round-r pass —
//! legal because the Latin schedule gives that worker exclusive
//! ownership of the chunk for the whole round, so the rows are final the
//! moment its pass ends. What may **not** move is the *apply*: in exact
//! mode every prefetched panel's write-back still lands at its own round
//! barrier, applied by the coordinator in spec order, which is why exact
//! mode stays bitwise-identical to the synchronous exchange (and to the
//! direct handover) at every `(D, threads, split, transport)` setting.
//! The per-epoch core merge pipelines the same way: each off-root
//! worker's Eq. 17 gradient panel is issued right after that worker's
//! *last* round pass (the gradient is complete then), and the root
//! drains and folds at the merge barrier in the same device-major order.
//!
//! Relaxed mode may additionally defer the apply itself: with
//! `staleness = S > 0`, a panel that has not arrived by its barrier is
//! applied at a later barrier, at most S rounds late (the paper's
//! multi-GPU overlap made explicit), enforced by a forced blocking
//! collect at the bound and audited by
//! [`audit_exchange_with_staleness`](crate::analysis::audit_exchange_with_staleness).
//! Overlap is measured, not assumed:
//! [`PlanAccum`](crate::metrics::PlanAccum) splits the exchange cost
//! into `comm_hidden_secs` (drained at a barrier that never had to
//! wait) vs `comm_exposed_secs` (barrier time spent blocking).

pub mod device;
pub mod partition;
pub mod schedule;
pub mod shared;
pub mod transport;
pub mod worker;

pub use device::{DeviceCount, DeviceGrid};
pub use partition::BlockPartition;
pub use schedule::LatinSchedule;
pub use transport::{
    ExchangeEvent, FaultKind, FaultKinds, FaultPlan, InProcTransport, KillSpec, PanelKind,
    PanelSpec, PrefetchMode, Transport, TransportError, TransportKind, TransportStats,
};
pub use worker::{EngineRebuilds, Execution, ParallelFastTucker, ParallelOptions};
