//! Unsafe-but-proven shared factor storage for the multi-device engine.
//!
//! # The three-level disjointness contract
//!
//! Concurrent row access through [`SharedFactors`] is sound because
//! nested partitions guarantee writers never collide — the CPU analogue
//! of the paper's nested levels of parallelism (device grid × inter-GPU
//! Latin rounds × intra-GPU thread blocks):
//!
//! 0. **Device grid (across devices):** the
//!    [`DeviceGrid`](super::DeviceGrid) groups the Latin workers onto
//!    `D` devices as contiguous ranges. It is a *coarsening* of the
//!    Latin level — two devices' row footprints in a round are unions of
//!    their workers' pairwise-disjoint footprints — so it introduces no
//!    new aliasing and only decides which device is accounted for each
//!    pass, which boundary rows the communication step counts, and the
//!    order of the per-epoch Eq. 17 core-gradient merge (flat worker-
//!    order fold in exact mode — the bitwise-at-every-`D` contract,
//!    pinned by
//!    `tests/properties.rs::prop_sharded_exact_bitwise_matches_single_device`
//!    — or the relaxed two-stage device tree).
//! 1. **Latin schedule (across workers):** within one scheduling round,
//!    [`LatinSchedule`](super::LatinSchedule) guarantees the workers'
//!    blocks are pairwise disjoint in every mode's chunk index, so the
//!    factor rows any two *workers* touch never overlap (pinned by
//!    `parallel::schedule::tests::prop_conflict_free_and_covering`).
//! 2. **Color waves (within a worker):** when a worker fans its plan's
//!    split sub-groups across an in-group thread pool
//!    ([`DispatchPool`](crate::kernel::dispatch::DispatchPool)), the
//!    sub-group coloring
//!    ([`BatchPlan::color_subgroups`](crate::kernel::BatchPlan::color_subgroups))
//!    guarantees same-wave sub-groups have pairwise-disjoint row
//!    footprints in every mode, so the *pool threads* never collide
//!    either; waves are barrier-separated, which also replays every
//!    conflicting sub-group pair in its sequential order (the exact-mode
//!    bitwise contract, pinned by
//!    `tests/properties.rs::prop_subgroup_coloring_is_disjoint_ordered_partition`
//!    and `prop_threaded_exact_bitwise_matches_sequential`).
//!
//! The single deliberate exception is **relaxed (hogwild) pooled
//! dispatch**: a single wave of freely-concurrent sub-groups may update
//! shared rows concurrently — the paper's GPU write semantics, opted into
//! explicitly via `Exactness::Relaxed` and pinned as an accuracy envelope
//! rather than a bitwise contract. Those accesses go through
//! [`RelaxedRowAccess`] (relaxed-atomic element loads/stores), so racing
//! updates can lose writes but are well-defined — never the aliasing
//! `&mut` UB the plain [`SharedRowAccess`] path would incur.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::kernel::contract::CoreLayout;
use crate::kernel::{
    batched, planner, BatchPlan, DispatchPool, Exactness, KernelStats, SubGroupColoring,
};
use crate::kruskal::KruskalCore;
use crate::metrics::PlanStats;
use crate::model::factors::FactorMatrices;
use crate::tensor::SparseTensor;

/// A `Sync` view over factor matrices allowing per-row mutable access from
/// multiple threads, provided callers honor the three-level disjointness
/// contract above.
pub struct SharedFactors {
    ptrs: Vec<*mut f32>,
    rows: Vec<usize>,
    cols: usize,
}

// SAFETY: all mutation goes through `row_mut_unchecked`, whose contract
// (disjoint rows across threads within a round) is enforced by the Latin
// schedule; reads of rows owned by other workers do not occur within a
// round because every mode chunk a worker reads is also one it owns.
unsafe impl Sync for SharedFactors {}
unsafe impl Send for SharedFactors {}

impl SharedFactors {
    /// Wrap `factors`; the borrow is held for `'_`'s scope by the caller
    /// (the parallel engine keeps the `&mut FactorMatrices` alive across
    /// the thread scope).
    pub fn new(factors: &mut FactorMatrices) -> Self {
        let cols = factors.rank();
        let rows = factors.dims();
        let ptrs = (0..factors.order())
            .map(|n| factors.mat_mut(n).data_mut().as_mut_ptr())
            .collect();
        SharedFactors { ptrs, rows, cols }
    }

    pub fn order(&self) -> usize {
        self.ptrs.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read row `i` of mode `n`.
    ///
    /// # Safety
    /// No other thread may be writing row `(n, i)` concurrently — holds
    /// whenever `(n, i)` lies inside the calling worker's round assignment.
    #[inline]
    pub unsafe fn row(&self, n: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.rows[n]);
        std::slice::from_raw_parts(self.ptrs[n].add(i * self.cols), self.cols)
    }

    /// Mutable row access; same contract as [`Self::row`] plus exclusivity.
    ///
    /// # Safety
    /// The calling worker must be the unique owner of row `(n, i)` in the
    /// current round.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, n: usize, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows[n]);
        std::slice::from_raw_parts_mut(self.ptrs[n].add(i * self.cols), self.cols)
    }

    /// Row `(n, i)` as relaxed-atomic words (f32 bit patterns) — the
    /// hogwild access path: concurrent readers/writers are well-defined
    /// (individual element updates may be lost, never torn into UB).
    ///
    /// # Safety
    /// While any thread accesses a row atomically, no thread may hold a
    /// plain `&`/`&mut` reference to it ([`Self::row`]/[`Self::row_mut`])
    /// — mixing the two access modes on one row is a data race again.
    #[inline]
    pub unsafe fn row_atomic(&self, n: usize, i: usize) -> &[AtomicU32] {
        debug_assert!(i < self.rows[n]);
        // f32 and AtomicU32 share size and alignment; the factor storage
        // outlives `self` per the constructor's contract.
        std::slice::from_raw_parts(
            self.ptrs[n].add(i * self.cols) as *const AtomicU32,
            self.cols,
        )
    }
}

/// [`FactorAccess`](crate::kernel::FactorAccess) view over
/// [`SharedFactors`], letting a Latin-schedule worker drive the shared
/// kernel ([`crate::kernel::batched`] / [`crate::kernel::scalar`])
/// directly against the logically-global factor matrices.
pub struct SharedRowAccess<'a> {
    shared: &'a SharedFactors,
}

impl<'a> SharedRowAccess<'a> {
    /// Wrap a shared view for one worker.
    ///
    /// # Safety
    /// Every row `(n, i)` subsequently staged/updated/stored through the
    /// returned accessor must be exclusively owned by the calling worker
    /// for the duration of the current scheduling round (the
    /// [`LatinSchedule`](super::LatinSchedule) invariant): no other thread
    /// may read or write those rows concurrently.
    pub unsafe fn new(shared: &'a SharedFactors) -> Self {
        SharedRowAccess { shared }
    }
}

impl crate::kernel::FactorAccess for SharedRowAccess<'_> {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        // SAFETY: ownership per the constructor's contract.
        out.copy_from_slice(unsafe { self.shared.row(n, i) });
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        crate::util::linalg::scale_axpy(beta, alpha, x, unsafe {
            self.shared.row_mut(n, i)
        });
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        unsafe { self.shared.row_mut(n, i) }.copy_from_slice(src);
    }
}

/// Hogwild-safe [`FactorAccess`](crate::kernel::FactorAccess) over
/// [`SharedFactors`] for **relaxed pooled dispatch**: every element
/// access is a relaxed-atomic load/store of the f32 bit pattern, so
/// concurrent updates to a shared row are well-defined — racing
/// read-modify-writes may *lose* an update (the paper's GPU write
/// semantics, accuracy-pinned by the relaxed RMSE envelope) but can
/// never tear into undefined behavior the way aliasing `&mut` rows
/// would. Element-wise arithmetic is identical to
/// [`SharedRowAccess`] (`row[k] = beta·row[k] + alpha·x[k]`), so a
/// race-free relaxed pass computes the same values.
pub struct RelaxedRowAccess<'a> {
    shared: &'a SharedFactors,
}

impl<'a> RelaxedRowAccess<'a> {
    /// Wrap a shared view for one hogwild pool thread.
    ///
    /// # Safety
    /// For the lifetime of any returned accessor, every row it touches
    /// may be accessed concurrently ONLY through other
    /// [`RelaxedRowAccess`] handles (atomic path); non-atomic access
    /// from outside the pool is excluded by the level-1 Latin ownership
    /// (see [`SharedFactors`]).
    pub unsafe fn new(shared: &'a SharedFactors) -> Self {
        RelaxedRowAccess { shared }
    }
}

impl crate::kernel::FactorAccess for RelaxedRowAccess<'_> {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (o, slot) in out.iter_mut().zip(row.iter()) {
            *o = f32::from_bits(slot.load(Ordering::Relaxed));
        }
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (slot, &xk) in row.iter().zip(x.iter()) {
            let v = f32::from_bits(slot.load(Ordering::Relaxed));
            slot.store((beta * v + alpha * xk).to_bits(), Ordering::Relaxed);
        }
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (slot, &v) in row.iter().zip(src.iter()) {
            slot.store(v.to_bits(), Ordering::Relaxed);
        }
    }
}

/// The pooled-dispatch policy shared by the Latin workers and the serial
/// engine (one implementation — `parallel::worker::worker_pass` and
/// `algo::fasttucker` both call it):
///
/// * `threads > 1` and the plan has parallel width: **exact** plans run
///   their sub-group coloring's waves (threading only when the planner's
///   conflict-density gate [`planner::coloring_pays_off`] says the waves
///   pay for the barriers), through non-atomic [`SharedRowAccess`]
///   handles (waves are row-disjoint); **relaxed** plans run one hogwild
///   wave through atomic [`RelaxedRowAccess`] handles.
/// * otherwise: the sequential executor ([`batched::run_plan`]) on the
///   pool's primary workspace — which is also the exact fallback, and is
///   bitwise identical to the pooled exact path by the dispatch
///   contract.
///
/// `stats.threads`/`stats.waves` record what actually executed (both
/// stay at their builder defaults — 1/0 — on the sequential path, even
/// when a coloring was computed but rejected by the gate).
///
/// Cost note: with `threads > 1` in exact mode, the coloring pass (one
/// O(plan footprint) sweep, comparable to plan construction) runs on
/// every pass even when the gate then rejects it — pools are explicit
/// opt-in, so conflict-dense workloads pay a bounded planning overhead
/// until the gate verdict is cached per block (ROADMAP follow-up).
///
/// # Safety
/// Level-1 ownership: every factor row the plan touches must be owned
/// exclusively by this call for its duration — the Latin-round ownership
/// for a worker, or holding the only live reference to the factors for
/// the serial engine. Level-2 (intra-pool) safety is internal: exact
/// coloring waves are row-disjoint, relaxed dispatch is atomic.
#[allow(clippy::too_many_arguments)]
pub unsafe fn dispatch_plan(
    pool: &mut DispatchPool,
    tensor: &SparseTensor,
    plan: &BatchPlan,
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    shared: &SharedFactors,
    lr_f: f32,
    lam_f: f32,
    update_core: bool,
    stats: &mut PlanStats,
) -> KernelStats {
    let exactness = plan.params().exactness;
    let coloring = if pool.threads() > 1 && plan.n_groups() > 1 {
        match exactness {
            Exactness::Exact => {
                let c = plan.color_subgroups_with_scratch(tensor, pool.color_scratch_mut());
                planner::coloring_pays_off(&c.stats()).then_some(c)
            }
            Exactness::Relaxed => Some(SubGroupColoring::single_wave(plan.n_groups())),
        }
    } else {
        None
    };
    match coloring {
        Some(coloring) => {
            stats.threads = pool.threads();
            stats.waves = coloring.n_waves();
            match exactness {
                // SAFETY: level 1 per this function's contract; level 2:
                // exact waves have pairwise-disjoint row footprints.
                Exactness::Exact => pool.execute(
                    tensor,
                    plan,
                    &coloring,
                    core,
                    strided,
                    layout,
                    || unsafe { SharedRowAccess::new(shared) },
                    lr_f,
                    lam_f,
                    update_core,
                    None,
                ),
                // SAFETY: level 1 per this function's contract; level 2:
                // every pool thread uses the atomic hogwild path.
                Exactness::Relaxed => pool.execute(
                    tensor,
                    plan,
                    &coloring,
                    core,
                    strided,
                    layout,
                    || unsafe { RelaxedRowAccess::new(shared) },
                    lr_f,
                    lam_f,
                    update_core,
                    None,
                ),
            }
        }
        None => {
            // SAFETY: level 1 per this function's contract; no intra-pool
            // concurrency on the sequential path.
            let mut access = unsafe { SharedRowAccess::new(shared) };
            batched::run_plan(
                pool.primary_mut(),
                tensor,
                plan,
                core,
                strided,
                layout,
                &mut access,
                lr_f,
                lam_f,
                update_core,
                None,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn disjoint_parallel_writes_are_visible() {
        let mut rng = Rng::new(1);
        let mut factors = FactorMatrices::random(&mut rng, &[64, 64], 4, 1.0);
        let shared = SharedFactors::new(&mut factors);
        std::thread::scope(|scope| {
            for w in 0..4 {
                let shared = &shared;
                scope.spawn(move || {
                    // Worker w owns rows [w*16, (w+1)*16) of both modes.
                    for n in 0..2 {
                        for i in w * 16..(w + 1) * 16 {
                            let row = unsafe { shared.row_mut(n, i) };
                            for v in row {
                                *v = (n * 1000 + w) as f32;
                            }
                        }
                    }
                });
            }
        });
        for n in 0..2 {
            for w in 0..4 {
                for i in w * 16..(w + 1) * 16 {
                    assert!(factors
                        .row(n, i)
                        .iter()
                        .all(|&v| v == (n * 1000 + w) as f32));
                }
            }
        }
    }
}
