//! Unsafe-but-proven shared factor storage for the multi-device engine.
//!
//! # The three-level disjointness contract
//!
//! Concurrent row access through [`SharedFactors`] is sound because
//! nested partitions guarantee writers never collide — the CPU analogue
//! of the paper's nested levels of parallelism (device grid × inter-GPU
//! Latin rounds × intra-GPU thread blocks):
//!
//! 0. **Device grid (across devices):** the
//!    [`DeviceGrid`](super::DeviceGrid) groups the Latin workers onto
//!    `D` devices as contiguous ranges. It is a *coarsening* of the
//!    Latin level — two devices' row footprints in a round are unions of
//!    their workers' pairwise-disjoint footprints — so it introduces no
//!    new aliasing and only decides which device is accounted for each
//!    pass, which boundary rows the communication step counts, and the
//!    order of the per-epoch Eq. 17 core-gradient merge (flat worker-
//!    order fold in exact mode — the bitwise-at-every-`D` contract,
//!    pinned by
//!    `tests/properties.rs::prop_sharded_exact_bitwise_matches_single_device`
//!    — or the relaxed two-stage device tree).
//! 1. **Latin schedule (across workers):** within one scheduling round,
//!    [`LatinSchedule`](super::LatinSchedule) guarantees the workers'
//!    blocks are pairwise disjoint in every mode's chunk index, so the
//!    factor rows any two *workers* touch never overlap (pinned by
//!    `parallel::schedule::tests::prop_conflict_free_and_covering`).
//! 2. **Color waves (within a worker):** when a worker fans its plan's
//!    split sub-groups across an in-group thread pool
//!    ([`DispatchPool`](crate::kernel::dispatch::DispatchPool)), the
//!    sub-group coloring
//!    ([`BatchPlan::color_subgroups`](crate::kernel::BatchPlan::color_subgroups))
//!    guarantees same-wave sub-groups have pairwise-disjoint row
//!    footprints in every mode, so the *pool threads* never collide
//!    either; waves are barrier-separated, which also replays every
//!    conflicting sub-group pair in its sequential order (the exact-mode
//!    bitwise contract, pinned by
//!    `tests/properties.rs::prop_subgroup_coloring_is_disjoint_ordered_partition`
//!    and `prop_threaded_exact_bitwise_matches_sequential`).
//!
//! The single deliberate exception is **relaxed (hogwild) pooled
//! dispatch**: a single wave of freely-concurrent sub-groups may update
//! shared rows concurrently — the paper's GPU write semantics, opted into
//! explicitly via `Exactness::Relaxed` and pinned as an accuracy envelope
//! rather than a bitwise contract. Those accesses go through
//! [`RelaxedRowAccess`] (relaxed-atomic element loads/stores), so racing
//! updates can lose writes but are well-defined — never the aliasing
//! `&mut` UB the plain [`SharedRowAccess`] path would incur.
//!
//! # The message-passing exchange (transport layer)
//!
//! With `transport = channel` (ISSUE 7), the round-boundary parameter
//! exchange is no longer pure bookkeeping: the coordinator serializes
//! every inter-device boundary-row panel, routes it through
//! [`crate::parallel::transport`] as a framed, checksummed message, and
//! writes the *validated* payload back before releasing the round's
//! workers. Those reads/writes use the dedicated
//! [`SharedFactors::row_exchange`]/[`SharedFactors::row_mut_exchange`]
//! accessors. The write-back side is sound for a simpler reason than the
//! three levels above: it runs **coordinator-serial at the round
//! barrier**, when no worker thread is live — there is nothing to be
//! disjoint *from*. The read side has two sound callers:
//!
//! 1. the coordinator at the barrier (same no-worker-live argument), the
//!    synchronous exchange path; and
//! 2. with async prefetch (ISSUE 8), **the owning worker itself, after
//!    its own round pass** — the Latin schedule gives that worker
//!    exclusive ownership of the chunk for the entire round, its pass
//!    has finished writing the rows, and no other worker may touch them
//!    until the next barrier, so the post-pass serialization read is the
//!    only access to those rows even while *other* workers are still
//!    computing. This is what lets round r+1's outgoing panels enter the
//!    transport while round r is still in flight; the **apply**
//!    (`row_mut_exchange`) never moves — it stays coordinator-serial at
//!    the barrier, which is the exact-mode bitwise argument.
//!
//! What is bitwise: the healthy exchange (exact little-endian f32
//! round-trips applied by the same single actor). What retries: frames
//! lost, duplicated, reordered, delayed, or detectably corrupted —
//! recovered by the exchanger's resend/dedup/buffering protocol without
//! touching the factors with bad bytes. What degrades or fails: an
//! exhausted retry budget, a dead device, or a protocol violation
//! aborts `train_epoch` with a typed
//! [`TransportError`](crate::parallel::TransportError) — the factors
//! are never silently corrupted. The in-flight protocol is audited from
//! outside by [`crate::analysis::audit_exchange`].
//!
//! This module is the **single authoritative statement** of the
//! contract; the `unsafe impl Send/Sync` below and every `# Safety`
//! section cite it. It is checked from outside by
//! [`crate::analysis`]: the disjointness auditor re-derives all three
//! levels from first principles (`strict-audit` runs it on every
//! coloring/grid the engines build), and the `shadow-ledger` feature
//! compiles provenance hooks into the three row accessors so the shadow
//! race detector can replay a run's accesses against the wave/round
//! structure.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::kernel::contract::CoreLayout;
use crate::kernel::{
    batched, planner, BatchPlan, DispatchPool, Exactness, KernelStats, SubGroupColoring,
};
use crate::kruskal::KruskalCore;
use crate::metrics::PlanStats;
use crate::model::factors::FactorMatrices;
use crate::tensor::SparseTensor;

/// A `Sync` view over factor matrices allowing per-row mutable access from
/// multiple threads, provided callers honor the three-level disjointness
/// contract above.
pub struct SharedFactors {
    ptrs: Vec<*mut f32>,
    rows: Vec<usize>,
    cols: usize,
}

// SAFETY: `SharedFactors` is a bag of raw pointers into factor storage
// the constructor borrowed mutably, so the aliasing rules hinge entirely
// on the three-level disjointness contract in the module docs:
//
// * `Send` — the view holds no thread-affine state; moving it (or a
//   reference) to another thread moves only pointers whose pointees the
//   caller keeps alive across the thread scope (constructor contract).
// * `Sync` — concurrent `&SharedFactors` access is sound because every
//   mutation goes through `row_mut` (level-1 Latin ownership + level-2
//   wave disjointness ⇒ one thread per row at a time), every read
//   through `row` targets rows the reading worker owns in the current
//   round, and hogwild mode swaps BOTH sides to the `row_atomic` path —
//   racy but atomic, never a plain-access data race.
//
// The contract is verified from outside: `analysis::audit` re-derives
// the row-disjointness of every coloring/schedule/grid the engines
// build (`strict-audit`), and `analysis::shadow` checks recorded
// accesses against the wave structure (`shadow-ledger`).
//
// SAFETY: the `Sync` bullet above.
unsafe impl Sync for SharedFactors {}
// SAFETY: see the `Sync` justification above (`Send` bullet).
unsafe impl Send for SharedFactors {}

// The hogwild path reinterprets `*mut f32` as `&[AtomicU32]`; that is
// only layout-sound while the two types agree exactly.
const _: () = assert!(std::mem::size_of::<f32>() == std::mem::size_of::<AtomicU32>());
const _: () = assert!(std::mem::align_of::<f32>() == std::mem::align_of::<AtomicU32>());

impl SharedFactors {
    /// Wrap `factors`; the borrow is held for `'_`'s scope by the caller
    /// (the parallel engine keeps the `&mut FactorMatrices` alive across
    /// the thread scope).
    pub fn new(factors: &mut FactorMatrices) -> Self {
        let cols = factors.rank();
        let rows = factors.dims();
        let ptrs = (0..factors.order())
            .map(|n| factors.mat_mut(n).data_mut().as_mut_ptr())
            .collect();
        SharedFactors { ptrs, rows, cols }
    }

    pub fn order(&self) -> usize {
        self.ptrs.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read row `i` of mode `n`.
    ///
    /// # Safety
    /// No other thread may be writing row `(n, i)` concurrently — holds
    /// whenever `(n, i)` lies inside the calling worker's round assignment.
    #[inline]
    pub unsafe fn row(&self, n: usize, i: usize) -> &[f32] {
        debug_assert!(n < self.ptrs.len(), "mode {n} out of range ({})", self.ptrs.len());
        debug_assert!(i < self.rows[n], "row {i} out of range for mode {n} ({})", self.rows[n]);
        #[cfg(feature = "shadow-ledger")]
        crate::analysis::shadow::record(n, i, crate::analysis::shadow::AccessKind::Read);
        // SAFETY: in-bounds by the asserts above (callers index real
        // factor geometry); no concurrent writer per the fn contract.
        unsafe { std::slice::from_raw_parts(self.ptrs[n].add(i * self.cols), self.cols) }
    }

    /// Mutable row access; same contract as [`Self::row`] plus exclusivity.
    ///
    /// # Safety
    /// The calling worker must be the unique owner of row `(n, i)` in the
    /// current round.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, n: usize, i: usize) -> &mut [f32] {
        debug_assert!(n < self.ptrs.len(), "mode {n} out of range ({})", self.ptrs.len());
        debug_assert!(i < self.rows[n], "row {i} out of range for mode {n} ({})", self.rows[n]);
        #[cfg(feature = "shadow-ledger")]
        crate::analysis::shadow::record(n, i, crate::analysis::shadow::AccessKind::Write);
        // SAFETY: in-bounds by the asserts above; the fn contract makes
        // this thread the row's unique owner, so minting `&mut` cannot
        // alias another live reference.
        unsafe { std::slice::from_raw_parts_mut(self.ptrs[n].add(i * self.cols), self.cols) }
    }

    /// Row `(n, i)` as relaxed-atomic words (f32 bit patterns) — the
    /// hogwild access path: concurrent readers/writers are well-defined
    /// (individual element updates may be lost, never torn into UB).
    ///
    /// # Safety
    /// While any thread accesses a row atomically, no thread may hold a
    /// plain `&`/`&mut` reference to it ([`Self::row`]/[`Self::row_mut`])
    /// — mixing the two access modes on one row is a data race again.
    #[inline]
    pub unsafe fn row_atomic(&self, n: usize, i: usize) -> &[AtomicU32] {
        debug_assert!(n < self.ptrs.len(), "mode {n} out of range ({})", self.ptrs.len());
        debug_assert!(i < self.rows[n], "row {i} out of range for mode {n} ({})", self.rows[n]);
        #[cfg(feature = "shadow-ledger")]
        crate::analysis::shadow::record(n, i, crate::analysis::shadow::AccessKind::Atomic);
        // SAFETY: in-bounds by the asserts above; f32 and AtomicU32
        // share size and alignment (const-asserted at module level); the
        // factor storage outlives `self` per the constructor's contract,
        // and the fn contract excludes concurrent plain references.
        unsafe {
            std::slice::from_raw_parts(
                self.ptrs[n].add(i * self.cols) as *const AtomicU32,
                self.cols,
            )
        }
    }

    /// Read row `i` of mode `n` for transport serialization — the
    /// coordinator's exchange path. Unlike [`Self::row`] this records
    /// nothing in the shadow ledger: the exchange runs between rounds
    /// with stale worker context, and its correctness is checked by the
    /// protocol auditor ([`crate::analysis::audit_exchange`]) instead of
    /// the per-row race detector (see `analysis::shadow`'s module doc).
    ///
    /// # Safety
    /// Caller must be one of the two exclusive readers of the module
    /// contract's exchange section: (a) the coordinator at a round
    /// barrier — no worker thread is live (the engine's thread scopes
    /// are closed), so no concurrent access to any row exists — or
    /// (b) the Latin worker owning the chunk containing row `i` in the
    /// current round, strictly *after* its own pass over the round has
    /// returned (the async prefetch path): ownership makes this worker
    /// the only thread allowed to touch the row until the next barrier,
    /// and its pass having finished means it is no longer writing.
    #[inline]
    pub unsafe fn row_exchange(&self, n: usize, i: usize) -> &[f32] {
        debug_assert!(n < self.ptrs.len(), "mode {n} out of range ({})", self.ptrs.len());
        debug_assert!(i < self.rows[n], "row {i} out of range for mode {n} ({})", self.rows[n]);
        // SAFETY: in-bounds by the asserts above; exclusive per the fn
        // contract — either coordinator-serial at the barrier, or the
        // post-pass read of the round's sole owner.
        unsafe { std::slice::from_raw_parts(self.ptrs[n].add(i * self.cols), self.cols) }
    }

    /// Write-back access for a validated transport payload. Unlike
    /// [`Self::row_exchange`], this has **no** worker-side caller: the
    /// apply always lands at the barrier, even under async prefetch
    /// (that asymmetry — transfer may move early, apply may not — is the
    /// exact-mode bitwise argument of the module contract).
    ///
    /// # Safety
    /// Caller must be the coordinator at a round barrier: no worker
    /// thread may be live, making this the only reference to the row.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut_exchange(&self, n: usize, i: usize) -> &mut [f32] {
        debug_assert!(n < self.ptrs.len(), "mode {n} out of range ({})", self.ptrs.len());
        debug_assert!(i < self.rows[n], "row {i} out of range for mode {n} ({})", self.rows[n]);
        // SAFETY: in-bounds by the asserts above; coordinator-serial per
        // the fn contract, so the minted `&mut` cannot alias any live
        // reference.
        unsafe { std::slice::from_raw_parts_mut(self.ptrs[n].add(i * self.cols), self.cols) }
    }
}

/// [`FactorAccess`](crate::kernel::FactorAccess) view over
/// [`SharedFactors`], letting a Latin-schedule worker drive the shared
/// kernel ([`crate::kernel::batched`] / [`crate::kernel::scalar`])
/// directly against the logically-global factor matrices.
pub struct SharedRowAccess<'a> {
    shared: &'a SharedFactors,
}

impl<'a> SharedRowAccess<'a> {
    /// Wrap a shared view for one worker.
    ///
    /// # Safety
    /// Every row `(n, i)` subsequently staged/updated/stored through the
    /// returned accessor must be exclusively owned by the calling worker
    /// for the duration of the current scheduling round (the
    /// [`LatinSchedule`](super::LatinSchedule) invariant): no other thread
    /// may read or write those rows concurrently.
    pub unsafe fn new(shared: &'a SharedFactors) -> Self {
        SharedRowAccess { shared }
    }
}

impl crate::kernel::FactorAccess for SharedRowAccess<'_> {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        // SAFETY: ownership per the constructor's contract.
        out.copy_from_slice(unsafe { self.shared.row(n, i) });
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        crate::util::linalg::scale_axpy(beta, alpha, x, unsafe {
            self.shared.row_mut(n, i)
        });
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        unsafe { self.shared.row_mut(n, i) }.copy_from_slice(src);
    }
}

/// Hogwild-safe [`FactorAccess`](crate::kernel::FactorAccess) over
/// [`SharedFactors`] for **relaxed pooled dispatch**: every element
/// access is a relaxed-atomic load/store of the f32 bit pattern, so
/// concurrent updates to a shared row are well-defined — racing
/// read-modify-writes may *lose* an update (the paper's GPU write
/// semantics, accuracy-pinned by the relaxed RMSE envelope) but can
/// never tear into undefined behavior the way aliasing `&mut` rows
/// would. Element-wise arithmetic is identical to
/// [`SharedRowAccess`] (`row[k] = beta·row[k] + alpha·x[k]`), so a
/// race-free relaxed pass computes the same values.
pub struct RelaxedRowAccess<'a> {
    shared: &'a SharedFactors,
}

impl<'a> RelaxedRowAccess<'a> {
    /// Wrap a shared view for one hogwild pool thread.
    ///
    /// # Safety
    /// For the lifetime of any returned accessor, every row it touches
    /// may be accessed concurrently ONLY through other
    /// [`RelaxedRowAccess`] handles (atomic path); non-atomic access
    /// from outside the pool is excluded by the level-1 Latin ownership
    /// (see [`SharedFactors`]).
    pub unsafe fn new(shared: &'a SharedFactors) -> Self {
        RelaxedRowAccess { shared }
    }
}

impl crate::kernel::FactorAccess for RelaxedRowAccess<'_> {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (o, slot) in out.iter_mut().zip(row.iter()) {
            *o = f32::from_bits(slot.load(Ordering::Relaxed));
        }
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (slot, &xk) in row.iter().zip(x.iter()) {
            let v = f32::from_bits(slot.load(Ordering::Relaxed));
            slot.store((beta * v + alpha * xk).to_bits(), Ordering::Relaxed);
        }
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        // SAFETY: atomic-only concurrent access per constructor contract.
        let row = unsafe { self.shared.row_atomic(n, i) };
        for (slot, &v) in row.iter().zip(src.iter()) {
            slot.store(v.to_bits(), Ordering::Relaxed);
        }
    }
}

/// The pooled-dispatch policy shared by the Latin workers and the serial
/// engine (one implementation — `parallel::worker::worker_pass` and
/// `algo::fasttucker` both call it):
///
/// * `threads > 1` and the plan has parallel width: **exact** plans run
///   their sub-group coloring's waves (threading only when the planner's
///   conflict-density gate [`planner::coloring_pays_off`] says the waves
///   pay for the barriers), through non-atomic [`SharedRowAccess`]
///   handles (waves are row-disjoint); **relaxed** plans run one hogwild
///   wave through atomic [`RelaxedRowAccess`] handles.
/// * otherwise: the sequential executor ([`batched::run_plan`]) on the
///   pool's primary workspace — which is also the exact fallback, and is
///   bitwise identical to the pooled exact path by the dispatch
///   contract.
///
/// `stats.threads`/`stats.waves` record what actually executed (both
/// stay at their builder defaults — 1/0 — on the sequential path, even
/// when a coloring was computed but rejected by the gate). A *relaxed*
/// plan that falls to the sequential path despite a multi-thread pool
/// (≤ 1 sub-group: degenerate shard geometry) additionally sets
/// `stats.degraded` — the caller asked for hogwild and got exact-style
/// sequential access, which is safe but worth surfacing.
///
/// Cost note: with `threads > 1` in exact mode, the coloring pass (one
/// O(plan footprint) sweep, comparable to plan construction) and the
/// pays-off verdict are **memoized per
/// `(plan fingerprint, tensor revision)`** on the pool
/// ([`DispatchPool::cached_coloring`]) — a worker re-running an
/// unchanged plan every epoch pays the sweep once, not per pass
/// (ISSUE 10 carried follow-up). The fingerprint pins the exact group
/// structure, the revision the coordinates the conflict graph reads, and
/// a pool is rebuilt on thread-count changes, so a hit is exactly the
/// coloring the fresh sweep would produce.
///
/// Plans with [`PlanParams::wide_accum`](crate::kernel::PlanParams) set
/// never engage the pool: wide (f64) accumulation is a sequential
/// relaxed-path feature ([`batched::run_plan`]), and a multi-thread pool
/// asked to run one degrades loudly like the other shape mismatches.
///
/// # Safety
/// Level-1 ownership: every factor row the plan touches must be owned
/// exclusively by this call for its duration — the Latin-round ownership
/// for a worker, or holding the only live reference to the factors for
/// the serial engine. Level-2 (intra-pool) safety is internal: exact
/// coloring waves are row-disjoint, relaxed dispatch is atomic.
#[allow(clippy::too_many_arguments)]
pub unsafe fn dispatch_plan(
    pool: &mut DispatchPool,
    tensor: &SparseTensor,
    plan: &BatchPlan,
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    shared: &SharedFactors,
    lr_f: f32,
    lam_f: f32,
    update_core: bool,
    stats: &mut PlanStats,
) -> KernelStats {
    let exactness = plan.params().exactness;
    let wide = plan.params().wide_accum;
    let coloring = if pool.threads() > 1 && plan.n_groups() > 1 && !wide {
        match exactness {
            Exactness::Exact => {
                // Memoized coloring + gate verdict (see the cost note):
                // keyed on the plan's grouping fingerprint and the
                // tensor's content revision, both of which fully
                // determine the conflict graph.
                let key = (plan.fingerprint(), tensor.revision());
                let cached = pool.cached_coloring(key).map(|v| v.cloned());
                match cached {
                    Some(verdict) => verdict,
                    None => {
                        let c = plan
                            .color_subgroups_with_scratch(tensor, pool.color_scratch_mut());
                        #[cfg(feature = "strict-audit")]
                        crate::analysis::audit_coloring(
                            tensor,
                            plan,
                            &crate::analysis::waves_of(&c),
                        )
                        .assert_clean("sub-group coloring");
                        let verdict = planner::coloring_pays_off(&c.stats()).then_some(c);
                        pool.record_coloring(key, verdict.clone());
                        verdict
                    }
                }
            }
            Exactness::Relaxed => Some(SubGroupColoring::single_wave(plan.n_groups())),
        }
    } else {
        if exactness == Exactness::Relaxed && pool.threads() > 1 && !plan.is_empty() {
            // A relaxed plan that cannot engage the pool (≤ 1 sub-group:
            // a degenerate shard — e.g. a zero-row factor mode collapsed
            // the geometry — or a too-small batch; or wide f64
            // accumulation, which is sequential by design) silently runs
            // the sequential path below. That is safe and numerically
            // fine, but it is not the hogwild execution the config asked
            // for — degrade loudly like the PR 4/5 clamps instead of
            // masking the shape problem. (Wide accumulation still
            // applies on the sequential path.)
            stats.degraded = true;
        }
        None
    };
    match coloring {
        Some(coloring) => {
            stats.threads = pool.threads();
            stats.waves = coloring.n_waves();
            match exactness {
                // SAFETY: level 1 per this function's contract; level 2:
                // exact waves have pairwise-disjoint row footprints.
                Exactness::Exact => pool.execute(
                    tensor,
                    plan,
                    &coloring,
                    core,
                    strided,
                    layout,
                    || unsafe { SharedRowAccess::new(shared) },
                    lr_f,
                    lam_f,
                    update_core,
                    None,
                ),
                // SAFETY: level 1 per this function's contract; level 2:
                // every pool thread uses the atomic hogwild path.
                Exactness::Relaxed => pool.execute(
                    tensor,
                    plan,
                    &coloring,
                    core,
                    strided,
                    layout,
                    || unsafe { RelaxedRowAccess::new(shared) },
                    lr_f,
                    lam_f,
                    update_core,
                    None,
                ),
            }
        }
        None => {
            // SAFETY: level 1 per this function's contract; no intra-pool
            // concurrency on the sequential path.
            let mut access = unsafe { SharedRowAccess::new(shared) };
            batched::run_plan(
                pool.primary_mut(),
                tensor,
                plan,
                core,
                strided,
                layout,
                &mut access,
                lr_f,
                lam_f,
                update_core,
                None,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::PlanParams;
    use crate::util::Rng;

    // The `unsafe_access_*` tests below are deliberately tiny: they are
    // the Miri CI leg (`cargo miri test --lib -- unsafe_access_`), where
    // interpreted execution is ~100x slower, and they concentrate every
    // raw-pointer/atomic pattern the accessors mint.

    #[test]
    fn unsafe_access_disjoint_parallel_writes_are_visible() {
        let mut rng = Rng::new(1);
        let mut factors = FactorMatrices::random(&mut rng, &[64, 64], 4, 1.0);
        let shared = SharedFactors::new(&mut factors);
        std::thread::scope(|scope| {
            for w in 0..4 {
                let shared = &shared;
                scope.spawn(move || {
                    // Worker w owns rows [w*16, (w+1)*16) of both modes.
                    for n in 0..2 {
                        for i in w * 16..(w + 1) * 16 {
                            // SAFETY: this thread is the unique owner of
                            // row (n, i) — the row ranges are disjoint
                            // across the four workers by construction.
                            let row = unsafe { shared.row_mut(n, i) };
                            for v in row {
                                *v = (n * 1000 + w) as f32;
                            }
                        }
                    }
                });
            }
        });
        for n in 0..2 {
            for w in 0..4 {
                for i in w * 16..(w + 1) * 16 {
                    assert!(factors
                        .row(n, i)
                        .iter()
                        .all(|&v| v == (n * 1000 + w) as f32));
                }
            }
        }
    }

    #[test]
    fn unsafe_access_atomic_rows_tolerate_contention() {
        // Two threads hammer the SAME rows through the hogwild path:
        // every interleaving is well-defined (Miri/TSan-visible), and
        // each element must end up holding one of the written values.
        let mut rng = Rng::new(2);
        let mut factors = FactorMatrices::random(&mut rng, &[8, 8], 4, 1.0);
        let shared = SharedFactors::new(&mut factors);
        std::thread::scope(|scope| {
            for t in 0..2u32 {
                let shared = &shared;
                scope.spawn(move || {
                    for n in 0..2 {
                        for i in 0..8 {
                            // SAFETY: all concurrent access to these
                            // rows goes through the atomic path.
                            let row = unsafe { shared.row_atomic(n, i) };
                            for slot in row {
                                slot.store(((100 + t) as f32).to_bits(), Ordering::Relaxed);
                                let _ = f32::from_bits(slot.load(Ordering::Relaxed));
                            }
                        }
                    }
                });
            }
        });
        for n in 0..2 {
            for i in 0..8 {
                for &v in factors.row(n, i) {
                    assert!(v == 100.0 || v == 101.0, "torn value {v}");
                }
            }
        }
    }

    #[test]
    fn unsafe_access_mixed_modes_on_disjoint_rows() {
        // Plain-mut and atomic access may coexist as long as they touch
        // DISJOINT rows (the mixed-mode hazard is per-row).
        let mut rng = Rng::new(3);
        let mut factors = FactorMatrices::random(&mut rng, &[16], 4, 1.0);
        let shared = SharedFactors::new(&mut factors);
        std::thread::scope(|scope| {
            let s = &shared;
            scope.spawn(move || {
                for i in 0..8 {
                    // SAFETY: rows 0..8 are exclusively this thread's.
                    unsafe { s.row_mut(0, i) }.fill(1.0);
                }
            });
            scope.spawn(move || {
                for i in 8..16 {
                    // SAFETY: rows 8..16 are only touched atomically.
                    for slot in unsafe { s.row_atomic(0, i) } {
                        slot.store(2.0f32.to_bits(), Ordering::Relaxed);
                    }
                }
            });
        });
        for i in 0..8 {
            assert!(factors.row(0, i).iter().all(|&v| v == 1.0));
        }
        for i in 8..16 {
            assert!(factors.row(0, i).iter().all(|&v| v == 2.0));
        }
    }

    #[test]
    fn unsafe_access_exchange_rows_roundtrip_bitwise() {
        // The coordinator-serial exchange accessors (ISSUE 7): serialize
        // rows to little-endian bytes, write them back — exact bitwise
        // round-trip, no worker threads involved (Miri-checks the
        // raw-pointer pattern the transport write-back mints).
        let mut rng = Rng::new(7);
        let mut factors = FactorMatrices::random(&mut rng, &[8, 6], 4, 1.0);
        let before: Vec<u32> =
            (0..8).flat_map(|i| factors.row(0, i).iter().map(|v| v.to_bits())).collect();
        let shared = SharedFactors::new(&mut factors);
        let mut bytes = Vec::new();
        for i in 0..8 {
            // SAFETY: no worker threads exist — the test is the
            // coordinator at an (empty) barrier.
            for &v in unsafe { shared.row_exchange(0, i) } {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        for i in 0..8 {
            // SAFETY: no worker threads exist (see above).
            let row = unsafe { shared.row_mut_exchange(0, i) };
            for (c, item) in row.iter_mut().enumerate() {
                let o = (i * 4 + c) * 4;
                *item = f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
            }
        }
        drop(shared);
        let after: Vec<u32> =
            (0..8).flat_map(|i| factors.row(0, i).iter().map(|v| v.to_bits())).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn relaxed_plan_without_pool_width_degrades_loudly() {
        // ISSUE 6 satellite: a relaxed plan with <= 1 sub-group cannot
        // engage the hogwild pool and silently runs the sequential
        // exact-style path — that must be recorded in PlanStats::degraded.
        let mut rng = Rng::new(4);
        let dims = [12usize, 6, 5];
        let t = synth::random_uniform(&mut rng, &dims, 6, 1.0, 5.0);
        let ids: Vec<u32> = (0..t.nnz() as u32).collect();
        let mut factors = FactorMatrices::random(&mut rng, &dims, 4, 0.1);
        let core = KruskalCore::random(&mut rng, 3, 4, 4, 0.1);
        let run = |params: PlanParams, threads: usize, factors: &mut FactorMatrices| {
            let plan = BatchPlan::build_params(&t, &ids, params);
            let mut pool = DispatchPool::new(threads, 3, 4, 4, plan.max_batch());
            let mut stats = plan.stats();
            let shared = SharedFactors::new(factors);
            // SAFETY: the test holds the only live factor reference.
            unsafe {
                dispatch_plan(
                    &mut pool, &t, &plan, &core, &[], CoreLayout::Packed, &shared, 0.01,
                    0.001, false, &mut stats,
                )
            };
            stats
        };
        // cap >= nnz: one sub-group. Relaxed + 2 threads => degraded.
        let stats = run(PlanParams::relaxed(64, 8), 2, &mut factors);
        assert_eq!(stats.threads, 1);
        assert!(stats.degraded, "degenerate relaxed fallback must degrade loudly");
        // Same geometry, sequential pool: sequential is what was asked.
        let stats = run(PlanParams::relaxed(64, 8), 1, &mut factors);
        assert!(!stats.degraded);
        // Exact mode falling back is the documented bitwise-identical
        // path, not a degradation.
        let stats = run(PlanParams::tiled(64, 8), 2, &mut factors);
        assert!(!stats.degraded);
        // A relaxed plan with real pool width engages the single wave
        // and stays clean.
        let t_wide = synth::random_uniform(&mut Rng::new(5), &[64, 32, 32], 600, 1.0, 5.0);
        let ids_wide: Vec<u32> = (0..t_wide.nnz() as u32).collect();
        let plan = BatchPlan::build_params(&t_wide, &ids_wide, PlanParams::relaxed(16, 8));
        assert!(plan.n_groups() > 1, "workload must have pool width");
        let mut factors_wide = FactorMatrices::random(&mut Rng::new(6), &[64, 32, 32], 4, 0.1);
        let mut pool = DispatchPool::new(2, 3, 4, 4, plan.max_batch());
        let mut stats = plan.stats();
        let shared = SharedFactors::new(&mut factors_wide);
        // SAFETY: the test holds the only live factor reference.
        unsafe {
            dispatch_plan(
                &mut pool, &t_wide, &plan, &core, &[], CoreLayout::Packed, &shared, 0.01,
                0.001, false, &mut stats,
            )
        };
        assert!(!stats.degraded);
        assert_eq!(stats.threads, 2);
    }
}
