//! Unsafe-but-proven shared factor storage for the multi-device engine.
//!
//! Within one scheduling round, [`LatinSchedule`](super::LatinSchedule)
//! guarantees the workers' blocks are pairwise disjoint in every mode's
//! chunk index, so the factor rows any two workers touch never overlap.
//! [`SharedFactors`] exposes raw row access under exactly that invariant
//! (which `parallel::schedule::tests::prop_conflict_free_and_covering`
//! pins); it is the CPU analogue of multiple GPUs updating disjoint slices
//! of the same logically-global factor matrices.

use crate::model::factors::FactorMatrices;

/// A `Sync` view over factor matrices allowing per-row mutable access from
/// multiple threads, provided callers honor the disjointness contract.
pub struct SharedFactors {
    ptrs: Vec<*mut f32>,
    rows: Vec<usize>,
    cols: usize,
}

// SAFETY: all mutation goes through `row_mut_unchecked`, whose contract
// (disjoint rows across threads within a round) is enforced by the Latin
// schedule; reads of rows owned by other workers do not occur within a
// round because every mode chunk a worker reads is also one it owns.
unsafe impl Sync for SharedFactors {}
unsafe impl Send for SharedFactors {}

impl SharedFactors {
    /// Wrap `factors`; the borrow is held for `'_`'s scope by the caller
    /// (the parallel engine keeps the `&mut FactorMatrices` alive across
    /// the thread scope).
    pub fn new(factors: &mut FactorMatrices) -> Self {
        let cols = factors.rank();
        let rows = factors.dims();
        let ptrs = (0..factors.order())
            .map(|n| factors.mat_mut(n).data_mut().as_mut_ptr())
            .collect();
        SharedFactors { ptrs, rows, cols }
    }

    pub fn order(&self) -> usize {
        self.ptrs.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read row `i` of mode `n`.
    ///
    /// # Safety
    /// No other thread may be writing row `(n, i)` concurrently — holds
    /// whenever `(n, i)` lies inside the calling worker's round assignment.
    #[inline]
    pub unsafe fn row(&self, n: usize, i: usize) -> &[f32] {
        debug_assert!(i < self.rows[n]);
        std::slice::from_raw_parts(self.ptrs[n].add(i * self.cols), self.cols)
    }

    /// Mutable row access; same contract as [`Self::row`] plus exclusivity.
    ///
    /// # Safety
    /// The calling worker must be the unique owner of row `(n, i)` in the
    /// current round.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, n: usize, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows[n]);
        std::slice::from_raw_parts_mut(self.ptrs[n].add(i * self.cols), self.cols)
    }
}

/// [`FactorAccess`](crate::kernel::FactorAccess) view over
/// [`SharedFactors`], letting a Latin-schedule worker drive the shared
/// kernel ([`crate::kernel::batched`] / [`crate::kernel::scalar`])
/// directly against the logically-global factor matrices.
pub struct SharedRowAccess<'a> {
    shared: &'a SharedFactors,
}

impl<'a> SharedRowAccess<'a> {
    /// Wrap a shared view for one worker.
    ///
    /// # Safety
    /// Every row `(n, i)` subsequently staged/updated/stored through the
    /// returned accessor must be exclusively owned by the calling worker
    /// for the duration of the current scheduling round (the
    /// [`LatinSchedule`](super::LatinSchedule) invariant): no other thread
    /// may read or write those rows concurrently.
    pub unsafe fn new(shared: &'a SharedFactors) -> Self {
        SharedRowAccess { shared }
    }
}

impl crate::kernel::FactorAccess for SharedRowAccess<'_> {
    #[inline]
    fn stage(&self, n: usize, i: usize, out: &mut [f32]) {
        // SAFETY: ownership per the constructor's contract.
        out.copy_from_slice(unsafe { self.shared.row(n, i) });
    }

    #[inline]
    fn update(&mut self, n: usize, i: usize, beta: f32, alpha: f32, x: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        crate::util::linalg::scale_axpy(beta, alpha, x, unsafe {
            self.shared.row_mut(n, i)
        });
    }

    #[inline]
    fn store(&mut self, n: usize, i: usize, src: &[f32]) {
        // SAFETY: exclusive ownership per the constructor's contract.
        unsafe { self.shared.row_mut(n, i) }.copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn disjoint_parallel_writes_are_visible() {
        let mut rng = Rng::new(1);
        let mut factors = FactorMatrices::random(&mut rng, &[64, 64], 4, 1.0);
        let shared = SharedFactors::new(&mut factors);
        std::thread::scope(|scope| {
            for w in 0..4 {
                let shared = &shared;
                scope.spawn(move || {
                    // Worker w owns rows [w*16, (w+1)*16) of both modes.
                    for n in 0..2 {
                        for i in w * 16..(w + 1) * 16 {
                            let row = unsafe { shared.row_mut(n, i) };
                            for v in row {
                                *v = (n * 1000 + w) as f32;
                            }
                        }
                    }
                });
            }
        });
        for n in 0..2 {
            for w in 0..4 {
                for i in w * 16..(w + 1) * 16 {
                    assert!(factors
                        .row(n, i)
                        .iter()
                        .all(|&v| v == (n * 1000 + w) as f32));
                }
            }
        }
    }
}
