//! The device-shard layer (ISSUE 5 tentpole): the paper's **data-division
//! and communication strategy** across D GPUs, layered over the Latin
//! worker engine.
//!
//! # The division strategy
//!
//! The Latin engine ([`super::worker`]) already cuts every mode into `W`
//! chunks and runs `W` row-disjoint workers per round; worker `g` is
//! pinned to mode-0 chunk `g` for the whole epoch (the schedule rotates
//! only modes ≥ 1). A [`DeviceGrid`] with `D ≤ W` devices groups those
//! workers onto devices as contiguous, balanced ranges:
//!
//! * **Nonzero division** — device `d` owns exactly the training
//!   nonzeros whose mode-0 row falls in its workers' chunks (every
//!   nonzero lands on exactly one device; pinned by the unit tests
//!   below). This is the paper's HOHDST tensor sharding.
//! * **Row ownership** — device `d` *homes* the factor chunks whose
//!   chunk index equals one of its worker ids (mode 0 statically, modes
//!   ≥ 1 as the replication home). In a given round, the chunks its
//!   workers process but does not home are its **boundary rows** — the
//!   rows the paper's parameter-exchange step ships between GPUs. The
//!   boundary set and the homed set are exact complements inside the
//!   set of rows the device touches that round.
//! * **Communication** — at each round boundary the engine asks which
//!   chunks changed hands *across devices*
//!   ([`LatinSchedule::owner_of`](super::LatinSchedule::owner_of) gives
//!   the previous owner) and counts those rows/bytes into
//!   [`PlanAccum::comm_rows`](crate::metrics::PlanAccum)
//!   / `comm_bytes`; intra-device handovers are free, exactly as on real
//!   hardware. The per-epoch Eq. 17 core-gradient merge ships one
//!   gradient panel per non-root device.
//!
//! # Why D devices are bitwise-identical to one (exact mode)
//!
//! The grid never changes *what* a worker computes, only which device is
//! accounted for it:
//!
//! 1. the per-(round, worker) nonzero blocks and RNG streams are those
//!    of the underlying `W`-worker engine, independent of `D`;
//! 2. a worker's exact-mode result depends only on its plan's sample
//!    order, and [`BatchPlan`](crate::kernel::BatchPlan) orders samples
//!    by a sort that ignores every capacity parameter — so the
//!    **per-device planner decisions** (each device sizes cap/tile from
//!    its own shard's fiber statistics) cannot move a bit;
//! 3. within a round all workers are row-disjoint (Latin level), so the
//!    device assignment of threads is order-free;
//! 4. the exact-mode core-gradient merge stays the flat left fold in
//!    global worker order (device ranges are contiguous, so device-major
//!    order *is* worker order). Relaxed mode instead uses the paper's
//!    two-stage tree (device-local fold, then device leaders in device
//!    order) — covered by the relaxed accuracy envelope, not the bitwise
//!    contract.
//!
//! Pinned end to end by
//! `tests/properties.rs::prop_sharded_exact_bitwise_matches_single_device`
//! and the CI `FASTTUCKER_DEVICES=2` differential leg.

use crate::algo::{AlgoError, AlgoResult};
use crate::log_warn;
use crate::parallel::{BlockPartition, LatinSchedule};
use crate::tensor::SparseTensor;

/// How many virtual devices the parallel engine shards across.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeviceCount {
    /// Harness-controlled: the `FASTTUCKER_DEVICES` environment variable
    /// when set (CI's 2-device differential leg), else one device per
    /// Latin worker (`D = W`, the historical "each worker is a GPU"
    /// semantics). Auto is a *policy*, so out-of-range values clamp
    /// silently to `[1, workers]`.
    #[default]
    Auto,
    /// Exactly `n` devices (≥ 1). A demand: `n > workers` is a
    /// degenerate grid — it clamps loudly and marks
    /// [`DeviceGrid::degraded`].
    Fixed(usize),
}

impl DeviceCount {
    /// Parse a config/CLI spelling (`"auto"` or a positive integer).
    pub fn parse(s: &str) -> Option<DeviceCount> {
        if s == "auto" {
            return Some(DeviceCount::Auto);
        }
        s.parse::<usize>().ok().filter(|&n| n >= 1).map(DeviceCount::Fixed)
    }
}

/// Resolve a [`DeviceCount`] against a worker count *without* building a
/// grid (config fingerprinting). `Auto` reads `FASTTUCKER_DEVICES` (else
/// `workers`) and clamps silently; `Fixed` is returned as requested —
/// the grid constructor clamps it loudly.
pub fn resolve_devices(devices: DeviceCount, workers: usize) -> usize {
    match devices {
        DeviceCount::Fixed(n) => n.max(1),
        DeviceCount::Auto => match std::env::var("FASTTUCKER_DEVICES") {
            Err(_) => workers,
            Ok(raw) => match raw.trim().parse::<usize>() {
                Ok(n) if n >= 1 => n.clamp(1, workers.max(1)),
                _ => {
                    log_warn!(
                        "FASTTUCKER_DEVICES={raw:?} is not a positive integer; \
                         using one device per worker"
                    );
                    workers
                }
            },
        },
    }
}

/// The device grid: `D` contiguous, balanced groups of the `W` Latin
/// workers, plus the row-ownership and communication geometry derived
/// from the shared `W`-chunk [`BlockPartition`] layout.
#[derive(Clone, Debug)]
pub struct DeviceGrid {
    devices: usize,
    workers: usize,
    dims: Vec<usize>,
    /// `starts[d]..starts[d + 1]` are device `d`'s workers (balanced
    /// split: sizes differ by at most one, every range non-empty).
    starts: Vec<usize>,
    /// Inverse map, `worker -> device`.
    device_of: Vec<usize>,
    degraded: bool,
}

impl DeviceGrid {
    /// Build the grid for `workers` Latin workers over a tensor with
    /// `dims`. Fails with [`AlgoError::PartitionOverflow`] when the
    /// underlying `W^N` geometry is unrepresentable (the same
    /// `checked_pow` guard as [`LatinSchedule`]/[`BlockPartition`] —
    /// ISSUE 5 satellite mirroring the PR 4 `PartitionOverflow` fix), so
    /// config-driven callers never reach a wrapping `usize::pow` or an
    /// aborting allocation through the grid.
    ///
    /// Degenerate-but-representable grids construct with
    /// [`Self::degraded`] set (and a warning) instead of panicking:
    /// `Fixed(D) > workers` clamps to `workers`; `D` larger than the
    /// shortest mode dimension leaves some device without a homeable row
    /// in that mode.
    pub fn try_new(
        devices: DeviceCount,
        workers: usize,
        dims: &[usize],
    ) -> AlgoResult<DeviceGrid> {
        assert!(workers >= 1);
        let order = dims.len();
        assert!(order >= 1);
        workers
            .checked_pow(order as u32)
            .filter(|&n| n <= BlockPartition::MAX_BLOCKS)
            .ok_or(AlgoError::PartitionOverflow { workers, order })?;
        let requested = resolve_devices(devices, workers);
        let mut degraded = false;
        let d = if requested > workers {
            if matches!(devices, DeviceCount::Fixed(_)) {
                log_warn!(
                    "device grid: {requested} devices over {workers} workers is \
                     degenerate — clamping to {workers} (recorded in PlanStats::degraded)"
                );
                degraded = true;
            }
            workers
        } else {
            requested
        };
        // An *explicitly requested* grid wider than the shortest mode
        // leaves some device without a homeable row in that mode —
        // degenerate, flag it. Auto stays silent here (it is a policy,
        // and this geometry was always supported: BlockPartition handles
        // dim < W via empty chunks), so default configs on skinny-mode
        // tensors do not suddenly report degraded passes.
        let min_dim = dims.iter().copied().min().unwrap_or(0);
        if d > 1 && d > min_dim && matches!(devices, DeviceCount::Fixed(_)) {
            log_warn!(
                "device grid: {d} devices exceed the shortest mode dimension \
                 ({min_dim}) — some devices home no rows in that mode \
                 (recorded in PlanStats::degraded)"
            );
            degraded = true;
        }
        // Balanced contiguous worker ranges: start[d] = floor(d·W/D).
        let starts: Vec<usize> = (0..=d).map(|i| i * workers / d).collect();
        let mut device_of = vec![0usize; workers];
        for (dev, range) in starts.windows(2).enumerate() {
            for slot in &mut device_of[range[0]..range[1]] {
                *slot = dev;
            }
        }
        Ok(DeviceGrid { devices: d, workers, dims: dims.to_vec(), starts, device_of, degraded })
    }

    /// Resolved device count `D` (1 ≤ D ≤ workers).
    pub fn devices(&self) -> usize {
        self.devices
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// `true` when the requested grid was degenerate (clamped `Fixed`
    /// count, or `D` exceeding the shortest mode dimension) — surfaced
    /// through [`PlanStats::degraded`](crate::metrics::PlanStats).
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Device hosting Latin worker `g`.
    #[inline]
    pub fn device_of(&self, worker: usize) -> usize {
        self.device_of[worker]
    }

    /// Latin workers of device `d` (contiguous, non-empty).
    #[inline]
    pub fn workers_of(&self, device: usize) -> std::ops::Range<usize> {
        self.starts[device]..self.starts[device + 1]
    }

    /// Row range `[start, end)` of `mode` *homed* on `device`: the union
    /// of the chunks whose index equals one of its worker ids. Worker
    /// ranges are contiguous, so the home rows are one contiguous range
    /// (possibly empty when the mode is shorter than the grid).
    pub fn owned_rows(&self, device: usize, mode: usize) -> (usize, usize) {
        let w = self.workers_of(device);
        let dim = self.dims[mode];
        let (lo, _) = BlockPartition::chunk_range(w.start, dim, self.workers);
        let (_, hi) = BlockPartition::chunk_range(w.end - 1, dim, self.workers);
        (lo, hi)
    }

    /// Device owning nonzero `k` of `tensor`: the home of its mode-0
    /// chunk (mode 0 is worker-pinned in the Latin schedule, so this is
    /// also the device whose workers will process `k` in every round).
    #[inline]
    pub fn device_of_nnz(&self, tensor: &SparseTensor, k: usize) -> usize {
        let row = tensor.index(k)[0] as usize;
        self.device_of[BlockPartition::chunk_of(row, self.dims[0], self.workers)]
    }

    /// Per-device nonzero counts — the division step, one O(nnz) pass
    /// over the per-nonzero definition ([`Self::device_of_nnz`]). Sums
    /// to `tensor.nnz()` (every nonzero on exactly one device). Equal to
    /// [`Self::shard_sizes_from_counts`] over the tensor's mode-0 row
    /// counts (pinned by the unit tests) — callers that already hold
    /// those counts should use that form and skip the tensor walk.
    pub fn shard_sizes(&self, tensor: &SparseTensor) -> Vec<usize> {
        let mut sizes = vec![0usize; self.devices];
        for k in 0..tensor.nnz() {
            sizes[self.device_of_nnz(tensor, k)] += 1;
        }
        sizes
    }

    /// [`Self::shard_sizes`] from precomputed per-mode-0-row nonzero
    /// counts (e.g.
    /// [`FiberStats::mode0_counts`](crate::kernel::FiberStats::mode0_counts),
    /// which the engine already computes for the per-device planner
    /// decisions): each shard is a contiguous slice of `counts`, so no
    /// tensor walk.
    pub fn shard_sizes_from_counts(&self, counts: &[u32]) -> Vec<usize> {
        (0..self.devices)
            .map(|dev| {
                let (lo, hi) = self.owned_rows(dev, 0);
                counts[lo..hi].iter().map(|&c| c as usize).sum()
            })
            .collect()
    }

    /// The `(mode, chunk)` pairs device `d`'s workers process in `round`
    /// that are **not homed** on `d` — its boundary set for the round.
    /// Together with the homed chunks among its assignments these are
    /// exact complements of the chunks the device touches (pinned by
    /// `boundary_and_owned_chunks_are_exact_complements`).
    pub fn boundary_chunks(
        &self,
        schedule: &LatinSchedule,
        round: usize,
        device: usize,
    ) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for g in self.workers_of(device) {
            let assignment = schedule.assignment(round, g);
            for (mode, &chunk) in assignment.iter().enumerate() {
                if self.device_of[chunk] != device {
                    out.push((mode, chunk));
                }
            }
        }
        out
    }

    /// Every factor-chunk handover entering `round`: for each chunk a
    /// worker receives at this round boundary, who wrote it last round,
    /// which rows it spans, and whether the handover crosses a device
    /// boundary (only those become transport panels — intra-device
    /// handovers are free). The order is the engine's fixed apply order
    /// (destination worker, then mode), which both the synchronous
    /// exchange and the async prefetch path (ISSUE 8) must preserve for
    /// the exact-mode bitwise contract. Round 0 has no handovers.
    pub fn round_handovers(&self, schedule: &LatinSchedule, round: usize) -> Vec<Handover> {
        let mut out = Vec::new();
        if round == 0 {
            return out;
        }
        for g in 0..self.workers {
            for (mode, chunk) in schedule.incoming_chunks(round, g) {
                let (row_start, row_end) =
                    BlockPartition::chunk_range(chunk, self.dims[mode], self.workers);
                let src_worker = schedule.owner_of(round - 1, mode, chunk);
                out.push(Handover {
                    src_worker,
                    dst_worker: g,
                    mode,
                    chunk,
                    row_start,
                    n_rows: row_end - row_start,
                    crosses: self.device_of[src_worker] != self.device_of[g],
                });
            }
        }
        out
    }
}

/// One factor-chunk handover at a round boundary (see
/// [`DeviceGrid::round_handovers`]): worker `dst_worker` takes over
/// `chunk` of `mode` — rows `row_start .. row_start + n_rows` — from
/// `src_worker`, who owned (and last wrote) it in the previous round.
/// `crosses` marks the inter-device subset that the channel transport
/// ships as panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Handover {
    pub src_worker: usize,
    pub dst_worker: usize,
    pub mode: usize,
    pub chunk: usize,
    pub row_start: usize,
    pub n_rows: usize,
    pub crosses: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn grid(d: usize, w: usize, dims: &[usize]) -> DeviceGrid {
        DeviceGrid::try_new(DeviceCount::Fixed(d), w, dims).unwrap()
    }

    #[test]
    fn worker_ranges_are_balanced_contiguous_and_complete() {
        forall("device grid worker ranges", 32, |rng| {
            let w = 1 + rng.gen_range(12);
            let d = 1 + rng.gen_range(w);
            let g = grid(d, w, &[64, 64, 64]);
            assert_eq!(g.devices(), d);
            let mut covered = vec![false; w];
            let mut sizes = Vec::new();
            for dev in 0..d {
                let r = g.workers_of(dev);
                assert!(!r.is_empty(), "device {dev} owns no workers");
                sizes.push(r.len());
                for worker in r {
                    assert!(!covered[worker], "worker {worker} on two devices");
                    covered[worker] = true;
                    assert_eq!(g.device_of(worker), dev);
                }
            }
            assert!(covered.iter().all(|&c| c), "worker unassigned");
            let (min, max) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "unbalanced split: {sizes:?}");
        });
    }

    #[test]
    fn every_nonzero_assigned_to_exactly_one_device() {
        // ISSUE 5 satellite: the division step is a partition, and it is
        // consistent with the mode-0 chunk ownership (worker-pinned).
        forall("nonzero division is a partition", 16, |rng| {
            let order = 2 + rng.gen_range(3);
            let w = 1 + rng.gen_range(5);
            let d = 1 + rng.gen_range(w);
            let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(30)).collect();
            let t = synth::random_uniform(rng, &dims, 300, 1.0, 5.0);
            let g = grid(d, w, &dims);
            let sizes = g.shard_sizes(&t);
            assert_eq!(sizes.len(), d);
            assert_eq!(sizes.iter().sum::<usize>(), t.nnz());
            // The counts-slice form the engine uses must agree with the
            // per-nonzero definition.
            let mut counts = vec![0u32; dims[0]];
            for k in 0..t.nnz() {
                counts[t.index(k)[0] as usize] += 1;
            }
            assert_eq!(g.shard_sizes_from_counts(&counts), sizes);
            for k in 0..t.nnz() {
                let dev = g.device_of_nnz(&t, k);
                assert!(dev < d);
                // Consistency: the worker pinned to this nonzero's mode-0
                // chunk lives on that device.
                let chunk = BlockPartition::chunk_of(
                    t.index(k)[0] as usize,
                    dims[0],
                    w,
                );
                assert_eq!(g.device_of(chunk), dev);
            }
        });
    }

    #[test]
    fn boundary_and_owned_chunks_are_exact_complements() {
        // ISSUE 5 satellite: per device/round, the boundary set and the
        // homed set partition the chunks the device touches.
        forall("boundary ⊔ homed = touched", 12, |rng| {
            let order = 2 + rng.gen_range(3);
            let w = 2 + rng.gen_range(4);
            let d = 1 + rng.gen_range(w);
            let dims: Vec<usize> = (0..order).map(|_| w + rng.gen_range(20)).collect();
            let g = grid(d, w, &dims);
            let s = LatinSchedule::new(w, order);
            // The independent level-0/1 auditor must agree with the
            // hand-rolled complement check below (ISSUE 6 tentpole).
            let t = synth::random_uniform(rng, &dims, 200, 1.0, 5.0);
            let report = crate::analysis::audit_schedule_and_grid(&g, &s, &t);
            assert!(report.ok(), "auditor rejected a real grid: {report}");
            assert!(report.checks > 0);
            for round in 0..s.rounds() {
                for dev in 0..d {
                    let boundary: std::collections::HashSet<(usize, usize)> =
                        g.boundary_chunks(&s, round, dev).into_iter().collect();
                    let mut touched = std::collections::HashSet::new();
                    for worker in g.workers_of(dev) {
                        for (mode, &chunk) in
                            s.assignment(round, worker).iter().enumerate()
                        {
                            touched.insert((mode, chunk));
                        }
                    }
                    for &(mode, chunk) in &touched {
                        let homed = g.workers_of(dev).contains(&chunk);
                        assert_eq!(
                            boundary.contains(&(mode, chunk)),
                            !homed,
                            "round {round} device {dev}: chunk ({mode}, {chunk}) \
                             must be boundary iff not homed"
                        );
                    }
                    assert!(
                        boundary.iter().all(|p| touched.contains(p)),
                        "boundary chunk the device never touches"
                    );
                    // A single device touches only its own chunks.
                    if d == 1 {
                        assert!(boundary.is_empty());
                    }
                }
            }
        });
    }

    #[test]
    fn owned_rows_tile_each_mode() {
        let dims = [37usize, 10, 23];
        let g = grid(3, 4, &dims);
        for mode in 0..3 {
            let mut next = 0usize;
            for dev in 0..3 {
                let (lo, hi) = g.owned_rows(dev, mode);
                assert_eq!(lo, next, "gap before device {dev} in mode {mode}");
                assert!(hi >= lo);
                next = hi;
            }
            assert_eq!(next, dims[mode], "mode {mode} rows not fully homed");
        }
    }

    #[test]
    fn round_handovers_cover_every_incoming_chunk_in_apply_order() {
        // ISSUE 8: the shared geometry helper behind both the
        // synchronous exchange accounting and the async prefetch
        // spec-builder must enumerate exactly the schedule's incoming
        // chunks, in (dst worker, mode) order, with the correct previous
        // owner, row range, and device-crossing flag.
        forall("round handovers", 12, |rng| {
            let order = 2 + rng.gen_range(2);
            let w = 2 + rng.gen_range(4);
            let d = 1 + rng.gen_range(w);
            let dims: Vec<usize> = (0..order).map(|_| w + rng.gen_range(20)).collect();
            let g = grid(d, w, &dims);
            let s = LatinSchedule::new(w, order);
            assert!(g.round_handovers(&s, 0).is_empty(), "round 0 has no handovers");
            for round in 1..s.rounds() {
                let hs = g.round_handovers(&s, round);
                let mut expect = Vec::new();
                for worker in 0..w {
                    for (mode, chunk) in s.incoming_chunks(round, worker) {
                        expect.push((worker, mode, chunk));
                    }
                }
                assert_eq!(hs.len(), expect.len(), "round {round}: handover count");
                for (h, (worker, mode, chunk)) in hs.iter().zip(&expect) {
                    assert_eq!((h.dst_worker, h.mode, h.chunk), (*worker, *mode, *chunk));
                    assert_eq!(h.src_worker, s.owner_of(round - 1, h.mode, h.chunk));
                    let (lo, hi) = BlockPartition::chunk_range(h.chunk, dims[h.mode], w);
                    assert_eq!((h.row_start, h.n_rows), (lo, hi - lo));
                    assert_eq!(
                        h.crosses,
                        g.device_of(h.src_worker) != g.device_of(h.dst_worker),
                        "crossing flag disagrees with the device map"
                    );
                    if d == 1 {
                        assert!(!h.crosses, "one device cannot cross a boundary");
                    }
                }
            }
        });
    }

    #[test]
    fn degenerate_grids_degrade_loudly_instead_of_panicking() {
        // Fixed(D) > workers: clamps, flags.
        let g = grid(8, 2, &[16, 16, 16]);
        assert_eq!(g.devices(), 2);
        assert!(g.degraded());
        // An EXPLICIT D exceeding the shortest mode dimension: flags.
        let g = grid(4, 4, &[2, 50, 50]);
        assert_eq!(g.devices(), 4);
        assert!(g.degraded());
        // The same geometry under Auto stays clean — Auto is a policy
        // and this shape was always supported (empty chunks are fine).
        let g = DeviceGrid::try_new(DeviceCount::Auto, 4, &[2, 50, 50]).unwrap();
        assert!(!g.degraded());
        // One-nnz tensor: the division still works (one busy device).
        let t = crate::tensor::SparseTensor::new_unchecked(
            vec![8, 8, 8],
            vec![1, 2, 3],
            vec![1.0],
        );
        let g = grid(2, 2, &[8, 8, 8]);
        assert!(!g.degraded());
        let sizes = g.shard_sizes(&t);
        assert_eq!(sizes.iter().sum::<usize>(), 1);
        assert_eq!(sizes.iter().filter(|&&c| c == 0).count(), 1);
        // Fixed(0) clamps to one device without flagging (config
        // validation rejects it earlier on user paths).
        let g = DeviceGrid::try_new(DeviceCount::Fixed(0), 3, &[8, 8, 8]).unwrap();
        assert_eq!(g.devices(), 1);
        // A healthy grid carries no flag.
        assert!(!grid(2, 4, &[16, 16, 16]).degraded());
    }

    #[test]
    fn overflowing_worker_geometry_is_a_typed_error() {
        // ISSUE 5 satellite: the grid mirrors the PR 4 checked_pow guard —
        // unrepresentable W^N geometry errors before any allocation.
        let err = DeviceGrid::try_new(DeviceCount::Fixed(2), 1 << 22, &[8, 8, 8]).unwrap_err();
        assert!(
            matches!(err, AlgoError::PartitionOverflow { workers, order }
                if workers == 1 << 22 && order == 3),
            "wrong error: {err}"
        );
        // Representable-but-absurd block space is rejected the same way.
        assert!(DeviceGrid::try_new(DeviceCount::Auto, 100_000, &[8, 8, 8]).is_err());
        // Sane geometry constructs through the checked path.
        assert!(DeviceGrid::try_new(DeviceCount::Fixed(2), 4, &[8, 8, 8]).is_ok());
    }

    #[test]
    fn device_count_parse_and_auto_resolution() {
        assert_eq!(DeviceCount::parse("auto"), Some(DeviceCount::Auto));
        assert_eq!(DeviceCount::parse("3"), Some(DeviceCount::Fixed(3)));
        assert_eq!(DeviceCount::parse("0"), None);
        assert_eq!(DeviceCount::parse("many"), None);
        assert_eq!(resolve_devices(DeviceCount::Fixed(5), 2), 5);
        // Auto without the env override is one device per worker. (The
        // env-set case is exercised by CI's FASTTUCKER_DEVICES=2 leg; not
        // asserted here to keep the test env-independent.)
        if std::env::var("FASTTUCKER_DEVICES").is_err() {
            assert_eq!(resolve_devices(DeviceCount::Auto, 4), 4);
        } else {
            // With the env set, Auto still clamps into [1, workers].
            let d = resolve_devices(DeviceCount::Auto, 4);
            assert!((1..=4).contains(&d));
        }
    }

    #[test]
    fn shard_sizes_balanced_on_uniform_data() {
        let mut rng = Rng::new(5);
        let t = synth::random_uniform(&mut rng, &[100, 50, 50], 40_000, 1.0, 5.0);
        let g = grid(2, 4, &[100, 50, 50]);
        let sizes = g.shard_sizes(&t);
        let (min, max) = (
            *sizes.iter().min().unwrap() as f64,
            *sizes.iter().max().unwrap() as f64,
        );
        assert!(max / min < 1.2, "uniform data sharded unevenly: {sizes:?}");
    }
}
