//! The `M^N` block partition of a sparse tensor (paper Fig. 2).
//!
//! Each mode `n` is cut into `M` contiguous chunks of near-equal size;
//! block `(b_1..b_N)` holds the nonzeros whose mode-`n` index falls in
//! chunk `b_n` for every `n`.

use crate::algo::{AlgoError, AlgoResult};
use crate::tensor::SparseTensor;

/// Partition of a tensor's nonzeros into `M^order` blocks.
#[derive(Clone, Debug)]
pub struct BlockPartition {
    m: usize,
    order: usize,
    dims: Vec<usize>,
    /// Nonzero ids per block, block index little-endian in mode order.
    blocks: Vec<Vec<u32>>,
}

impl BlockPartition {
    /// Upper bound on `M^N` blocks a partition will materialize: the
    /// block table alone costs ~24 B per (mostly empty) block, so beyond
    /// this the geometry is a misconfiguration even when the power does
    /// not wrap `usize` — `try_build` rejects it with the same typed
    /// error instead of aborting on a gargantuan allocation.
    pub const MAX_BLOCKS: usize = 1 << 24;

    /// Chunk id of row `i` in a mode of size `dim` cut into `m` chunks.
    /// Chunks are `ceil(dim/m)`-sized, last chunk possibly short.
    #[inline]
    pub fn chunk_of(i: usize, dim: usize, m: usize) -> usize {
        let chunk = dim.div_ceil(m);
        (i / chunk).min(m - 1)
    }

    /// Row range `[start, end)` of chunk `c`.
    #[inline]
    pub fn chunk_range(c: usize, dim: usize, m: usize) -> (usize, usize) {
        let chunk = dim.div_ceil(m);
        let start = (c * chunk).min(dim);
        let end = ((c + 1) * chunk).min(dim);
        (start, end)
    }

    /// Linear block id of per-mode chunk coordinates.
    #[inline]
    pub fn block_id(coords: &[usize], m: usize) -> usize {
        let mut id = 0usize;
        for &c in coords.iter().rev() {
            id = id * m + c;
        }
        id
    }

    /// Build the partition — one O(nnz) pass. Panics when the `M^N`
    /// block count overflows `usize`; config-driven callers should use
    /// [`Self::try_build`], which surfaces that as a typed error
    /// *before* any allocation (ISSUE 4 regression: `usize::pow` wraps
    /// silently in release builds).
    pub fn build(t: &SparseTensor, m: usize) -> Self {
        Self::try_build(t, m).expect("BlockPartition geometry overflows usize")
    }

    /// Checked [`Self::build`]: fails with
    /// [`AlgoError::PartitionOverflow`] when `M^order` overflows.
    pub fn try_build(t: &SparseTensor, m: usize) -> AlgoResult<Self> {
        assert!(m >= 1);
        let order = t.order();
        let n_blocks = m
            .checked_pow(order as u32)
            .filter(|&n| n <= Self::MAX_BLOCKS)
            .ok_or(AlgoError::PartitionOverflow { workers: m, order })?;
        let mut blocks = vec![Vec::new(); n_blocks];
        let dims = t.dims().to_vec();
        let mut coords = vec![0usize; order];
        for k in 0..t.nnz() {
            let ix = t.index(k);
            for n in 0..order {
                coords[n] = Self::chunk_of(ix[n] as usize, dims[n], m);
            }
            blocks[Self::block_id(&coords, m)].push(k as u32);
        }
        Ok(BlockPartition { m, order, dims, blocks })
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn order(&self) -> usize {
        self.order
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Nonzero ids of block `(b_1..b_N)`.
    pub fn block(&self, coords: &[usize]) -> &[u32] {
        &self.blocks[Self::block_id(coords, self.m)]
    }

    pub fn block_by_id(&self, id: usize) -> &[u32] {
        &self.blocks[id]
    }

    /// Load-imbalance factor: max block size / mean block size. The paper's
    /// near-linear scaling requires this to stay close to 1 on uniform data.
    pub fn imbalance(&self) -> f64 {
        let total: usize = self.blocks.iter().map(|b| b.len()).sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.blocks.len() as f64;
        let max = self.blocks.iter().map(|b| b.len()).max().unwrap_or(0);
        max as f64 / mean
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    #[test]
    fn chunk_math() {
        // dim 10, m 3 -> chunks of 4: [0,4) [4,8) [8,10).
        assert_eq!(BlockPartition::chunk_of(0, 10, 3), 0);
        assert_eq!(BlockPartition::chunk_of(3, 10, 3), 0);
        assert_eq!(BlockPartition::chunk_of(4, 10, 3), 1);
        assert_eq!(BlockPartition::chunk_of(9, 10, 3), 2);
        assert_eq!(BlockPartition::chunk_range(2, 10, 3), (8, 10));
    }

    #[test]
    fn chunk_of_never_exceeds_m() {
        // dim < m: everything lands in low chunks but < m.
        for i in 0..3 {
            assert!(BlockPartition::chunk_of(i, 3, 5) < 5);
        }
    }

    #[test]
    fn block_id_is_positional() {
        assert_eq!(BlockPartition::block_id(&[1, 0, 0], 2), 1);
        assert_eq!(BlockPartition::block_id(&[0, 1, 0], 2), 2);
        assert_eq!(BlockPartition::block_id(&[0, 0, 1], 2), 4);
        assert_eq!(BlockPartition::block_id(&[1, 1, 1], 2), 7);
    }

    #[test]
    fn partition_covers_all_nonzeros_exactly_once() {
        forall("block partition is exact", 24, |rng| {
            let order = 2 + rng.gen_range(3);
            let m = 1 + rng.gen_range(4);
            let dims: Vec<usize> = (0..order).map(|_| 3 + rng.gen_range(20)).collect();
            let t = synth::random_uniform(rng, &dims, 300, 1.0, 5.0);
            let p = BlockPartition::build(&t, m);
            assert_eq!(p.n_blocks(), m.pow(order as u32));
            let mut seen = vec![false; t.nnz()];
            for b in 0..p.n_blocks() {
                for &k in p.block_by_id(b) {
                    assert!(!seen[k as usize]);
                    seen[k as usize] = true;
                    // Membership is consistent with chunk_of.
                    let ix = t.index(k as usize);
                    let mut coords = vec![0usize; order];
                    for n in 0..order {
                        coords[n] =
                            BlockPartition::chunk_of(ix[n] as usize, dims[n], m);
                    }
                    assert_eq!(BlockPartition::block_id(&coords, m), b);
                }
            }
            assert!(seen.iter().all(|&x| x));
        });
    }

    #[test]
    fn overflowing_block_count_is_a_typed_error_before_allocating() {
        // ISSUE 4 regression: a huge worker count must not wrap M^N and
        // silently mis-partition (or OOM building the block table).
        let t = synth::random_uniform(&mut Rng::new(2), &[8, 8, 8], 20, 1.0, 5.0);
        let err = BlockPartition::try_build(&t, 1 << 22).unwrap_err();
        assert!(
            matches!(
                err,
                crate::algo::AlgoError::PartitionOverflow { workers, order }
                    if workers == 1 << 22 && order == 3
            ),
            "wrong error: {err}"
        );
        // Representable-but-absurd geometry (no usize wrap, 10^15 blocks)
        // must also error instead of aborting on a petabyte allocation.
        assert!(BlockPartition::try_build(&t, 100_000).is_err());
        // A sane worker count still builds through the checked path.
        let p = BlockPartition::try_build(&t, 2).unwrap();
        assert_eq!(p.n_blocks(), 8);
    }

    #[test]
    fn imbalance_near_one_on_uniform_data() {
        let mut rng = Rng::new(1);
        let t = synth::random_uniform(&mut rng, &[100, 100, 100], 200_000, 1.0, 5.0);
        let p = BlockPartition::build(&t, 2);
        assert!(p.imbalance() < 1.1, "imbalance {}", p.imbalance());
    }
}
