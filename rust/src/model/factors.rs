//! Dense row-major matrices and the per-mode factor matrix collection.
//!
//! `A^(n) ∈ R^{I_n × J}` is stored row-major so a factor row (the SGD unit
//! of work) is one contiguous cache-line-friendly slice — the CPU analogue
//! of the paper's memory-coalesced layout.

use crate::util::Rng;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn random(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Self {
        let data = (0..rows * cols).map(|_| scale * rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }
}

/// The N per-mode factor matrices, all with the same rank J (as in the
/// paper's experiments; per-mode J_n differs only in notation).
#[derive(Clone, Debug)]
pub struct FactorMatrices {
    mats: Vec<Matrix>,
    rank: usize,
}

impl FactorMatrices {
    pub fn random(rng: &mut Rng, dims: &[usize], rank: usize, scale: f32) -> Self {
        let mats = dims
            .iter()
            .map(|&d| Matrix::random(rng, d, rank, scale))
            .collect();
        FactorMatrices { mats, rank }
    }

    pub fn zeros(dims: &[usize], rank: usize) -> Self {
        let mats = dims.iter().map(|&d| Matrix::zeros(d, rank)).collect();
        FactorMatrices { mats, rank }
    }

    pub fn from_mats(mats: Vec<Matrix>) -> Self {
        let rank = mats.first().map(|m| m.cols()).unwrap_or(0);
        assert!(mats.iter().all(|m| m.cols() == rank));
        FactorMatrices { mats, rank }
    }

    pub fn order(&self) -> usize {
        self.mats.len()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn dims(&self) -> Vec<usize> {
        self.mats.iter().map(|m| m.rows()).collect()
    }

    pub fn mats(&self) -> &[Matrix] {
        &self.mats
    }

    pub fn mat(&self, n: usize) -> &Matrix {
        &self.mats[n]
    }

    pub fn mat_mut(&mut self, n: usize) -> &mut Matrix {
        &mut self.mats[n]
    }

    #[inline]
    pub fn row(&self, n: usize, i: usize) -> &[f32] {
        self.mats[n].row(i)
    }

    #[inline]
    pub fn row_mut(&mut self, n: usize, i: usize) -> &mut [f32] {
        self.mats[n].row_mut(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_row_access() {
        let m = Matrix::from_data(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let m = Matrix::random(&mut rng, 5, 7, 1.0);
        assert_eq!(m.transposed().transposed(), m);
    }

    #[test]
    fn factor_matrices_shapes() {
        let mut rng = Rng::new(5);
        let f = FactorMatrices::random(&mut rng, &[10, 20, 30], 4, 0.5);
        assert_eq!(f.order(), 3);
        assert_eq!(f.rank(), 4);
        assert_eq!(f.dims(), vec![10, 20, 30]);
        assert_eq!(f.row(2, 29).len(), 4);
    }

    #[test]
    #[should_panic]
    fn mismatched_ranks_panic() {
        FactorMatrices::from_mats(vec![Matrix::zeros(2, 3), Matrix::zeros(2, 4)]);
    }

    #[test]
    fn row_mut_writes() {
        let mut f = FactorMatrices::zeros(&[3, 3], 2);
        f.row_mut(0, 1).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(f.row(0, 1), &[1.0, 2.0]);
        assert_eq!(f.row(0, 0), &[0.0, 0.0]);
    }
}
