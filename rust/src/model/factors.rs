//! Dense row-major matrices and the per-mode factor matrix collection.
//!
//! `A^(n) ∈ R^{I_n × J}` is stored row-major so a factor row (the SGD unit
//! of work) is one contiguous cache-line-friendly slice — the CPU analogue
//! of the paper's memory-coalesced layout.

use crate::util::element::Element;
use crate::util::Rng;

/// Row-major dense matrix.
///
/// The storage type `E` is any sealed [`Element`] (ISSUE 10): the
/// default `f32` is what every hot kernel consumes; the type parameter
/// keeps factor-storage precision an independent axis from the input
/// value precision ([`crate::tensor::SparseTensor`]). Mixed precision
/// pairs f32 storage with f64 *accumulation* (`PlanParams::wide_accum`)
/// rather than f64 storage, so the hot rows stay half the size.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<E: Element = f32> {
    rows: usize,
    cols: usize,
    data: Vec<E>,
}

impl<E: Element> Matrix<E> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![E::ZERO; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<E>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn random(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Self {
        let data = (0..rows * cols).map(|_| E::from_f32(scale * rng.normal())).collect();
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[E] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [E] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[E] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [E] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> E {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: E) {
        self.data[i * self.cols + j] = v;
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix<E> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Frobenius norm (accumulated wide).
    pub fn frob_norm(&self) -> f32 {
        (self.data.iter().map(|&x| x.to_f64() * x.to_f64()).sum::<f64>()).sqrt() as f32
    }
}

/// The N per-mode factor matrices, all with the same rank J (as in the
/// paper's experiments; per-mode J_n differs only in notation).
#[derive(Clone, Debug)]
pub struct FactorMatrices<E: Element = f32> {
    mats: Vec<Matrix<E>>,
    rank: usize,
}

impl<E: Element> FactorMatrices<E> {
    pub fn random(rng: &mut Rng, dims: &[usize], rank: usize, scale: f32) -> Self {
        let mats = dims
            .iter()
            .map(|&d| Matrix::random(rng, d, rank, scale))
            .collect();
        FactorMatrices { mats, rank }
    }

    pub fn zeros(dims: &[usize], rank: usize) -> Self {
        let mats = dims.iter().map(|&d| Matrix::zeros(d, rank)).collect();
        FactorMatrices { mats, rank }
    }

    pub fn from_mats(mats: Vec<Matrix<E>>) -> Self {
        let rank = mats.first().map(|m| m.cols()).unwrap_or(0);
        assert!(mats.iter().all(|m| m.cols() == rank));
        FactorMatrices { mats, rank }
    }

    pub fn order(&self) -> usize {
        self.mats.len()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn dims(&self) -> Vec<usize> {
        self.mats.iter().map(|m| m.rows()).collect()
    }

    pub fn mats(&self) -> &[Matrix<E>] {
        &self.mats
    }

    pub fn mat(&self, n: usize) -> &Matrix<E> {
        &self.mats[n]
    }

    pub fn mat_mut(&mut self, n: usize) -> &mut Matrix<E> {
        &mut self.mats[n]
    }

    #[inline]
    pub fn row(&self, n: usize, i: usize) -> &[E] {
        self.mats[n].row(i)
    }

    #[inline]
    pub fn row_mut(&mut self, n: usize, i: usize) -> &mut [E] {
        self.mats[n].row_mut(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_row_access() {
        let m = Matrix::<f32>::from_data(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(4);
        let m = Matrix::<f32>::random(&mut rng, 5, 7, 1.0);
        assert_eq!(m.transposed().transposed(), m);
    }

    #[test]
    fn factor_matrices_shapes() {
        let mut rng = Rng::new(5);
        let f = FactorMatrices::<f32>::random(&mut rng, &[10, 20, 30], 4, 0.5);
        assert_eq!(f.order(), 3);
        assert_eq!(f.rank(), 4);
        assert_eq!(f.dims(), vec![10, 20, 30]);
        assert_eq!(f.row(2, 29).len(), 4);
    }

    #[test]
    #[should_panic]
    fn mismatched_ranks_panic() {
        FactorMatrices::from_mats(vec![Matrix::<f32>::zeros(2, 3), Matrix::zeros(2, 4)]);
    }

    #[test]
    fn f64_instantiation_stores_wide_rows() {
        // ISSUE 10: factor storage genericizes over the sealed Element
        // types; an f64 matrix keeps values past f32 precision.
        let wide_val = 1.0f64 + 1.0e-12;
        let mut m = Matrix::<f64>::zeros(2, 2);
        m.set(1, 1, wide_val);
        assert_eq!(m.get(1, 1), wide_val);
        assert_ne!(m.get(1, 1) as f32 as f64, wide_val);
        let f = FactorMatrices::<f64>::zeros(&[3, 4], 2);
        assert_eq!(f.dims(), vec![3, 4]);
        assert_eq!(f.row(1, 3), &[0.0f64, 0.0]);
        let mut rng = Rng::new(7);
        let r = FactorMatrices::<f64>::random(&mut rng, &[5], 3, 1.0);
        assert!(r.mat(0).frob_norm() > 0.0);
    }

    #[test]
    fn row_mut_writes() {
        let mut f = FactorMatrices::<f32>::zeros(&[3, 3], 2);
        f.row_mut(0, 1).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(f.row(0, 1), &[1.0, 2.0]);
        assert_eq!(f.row(0, 0), &[0.0, 0.0]);
    }
}
