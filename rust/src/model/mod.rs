//! Model state: the factor matrices `A^(n)` and the core representation,
//! plus initialization and binary checkpointing.

pub mod factors;
pub mod checkpoint;

pub use factors::{FactorMatrices, Matrix};

use crate::kruskal::{DenseCore, KruskalCore};
use crate::util::Rng;

/// Which core representation a model carries.
#[derive(Clone, Debug)]
pub enum CoreRepr {
    /// cuFastTucker: Kruskal-factored core (B^(n) matrices).
    Kruskal(KruskalCore),
    /// cuTucker / SGD_Tucker / P-Tucker / Vest: explicit dense core G.
    Dense(DenseCore),
}

/// A full Tucker model: N factor matrices plus a core.
#[derive(Clone, Debug)]
pub struct TuckerModel {
    pub factors: FactorMatrices,
    pub core: CoreRepr,
}

impl TuckerModel {
    /// Random init with the paper's scheme: factors ~ N(0, 1/J) entries,
    /// Kruskal core factors ~ N(0, 1/R) so the initial prediction variance
    /// is O(1).
    pub fn init_kruskal(rng: &mut Rng, dims: &[usize], j: usize, r_core: usize) -> Self {
        let factors = FactorMatrices::random(rng, dims, j, (1.0 / j as f32).sqrt());
        let core = KruskalCore::random(rng, dims.len(), j, r_core, (1.0 / r_core as f32).sqrt());
        TuckerModel { factors, core: CoreRepr::Kruskal(core) }
    }

    /// Random init with an explicit dense core (baseline algorithms).
    pub fn init_dense(rng: &mut Rng, dims: &[usize], j: usize) -> Self {
        let factors = FactorMatrices::random(rng, dims, j, (1.0 / j as f32).sqrt());
        let core = DenseCore::random(rng, dims.len(), j, (1.0 / j as f32).powi(2));
        TuckerModel { factors, core: CoreRepr::Dense(core) }
    }

    pub fn order(&self) -> usize {
        self.factors.order()
    }

    pub fn rank(&self) -> usize {
        self.factors.rank()
    }

    /// Predict one entry through whichever core representation is held
    /// (the [`crate::kruskal::predict`] dispatch — one oracle path).
    pub fn predict(&self, coords: &[u32]) -> f32 {
        crate::kruskal::predict::predict(&self.factors, &self.core, coords)
    }

    /// Parameter count (the paper's space-overhead comparison).
    pub fn param_count(&self) -> usize {
        let f: usize = self
            .factors
            .mats()
            .iter()
            .map(|m| m.rows() * m.cols())
            .sum();
        let c = match &self.core {
            CoreRepr::Kruskal(core) => core.param_count(),
            CoreRepr::Dense(core) => core.len(),
        };
        f + c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let mut rng = Rng::new(1);
        let m = TuckerModel::init_kruskal(&mut rng, &[10, 12, 14], 4, 3);
        assert_eq!(m.order(), 3);
        assert_eq!(m.rank(), 4);
        assert_eq!(m.param_count(), (10 + 12 + 14) * 4 + 3 * 4 * 3);
    }

    #[test]
    fn dense_init_param_count() {
        let mut rng = Rng::new(2);
        let m = TuckerModel::init_dense(&mut rng, &[10, 12], 4);
        assert_eq!(m.param_count(), (10 + 12) * 4 + 16);
    }

    #[test]
    fn kruskal_vs_dense_predictions_match_after_densify() {
        let mut rng = Rng::new(3);
        let m = TuckerModel::init_kruskal(&mut rng, &[8, 9, 10], 4, 4);
        let kr = match &m.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dense = kr.to_dense();
        let md = TuckerModel { factors: m.factors.clone(), core: CoreRepr::Dense(dense) };
        for coords in [[0u32, 0, 0], [7, 8, 9], [3, 4, 5]] {
            let a = m.predict(&coords);
            let b = md.predict(&coords);
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
