//! Binary checkpoints for [`TuckerModel`] (own format; offline build has
//! no serde). Layout, all little-endian:
//!
//! ```text
//! magic "FTCK" | version u32 | order u32 | rank u32
//! | core_tag u32 (0 = kruskal, 1 = dense) | r_core u32 (kruskal) or 0
//! | dims: order × u64
//! | factor data: per mode, rows*cols f32
//! | core data: kruskal => order × (r_core*J) f32 ; dense => ∏J f32
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::kruskal::{DenseCore, KruskalCore};
use crate::model::factors::{FactorMatrices, Matrix};
use crate::model::{CoreRepr, TuckerModel};

const MAGIC: &[u8; 4] = b"FTCK";
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a model.
pub fn save(model: &TuckerModel, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, model.order() as u32)?;
    write_u32(&mut w, model.rank() as u32)?;
    match &model.core {
        CoreRepr::Kruskal(k) => {
            write_u32(&mut w, 0)?;
            write_u32(&mut w, k.rank() as u32)?;
        }
        CoreRepr::Dense(_) => {
            write_u32(&mut w, 1)?;
            write_u32(&mut w, 0)?;
        }
    }
    for d in model.factors.dims() {
        write_u64(&mut w, d as u64)?;
    }
    for m in model.factors.mats() {
        write_f32s(&mut w, m.data())?;
    }
    match &model.core {
        CoreRepr::Kruskal(k) => {
            for n in 0..k.order() {
                write_f32s(&mut w, k.factor(n).data())?;
            }
        }
        CoreRepr::Dense(d) => write_f32s(&mut w, d.data())?,
    }
    Ok(())
}

/// Load a model.
pub fn load(path: &Path) -> Result<TuckerModel> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a fasttucker checkpoint: bad magic");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let order = read_u32(&mut r)? as usize;
    let rank = read_u32(&mut r)? as usize;
    let core_tag = read_u32(&mut r)?;
    let r_core = read_u32(&mut r)? as usize;
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        dims.push(read_u64(&mut r)? as usize);
    }
    let mut mats = Vec::with_capacity(order);
    for &d in &dims {
        let data = read_f32s(&mut r, d * rank)?;
        mats.push(Matrix::from_data(d, rank, data));
    }
    let factors = FactorMatrices::from_mats(mats);
    let core = match core_tag {
        0 => {
            let mut bs = Vec::with_capacity(order);
            for _ in 0..order {
                let data = read_f32s(&mut r, r_core * rank)?;
                bs.push(Matrix::from_data(r_core, rank, data));
            }
            CoreRepr::Kruskal(KruskalCore::from_factors(bs))
        }
        1 => {
            let len = rank.pow(order as u32);
            let data = read_f32s(&mut r, len)?;
            CoreRepr::Dense(DenseCore::from_data(vec![rank; order], data))
        }
        t => bail!("unknown core tag {t}"),
    };
    Ok(TuckerModel { factors, core })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fasttucker_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn kruskal_roundtrip() {
        let mut rng = Rng::new(10);
        let m = TuckerModel::init_kruskal(&mut rng, &[10, 11, 12], 4, 3);
        let path = tmp("kruskal.ftck");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.order(), 3);
        assert_eq!(loaded.rank(), 4);
        for coords in [[0u32, 0, 0], [9, 10, 11]] {
            assert!((loaded.predict(&coords) - m.predict(&coords)).abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(11);
        let m = TuckerModel::init_dense(&mut rng, &[8, 9], 3);
        let path = tmp("dense.ftck");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        for coords in [[0u32, 0], [7, 8]] {
            assert!((loaded.predict(&coords) - m.predict(&coords)).abs() < 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.ftck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
