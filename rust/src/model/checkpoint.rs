//! Binary checkpoints for [`TuckerModel`] (own format; offline build has
//! no serde). Layout, all little-endian:
//!
//! ```text
//! magic "FTCK" | version u32 (= 2)
//! | order u32 | rank u32
//! | core_tag u32 (0 = kruskal, 1 = dense) | r_core u32 (kruskal) or 0
//! | dims: order × u64
//! | factor data: per mode, rows*cols f32
//! | core data: kruskal => order × (r_core*J) f32 ; dense => ∏J f32
//! | fnv1a64 checksum u64 over every preceding byte   (version ≥ 2)
//! ```
//!
//! Version 2 (ISSUE 7 satellite) appends a whole-file FNV-1a-64 checksum
//! ([`crate::util::fnv1a64`]) so truncation and bit-flips are detected
//! instead of silently loading garbage factors; version-1 files (no
//! trailer) are still accepted for back-compat, with only structural
//! validation. [`load`] never panics and never allocates more than the
//! file's own size on malformed input — every failure is a typed
//! [`AlgoError::CheckpointCorrupt`].

use std::path::Path;

use crate::algo::{AlgoError, AlgoResult};
use crate::util::error::{Context, Result};
use crate::util::fnv1a64;

use crate::kruskal::{DenseCore, KruskalCore};
use crate::model::factors::{FactorMatrices, Matrix};
use crate::model::{CoreRepr, TuckerModel};

const MAGIC: &[u8; 4] = b"FTCK";
const VERSION: u32 = 2;
/// Structural sanity bounds: a header field past these is corruption,
/// not a real model (guards the pre-allocation path — a flipped dims
/// byte must not turn into a multi-GB allocation).
const MAX_ORDER: usize = 16;
const MAX_RANK: usize = 1 << 16;

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked reader over the checkpoint body; every failure is a
/// typed corruption error, never a panic.
struct Body<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Body<'a> {
    fn take(&mut self, n: usize, what: &str) -> AlgoResult<&'a [u8]> {
        if self.bytes.len() - self.pos < n {
            return Err(AlgoError::CheckpointCorrupt {
                detail: format!(
                    "truncated: need {n} bytes for {what}, {} left",
                    self.bytes.len() - self.pos
                ),
            });
        }
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn take_u32(&mut self, what: &str) -> AlgoResult<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn take_u64(&mut self, what: &str) -> AlgoResult<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn take_f32s(&mut self, n: usize, what: &str) -> AlgoResult<Vec<f32>> {
        Ok(self
            .take(n * 4, what)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Save a model (format version 2: body + trailing checksum, written in
/// one `fs::write` so a crash can truncate but never interleave).
pub fn save(model: &TuckerModel, path: &Path) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    push_u32(&mut buf, model.order() as u32);
    push_u32(&mut buf, model.rank() as u32);
    match &model.core {
        CoreRepr::Kruskal(k) => {
            push_u32(&mut buf, 0);
            push_u32(&mut buf, k.rank() as u32);
        }
        CoreRepr::Dense(_) => {
            push_u32(&mut buf, 1);
            push_u32(&mut buf, 0);
        }
    }
    for d in model.factors.dims() {
        push_u64(&mut buf, d as u64);
    }
    for m in model.factors.mats() {
        push_f32s(&mut buf, m.data());
    }
    match &model.core {
        CoreRepr::Kruskal(k) => {
            for n in 0..k.order() {
                push_f32s(&mut buf, k.factor(n).data());
            }
        }
        CoreRepr::Dense(d) => push_f32s(&mut buf, d.data()),
    }
    let checksum = fnv1a64(&buf);
    buf.extend_from_slice(&checksum.to_le_bytes());
    std::fs::write(path, &buf).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Load a model. Every malformed input — unreadable file, truncation,
/// checksum mismatch, impossible header fields — is a typed
/// [`AlgoError::CheckpointCorrupt`]; bit-flipped version-2 files are
/// rejected by the trailing checksum before any factor data is trusted.
pub fn load(path: &Path) -> AlgoResult<TuckerModel> {
    let corrupt = |detail: String| AlgoError::CheckpointCorrupt { detail };
    let bytes = std::fs::read(path).map_err(|e| corrupt(format!("read {path:?}: {e}")))?;
    if bytes.len() < 8 {
        return Err(corrupt(format!("{} bytes is too short for a header", bytes.len())));
    }
    if &bytes[0..4] != MAGIC {
        return Err(corrupt("not a fasttucker checkpoint: bad magic".into()));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let body_bytes = match version {
        2 => {
            if bytes.len() < 16 {
                return Err(corrupt("v2 file too short for a checksum trailer".into()));
            }
            let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
            let actual = fnv1a64(&bytes[..bytes.len() - 8]);
            if actual != stored {
                return Err(corrupt(format!(
                    "checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) — \
                     the file is truncated or bit-flipped"
                )));
            }
            &bytes[8..bytes.len() - 8]
        }
        // Legacy pre-checksum format: structural validation only.
        1 => &bytes[8..],
        v => return Err(corrupt(format!("unsupported checkpoint version {v}"))),
    };
    let mut body = Body { bytes: body_bytes, pos: 0 };
    let order = body.take_u32("order")? as usize;
    let rank = body.take_u32("rank")? as usize;
    let core_tag = body.take_u32("core tag")?;
    let r_core = body.take_u32("core rank")? as usize;
    // Sanity bounds BEFORE any data-sized allocation: a corrupt v1
    // header (no checksum to catch it) must fail here, not OOM.
    if order == 0 || order > MAX_ORDER {
        return Err(corrupt(format!("impossible order {order} (max {MAX_ORDER})")));
    }
    if rank == 0 || rank > MAX_RANK {
        return Err(corrupt(format!("impossible rank {rank} (max {MAX_RANK})")));
    }
    if core_tag == 0 && (r_core == 0 || r_core > MAX_RANK) {
        return Err(corrupt(format!("impossible kruskal core rank {r_core}")));
    }
    let mut dims = Vec::with_capacity(order);
    for n in 0..order {
        let d = body.take_u64("dims")? as usize;
        // A dim larger than the remaining payload could even hold is a
        // corrupt header, rejected before the allocation it implies.
        if d == 0 || d.checked_mul(rank * 4).map_or(true, |b| b > body_bytes.len()) {
            return Err(corrupt(format!("impossible dim {d} for mode {n}")));
        }
        dims.push(d);
    }
    let mut mats = Vec::with_capacity(order);
    for &d in &dims {
        let data = body.take_f32s(d * rank, "factor data")?;
        mats.push(Matrix::from_data(d, rank, data));
    }
    let factors = FactorMatrices::from_mats(mats);
    let core = match core_tag {
        0 => {
            let mut bs = Vec::with_capacity(order);
            for _ in 0..order {
                let data = body.take_f32s(r_core * rank, "kruskal core data")?;
                bs.push(Matrix::from_data(r_core, rank, data));
            }
            CoreRepr::Kruskal(KruskalCore::from_factors(bs))
        }
        1 => {
            let len = (rank as u64)
                .checked_pow(order as u32)
                .and_then(|l| usize::try_from(l).ok())
                .and_then(|l| l.checked_mul(4))
                .filter(|&b| b <= body_bytes.len())
                .map(|b| b / 4);
            let len = match len {
                Some(l) => l,
                None => {
                    return Err(corrupt(format!(
                        "impossible dense core size {rank}^{order}"
                    )))
                }
            };
            let data = body.take_f32s(len, "dense core data")?;
            CoreRepr::Dense(DenseCore::from_data(vec![rank; order], data))
        }
        t => return Err(corrupt(format!("unknown core tag {t}"))),
    };
    if body.pos != body_bytes.len() {
        return Err(corrupt(format!(
            "{} trailing bytes after the core data",
            body_bytes.len() - body.pos
        )));
    }
    Ok(TuckerModel { factors, core })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fasttucker_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn kruskal_roundtrip() {
        let mut rng = Rng::new(10);
        let m = TuckerModel::init_kruskal(&mut rng, &[10, 11, 12], 4, 3);
        let path = tmp("kruskal.ftck");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.order(), 3);
        assert_eq!(loaded.rank(), 4);
        for coords in [[0u32, 0, 0], [9, 10, 11]] {
            assert!((loaded.predict(&coords) - m.predict(&coords)).abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(11);
        let m = TuckerModel::init_dense(&mut rng, &[8, 9], 3);
        let path = tmp("dense.ftck");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        for coords in [[0u32, 0], [7, 8]] {
            assert!((loaded.predict(&coords) - m.predict(&coords)).abs() < 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.ftck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(matches!(
            load(&path),
            Err(AlgoError::CheckpointCorrupt { .. })
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncation_at_every_length() {
        // ISSUE 7 satellite: a partially-written checkpoint (crash mid
        // fs::write) must be rejected as corrupt at EVERY cut point —
        // header, dims, factor data, core data, checksum trailer.
        let mut rng = Rng::new(12);
        let m = TuckerModel::init_kruskal(&mut rng, &[6, 5, 4], 3, 2);
        let path = tmp("trunc.ftck");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut_path = tmp("trunc_cut.ftck");
        for cut in 0..bytes.len() {
            std::fs::write(&cut_path, &bytes[..cut]).unwrap();
            assert!(
                matches!(load(&cut_path), Err(AlgoError::CheckpointCorrupt { .. })),
                "truncation to {cut}/{} bytes went undetected",
                bytes.len()
            );
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cut_path).ok();
    }

    #[test]
    fn rejects_every_single_bit_flip() {
        // The v2 checksum must catch any single flipped bit anywhere in
        // the file — header, payload, or the trailer itself.
        let mut rng = Rng::new(13);
        let m = TuckerModel::init_kruskal(&mut rng, &[5, 4, 3], 3, 2);
        let path = tmp("flip.ftck");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let flip_path = tmp("flip_bad.ftck");
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                std::fs::write(&flip_path, &bad).unwrap();
                assert!(
                    matches!(load(&flip_path), Err(AlgoError::CheckpointCorrupt { .. })),
                    "flip at byte {i} bit {bit} went undetected"
                );
            }
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&flip_path).ok();
    }

    #[test]
    fn rejects_wrong_dims_without_allocating() {
        // A corrupt header claiming absurd geometry must fail the sanity
        // bounds (typed error), not attempt the allocation it implies.
        // Patched v2 files get their checksum recomputed so the header
        // validation itself is what's under test.
        let mut rng = Rng::new(14);
        let m = TuckerModel::init_kruskal(&mut rng, &[6, 5, 4], 3, 2);
        let path = tmp("dims.ftck");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let patched = |patch: &dyn Fn(&mut Vec<u8>)| {
            let mut b = bytes[..bytes.len() - 8].to_vec();
            patch(&mut b);
            let ck = fnv1a64(&b);
            b.extend_from_slice(&ck.to_le_bytes());
            b
        };
        let bad_path = tmp("dims_bad.ftck");
        // order = 10_000 (offset 8), rank = 0 (offset 12), first dim
        // huge (offset 24: after magic+version+order+rank+tag+r_core).
        let cases: Vec<Vec<u8>> = vec![
            patched(&|b| b[8..12].copy_from_slice(&10_000u32.to_le_bytes())),
            patched(&|b| b[12..16].copy_from_slice(&0u32.to_le_bytes())),
            patched(&|b| b[24..32].copy_from_slice(&u64::MAX.to_le_bytes())),
            patched(&|b| b[24..32].copy_from_slice(&(1u64 << 40).to_le_bytes())),
        ];
        for (i, bad) in cases.iter().enumerate() {
            std::fs::write(&bad_path, bad).unwrap();
            assert!(
                matches!(load(&bad_path), Err(AlgoError::CheckpointCorrupt { .. })),
                "bogus-header case {i} went undetected"
            );
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bad_path).ok();
    }

    #[test]
    fn accepts_legacy_v1_files() {
        // v1 = the v2 body without the trailer: strip it, patch the
        // version field, and the loader must still accept the file
        // (structural checks only — no checksum existed to verify).
        let mut rng = Rng::new(15);
        let m = TuckerModel::init_kruskal(&mut rng, &[7, 6, 5], 4, 3);
        let path = tmp("legacy.ftck");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut v1 = bytes[..bytes.len() - 8].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        let v1_path = tmp("legacy_v1.ftck");
        std::fs::write(&v1_path, &v1).unwrap();
        let loaded = load(&v1_path).unwrap();
        for coords in [[0u32, 0, 0], [6, 5, 4]] {
            assert!((loaded.predict(&coords) - m.predict(&coords)).abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&v1_path).ok();
    }
}
