//! **P-Tucker** (Oh et al., ICDE'18) — row-wise ALS for sparse Tucker:
//! for every mode `n` and row `i`, solve the exact least-squares problem
//! over that row's nonzeros,
//!
//! `(Σ_{nz∈Ω_i} d d^T + λI) a_i = Σ_{nz∈Ω_i} x · d`,
//!
//! where `d = D^(n)` is the per-nonzero coefficient vector through the
//! dense core (`O(J^N)` each — P-Tucker has no Kruskal reduction). The
//! J×J normal equations are solved with an in-tree Cholesky.
//!
//! P-Tucker does not update the core tensor (the paper compares
//! factor-update time only for this method).

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats};
use crate::model::{CoreRepr, TuckerModel};
use crate::tensor::{ModeSlices, SparseTensor};
use crate::util::linalg::{cholesky_solve, syr};
use crate::util::Rng;

/// The P-Tucker decomposer.
pub struct PTucker {
    pub lambda: f32,
    slices: Vec<ModeSlices>,
    slices_for: Option<(usize, usize)>, // (nnz, order) fingerprint
}

impl PTucker {
    pub fn new(lambda: f32) -> Self {
        PTucker { lambda, slices: Vec::new(), slices_for: None }
    }

    pub fn with_defaults() -> Self {
        Self::new(0.01)
    }

    fn ensure_slices(&mut self, train: &SparseTensor) {
        let fp = (train.nnz(), train.order());
        if self.slices_for != Some(fp) {
            self.slices = (0..train.order())
                .map(|n| ModeSlices::build(train, n))
                .collect();
            self.slices_for = Some(fp);
        }
    }
}

impl Decomposer for PTucker {
    fn name(&self) -> &'static str {
        "ptucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        _epoch: usize,
        _rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        let core = match &model.core {
            CoreRepr::Dense(c) => c.clone(),
            CoreRepr::Kruskal(_) => {
                return Err(AlgoError::core_mismatch("ptucker", "dense", "Kruskal"))
            }
        };
        self.ensure_slices(train);
        let order = model.order();
        let j = model.rank();
        let t0 = Instant::now();

        let mut ata = vec![0.0f32; j * j];
        let mut atb = vec![0.0f32; j];
        let mut d = vec![0.0f32; j];
        let mut visited = 0usize;

        for n in 0..order {
            let slices = &self.slices[n];
            for i in slices.nonempty_rows() {
                ata.fill(0.0);
                atb.fill(0.0);
                for &nz in slices.slice(i) {
                    let coords = train.index(nz as usize);
                    let x = train.value(nz as usize);
                    core.mode_coeff(&model.factors, coords, n, &mut d);
                    syr(1.0, &d, &mut ata);
                    for (b, &dv) in atb.iter_mut().zip(d.iter()) {
                        *b += x * dv;
                    }
                    visited += 1;
                }
                // Ridge term.
                for k in 0..j {
                    ata[k * j + k] += self.lambda;
                }
                if let Some(sol) = cholesky_solve(&ata, &atb, j) {
                    model.factors.row_mut(n, i).copy_from_slice(&sol);
                }
                // On numerical failure the row is left unchanged (the
                // original implementation guards similarly).
            }
        }

        Ok(EpochStats {
            samples: visited,
            factor_secs: t0.elapsed().as_secs_f64(),
            core_secs: 0.0,
        })
    }

    fn updates_core(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    #[test]
    fn als_converges_fast_on_planted() {
        let spec = PlantedSpec {
            dims: vec![20, 20, 20],
            nnz: 4000,
            j: 3,
            r_core: 3,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        // Give P-Tucker the true dense core (it does not learn the core)
        // and random factors: ALS should fit factors in a few sweeps.
        let mut model = TuckerModel {
            factors: crate::model::factors::FactorMatrices::random(
                &mut rng,
                &spec.dims,
                spec.j,
                0.5,
            ),
            core: CoreRepr::Dense(p.truth_core.to_dense()),
        };
        let mut algo = PTucker::with_defaults();
        let before = rmse(&model, &p.tensor);
        for epoch in 0..5 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.2 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn single_row_solves_exactly() {
        // A mode-0 row with >= J nonzeros and no noise: ALS recovers the
        // least-squares optimum, which reproduces the observations.
        let spec = PlantedSpec {
            dims: vec![4, 10, 10],
            nnz: 600,
            j: 2,
            r_core: 2,
            noise: 0.0,
            clamp: None,
        };
        let mut rng = Rng::new(2);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel {
            factors: p.truth_factors.clone(),
            core: CoreRepr::Dense(p.truth_core.to_dense()),
        };
        // Perturb mode-0 rows only.
        for i in 0..4 {
            for v in model.factors.row_mut(0, i) {
                *v += 0.5;
            }
        }
        let mut algo = PTucker::new(1e-6);
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let after = rmse(&model, &p.tensor);
        assert!(after < 1e-2, "rmse {after}");
    }

    #[test]
    fn does_not_touch_core() {
        let spec = PlantedSpec {
            dims: vec![8, 8, 8],
            nnz: 300,
            j: 2,
            r_core: 2,
            noise: 0.1,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let core_before = match &model.core {
            CoreRepr::Dense(c) => c.data().to_vec(),
            _ => unreachable!(),
        };
        let mut algo = PTucker::with_defaults();
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let core_after = match &model.core {
            CoreRepr::Dense(c) => c.data().to_vec(),
            _ => unreachable!(),
        };
        assert_eq!(core_before, core_after);
    }
}
