//! **SGD_Tucker** (Li et al., 2020) — the stochastic STD strategy *without*
//! the Theorem-1/2 reduction: per sample it **materializes** the Kronecker
//! rows `s^(n) = a^(N) ⊗ … ⊗ a^(n+1) ⊗ a^(n-1) ⊗ … ⊗ a^(1)` (length
//! `∏_{m≠n} J`) and contracts them against the matricized dense core
//! `G^(n)`, exactly the intermediate-matrix construction the paper's
//! complexity analysis (Section 4.3) charges `O(∏ J_k)` per sample, plus
//! the memory traffic of writing/reading the materialized rows.

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats, SgdHyper};
use crate::model::{CoreRepr, TuckerModel};
use crate::sched::Sampler;
use crate::tensor::{indexing, SparseTensor};
use crate::util::linalg::{dot, scale_axpy};
use crate::util::Rng;

/// Scratch: the materialized Kronecker row, per-mode matricization tables,
/// and the epoch core-gradient accumulator.
struct KronWs {
    order: usize,
    j: usize,
    core_len: usize,
    /// `tables[n][jn * ncols + col]` = dense core index of `G^(n)[jn, col]`.
    tables: Vec<Vec<u32>>,
    /// Materialized Kronecker row (ncols = core_len / j).
    s: Vec<f32>,
    /// Per-mode coefficient vectors `D^(n)`, flattened `[n][j]`.
    d: Vec<f32>,
    core_grad: Vec<f32>,
    core_grad_count: usize,
}

impl KronWs {
    fn new(order: usize, j: usize) -> Self {
        let core_len = j.pow(order as u32);
        let ncols = core_len / j;
        let dims = vec![j; order];
        let mut tables = Vec::with_capacity(order);
        let mut coords = vec![0u32; order];
        for n in 0..order {
            let mut tbl = vec![0u32; core_len];
            for jn in 0..j {
                coords[n] = jn as u32;
                for col in 0..ncols {
                    indexing::col_to_coords(col, &dims, n, &mut coords);
                    coords[n] = jn as u32;
                    tbl[jn * ncols + col] = indexing::dense_index(&coords, &dims) as u32;
                }
            }
            tables.push(tbl);
        }
        KronWs {
            order,
            j,
            core_len,
            tables,
            s: vec![0.0; ncols.max(1)],
            d: vec![0.0; order * j],
            core_grad: vec![0.0; core_len],
            core_grad_count: 0,
        }
    }

    /// Materialize `s^(n)` for the sample's factor rows: iterated Kronecker
    /// expansion in mode order (mode 0 fastest), skipping mode `n` — the
    /// ordering `unfold_strides` defines.
    fn materialize_kron(&mut self, model: &TuckerModel, coords: &[u32], n: usize) -> usize {
        let j = self.j;
        self.s[0] = 1.0;
        let mut len = 1usize;
        for m in 0..self.order {
            if m == n {
                continue;
            }
            let a_row = model.factors.row(m, coords[m] as usize);
            // Expand in place from the back to avoid aliasing.
            for jm in (0..j).rev() {
                for t in (0..len).rev() {
                    self.s[jm * len + t] = a_row[jm] * self.s[t];
                }
            }
            len *= j;
        }
        len
    }
}

/// The SGD_Tucker decomposer.
pub struct SgdTucker {
    pub hyper: SgdHyper,
    ws: Option<KronWs>,
}

impl SgdTucker {
    pub fn new(hyper: SgdHyper) -> Self {
        SgdTucker { hyper, ws: None }
    }

    pub fn with_defaults() -> Self {
        Self::new(SgdHyper::default())
    }

    fn ensure_ws(&mut self, order: usize, j: usize) {
        let stale = match &self.ws {
            Some(w) => w.order != order || w.j != j,
            None => true,
        };
        if stale {
            self.ws = Some(KronWs::new(order, j));
        }
    }
}

impl Decomposer for SgdTucker {
    fn name(&self) -> &'static str {
        "sgd_tucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        if matches!(&model.core, CoreRepr::Kruskal(_)) {
            return Err(AlgoError::core_mismatch("sgd_tucker", "dense", "Kruskal"));
        }
        let (order, j) = (model.order(), model.rank());
        self.ensure_ws(order, j);
        let h = self.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);

        let sampler = Sampler::new(train.nnz());
        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        let psi = if h.sample_frac >= 1.0 {
            let mut ids: Vec<usize> = (0..train.nnz()).collect();
            rng.shuffle(&mut ids);
            ids
        } else {
            sampler.one_step(rng, m)
        };

        let ws = self.ws.as_mut().unwrap();
        let ncols = ws.core_len / j;
        let t0 = Instant::now();
        for &k in &psi {
            let coords = train.index(k);
            let x = train.value(k);
            let e;
            {
                // Scoped immutable borrow of the (epoch-validated) dense
                // core: no per-sample clone of the core data.
                let core_data = match &model.core {
                    CoreRepr::Dense(c) => c.data(),
                    CoreRepr::Kruskal(_) => unreachable!(),
                };

                // Materialize every mode's Kronecker row and contract it
                // against the matricized core — all from the *pre-update*
                // factor rows (same linearization point as cuTucker /
                // FastTucker). Mode 0's s is materialized last so it is the
                // one left in `ws.s` for the core-gradient pass below.
                for n in (0..order).rev() {
                    let len = ws.materialize_kron(model, coords, n);
                    debug_assert_eq!(len, ncols);
                    let tbl = &ws.tables[n];
                    for jn in 0..j {
                        let mut acc = 0.0f32;
                        for col in 0..ncols {
                            acc += core_data[tbl[jn * ncols + col] as usize] * ws.s[col];
                        }
                        ws.d[n * j + jn] = acc;
                    }
                }
                e = dot(model.factors.row(0, coords[0] as usize), &ws.d[0..j]) - x;

                // Core gradient via mode-0's materialized row:
                // grad G^(n=0)[jn, col] += e * a0[jn] * s[col].
                if h.update_core {
                    let a0 = model.factors.row(0, coords[0] as usize);
                    let tbl = &ws.tables[0];
                    for jn in 0..j {
                        let coef = e * a0[jn];
                        for col in 0..ncols {
                            ws.core_grad[tbl[jn * ncols + col] as usize] += coef * ws.s[col];
                        }
                    }
                    ws.core_grad_count += 1;
                }
            }

            // Factor SGD updates (Eq. 13 with the dense-core D vectors).
            for n in 0..order {
                let d_n = &ws.d[n * j..(n + 1) * j];
                let row = model.factors.row_mut(n, coords[n] as usize);
                scale_axpy(1.0 - lr_f * h.lambda_factor, -lr_f * e, d_n, row);
            }
        }
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        if h.update_core && ws.core_grad_count > 0 {
            let mcount = ws.core_grad_count as f32;
            let core = match &mut model.core {
                CoreRepr::Dense(c) => c,
                CoreRepr::Kruskal(_) => unreachable!(),
            };
            for (gv, &grad) in core.data_mut().iter_mut().zip(ws.core_grad.iter()) {
                *gv = (1.0 - lr_c * h.lambda_core) * *gv - lr_c * grad / mcount;
            }
            ws.core_grad.fill(0.0);
            ws.core_grad_count = 0;
        }
        let core_secs = t1.elapsed().as_secs_f64();

        Ok(EpochStats { samples: psi.len(), factor_secs, core_secs })
    }

    fn updates_core(&self) -> bool {
        self.hyper.update_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    #[test]
    fn kron_materialization_matches_definition() {
        // s[col] must equal Π_{m≠n} a^(m)[j_m] with the unfold_strides digit
        // ordering.
        let mut rng = Rng::new(1);
        let model = TuckerModel::init_dense(&mut rng, &[5, 6, 7], 3);
        let mut ws = KronWs::new(3, 3);
        let coords = [4u32, 5, 6];
        for n in 0..3 {
            let len = ws.materialize_kron(&model, &coords, n);
            assert_eq!(len, 9);
            let dims = vec![3usize; 3];
            let mut cc = vec![0u32; 3];
            for col in 0..len {
                indexing::col_to_coords(col, &dims, n, &mut cc);
                let mut want = 1.0f32;
                for m in 0..3 {
                    if m != n {
                        want *= model.factors.row(m, coords[m] as usize)[cc[m] as usize];
                    }
                }
                assert!(
                    (ws.s[col] - want).abs() < 1e-5,
                    "n={n} col={col}: {} vs {want}",
                    ws.s[col]
                );
            }
        }
    }

    #[test]
    fn matricization_tables_are_bijections() {
        let ws = KronWs::new(3, 4);
        for n in 0..3 {
            let mut seen = vec![false; ws.core_len];
            for &ix in &ws.tables[n] {
                assert!(!seen[ix as usize]);
                seen[ix as usize] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn converges_on_planted() {
        let spec = PlantedSpec {
            dims: vec![20, 20, 20],
            nnz: 2500,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(2);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let mut algo = SgdTucker::with_defaults();
        algo.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        algo.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..25 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.6 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn agrees_with_cutucker_direction() {
        // One epoch of SGD_Tucker and cuTucker from the same init with the
        // same sample order must produce identical models (they compute the
        // same math differently).
        let spec = PlantedSpec {
            dims: vec![12, 12, 12],
            nnz: 400,
            j: 3,
            r_core: 3,
            noise: 0.1,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let init = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);

        let mut m1 = init.clone();
        let mut a1 = SgdTucker::with_defaults();
        let mut r1 = Rng::new(42);
        a1.train_epoch(&mut m1, &p.tensor, 0, &mut r1).unwrap();

        let mut m2 = init.clone();
        let mut a2 = crate::algo::CuTucker::with_defaults();
        let mut r2 = Rng::new(42);
        a2.train_epoch(&mut m2, &p.tensor, 0, &mut r2).unwrap();

        for n in 0..3 {
            let d1 = m1.factors.mat(n).data();
            let d2 = m2.factors.mat(n).data();
            for (x, y) in d1.iter().zip(d2.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }
}
